"""Distributed memory storage (DataSpaces analogue) tests."""
import numpy as np
import pytest
from tests._prop import given, st

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import (
    DistributedMemoryStorage,
    InProcTransport,
    TransportError,
    decode_homes,
)

DOM = BoundingBox((0, 0), (64, 64))


def _key(name="R", ts=0, v=0):
    return RegionKey("t", name, ElementType.FLOAT32, ts, v)


class FaultyTransport(InProcTransport):
    """In-proc transport with switchable dead servers + call counters —
    deterministic fault injection for the write-failover/rollback tests
    (the socket chaos suite covers the same paths on real processes)."""

    def __init__(self, num_servers: int):
        super().__init__(num_servers)
        self.down: set[int] = set()
        self.lookup_calls = 0

    def _check(self, server: int) -> None:
        if server in self.down:
            raise TransportError(f"server {server} is down (injected)")

    def store(self, server, *a):
        self._check(server)
        return super().store(server, *a)

    def fetch(self, server, *a):
        self._check(server)
        return super().fetch(server, *a)

    def fetch_many(self, server, *a):
        self._check(server)
        return super().fetch_many(server, *a)

    def put_meta(self, server, *a):
        self._check(server)
        return super().put_meta(server, *a)

    def put_meta_batch(self, server, *a):
        self._check(server)
        return super().put_meta_batch(server, *a)

    def lookup(self, server, *a):
        self.lookup_calls += 1
        self._check(server)
        return super().lookup(server, *a)

    def keys(self, server):
        self._check(server)
        return super().keys(server)

    def drop(self, server, *a):
        self._check(server)
        return super().drop(server, *a)

    def drop_block(self, server, *a):
        self._check(server)
        return super().drop_block(server, *a)


def test_put_get_identity():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.random.default_rng(0).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    assert np.array_equal(dms.get(_key(), DOM), arr)


@given(
    st.integers(0, 63), st.integers(0, 63), st.data()
)
def test_roi_reads_match_numpy(y0, x0, data):
    y1 = data.draw(st.integers(y0 + 1, 64))
    x1 = data.draw(st.integers(x0 + 1, 64))
    dms = DistributedMemoryStorage(DOM, (16, 16), 3)
    arr = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    dms.put(_key(), DOM, arr)
    roi = BoundingBox((y0, x0), (y1, x1))
    assert np.array_equal(dms.get(_key(), roi), arr[roi.slices()])


def test_partial_put_roi_get():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.ones((32, 32), np.float32)
    part = BoundingBox((16, 16), (48, 48))
    dms.put(_key(), part, arr)
    got = dms.get(_key(), BoundingBox((20, 20), (40, 40)))
    assert got.shape == (20, 20) and (got == 1).all()


def test_uncovered_roi_raises():
    dms = DistributedMemoryStorage(DOM, (16, 16), 2)
    dms.put(_key(), BoundingBox((0, 0), (16, 16)), np.ones((16, 16), np.float32))
    import pytest

    with pytest.raises(KeyError):
        dms.get(_key(), DOM)


def test_overlapping_writes_last_staged_wins():
    """Paper S3.4: storage keeps the last staged version of overlaps."""
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    a = np.zeros((64, 64), np.float32)
    b = np.ones((32, 64), np.float32)
    dms.put(_key(), DOM, a)
    dms.put(_key(), BoundingBox((16, 0), (48, 64)), b)
    got = dms.get(_key(), DOM)
    assert (got[16:48] == 1).all() and (got[:16] == 0).all() and (got[48:] == 0).all()


def test_sfc_balances_servers():
    dms = DistributedMemoryStorage(DOM, (8, 8), 4)
    arr = np.random.default_rng(1).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    load = dms.server_load()
    assert len(load) == 4
    assert max(load) <= 2 * min(load)  # SFC range partition is balanced
    # at R > 1 the PHYSICAL load includes replica copies, which are not
    # an SFC imbalance — the balance check must use the primary view
    dms2 = DistributedMemoryStorage(DOM, (8, 8), 4, replication=2)
    dms2.put(_key(), DOM, arr)
    by_role = dms2.server_load(by_role=True)
    assert sum(by_role["total"]) == 2 * arr.nbytes
    assert sum(by_role["primary"]) == arr.nbytes
    assert sum(by_role["replica"]) == arr.nbytes
    # the primary (SFC-partition) view matches the unreplicated balance
    assert by_role["primary"] == load
    assert max(by_role["primary"]) <= 2 * min(by_role["primary"])


def test_metadata_propagated_payload_single_home():
    dms = DistributedMemoryStorage(DOM, (32, 32), 4)
    arr = np.ones((32, 32), np.float32)
    dms.put(_key(), BoundingBox((0, 0), (32, 32)), arr)
    stats = dms.transport.stats
    assert stats.puts == 1  # one payload block, one home server
    assert stats.meta_msgs == 3  # metadata broadcast to the other servers
    # every server's directory can answer
    for srv in dms._servers:
        assert srv.lookup(_key())


def test_versioned_keys_coexist_and_query():
    dms = DistributedMemoryStorage(DOM, (16, 16), 2)
    dms.put(_key(ts=0), DOM, np.zeros((64, 64), np.float32))
    dms.put(_key(ts=1), DOM, np.ones((64, 64), np.float32))
    found = dms.query("t", "R")
    assert [k.timestamp for k, _ in found] == [0, 1]
    assert (dms.get(_key(ts=1), DOM) == 1).all()
    dms.delete(_key(ts=0))
    assert len(dms.query("t", "R")) == 1


def test_trailing_channel_dims():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    key = RegionKey("t", "RGB", ElementType.UINT8)
    arr = np.random.default_rng(2).integers(0, 255, (64, 64, 3), dtype=np.uint8)
    dms.put(key, DOM, arr)
    roi = BoundingBox((10, 20), (30, 60))
    assert np.array_equal(dms.get(key, roi), arr[10:30, 20:60])


def test_replication_places_blocks_on_ring_neighbors():
    """replication=2: every block lands on its home AND the next server
    along the SFC virtual-domain ring, doubling resident bytes but
    leaving reads bit-exact."""
    from repro.storage import decode_homes

    dms = DistributedMemoryStorage(DOM, (16, 16), 4, replication=2)
    arr = np.random.default_rng(3).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    assert np.array_equal(dms.get(_key(), DOM), arr)
    assert sum(dms.server_load()) == 2 * arr.nbytes  # write amplification = R
    directory = dms.transport.lookup(1, _key())
    assert len(directory) == 16
    for bc, (_, h) in directory.items():
        homes = decode_homes(h)
        assert homes == dms.replica_servers(bc)
        assert homes[0] == dms.home_server(bc)
        assert homes[1] == (homes[0] + 1) % 4
        # the payload really is resident on both replicas
        for sid in homes:
            assert dms._servers[sid].fetch(_key(), bc) is not None
    assert dms.stats.failover_fetches == 0  # healthy fleet: primaries serve


def test_replication_validation():
    import pytest

    with pytest.raises(ValueError, match="replication"):
        DistributedMemoryStorage(DOM, (16, 16), 4, replication=0)
    with pytest.raises(ValueError, match="replication"):
        DistributedMemoryStorage(DOM, (16, 16), 4, replication=5)
    # full replication (R == num_servers) is legal: every server holds all
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, replication=4)
    arr = np.ones((64, 64), np.float32)
    dms.put(_key(), DOM, arr)
    assert all(load == arr.nbytes for load in dms.server_load())
    assert np.array_equal(dms.get(_key(), DOM), arr)


def test_put_failover_rehomes_blocks_onto_live_servers():
    """A dead replica must not fail a put at R=2: blocks whose replica
    set touches the dead server re-home onto the next live server along
    the ring, every block still lands on R distinct live servers, and
    reads stay bit-exact."""
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    arr = np.random.default_rng(20).random((64, 64)).astype(np.float32)
    tr.down.add(2)
    dms.put(_key(), DOM, arr)  # must not raise
    assert dms.stats.put_failovers > 0
    load = dms.server_load()
    assert load[2] == 0  # nothing landed on the dead server
    assert sum(load) == 2 * arr.nbytes  # still R copies of every block
    for bc, (_, h) in tr.lookup(0, _key()).items():
        homes = decode_homes(h)
        assert len(homes) == 2 and 2 not in homes  # actual placement recorded
    np.testing.assert_array_equal(dms.get(_key(), DOM), arr)
    # even with the other replica of the re-homed blocks gone, reads
    # fail over to the re-homed copies: the write failover preserved R
    tr.down.add(1)
    np.testing.assert_array_equal(dms.get(_key(), DOM), arr)


def test_put_degrades_below_r_but_raises_only_at_zero_live():
    """With fewer live servers than R the put degrades (fewer copies,
    recorded faithfully); only zero writable replicas raises."""
    tr = FaultyTransport(2)
    dms = DistributedMemoryStorage(DOM, (32, 32), transport=tr, replication=2)
    arr = np.ones((64, 64), np.float32)
    tr.down.add(1)
    dms.put(_key(), DOM, arr)  # degraded: single copy per block
    for _, (_, h) in tr.lookup(0, _key()).items():
        assert decode_homes(h) == (0,)
    tr.down.add(0)
    with pytest.raises(TransportError, match="ANY server"):
        dms.put(_key("gone"), DOM, arr)


def test_failed_put_rolls_back_partial_blocks():
    """Satellite regression: a put that fails mid-way must not leak the
    blocks it already stored — server_load() returns to pre-put bytes
    and no directory mentions the key."""
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr)  # R=1: strict
    arr = np.random.default_rng(21).random((64, 64)).astype(np.float32)
    dms.put(_key("keep"), DOM, arr)
    pre = dms.server_load()
    assert sum(pre) == arr.nbytes
    tr.down.add(3)
    # R=1 with a dead server: blocks re-home, but the strictly-consistent
    # metadata broadcast fails -> the whole put fails and rolls back
    with pytest.raises(TransportError):
        dms.put(_key("fail"), DOM, arr)
    assert dms.stats.put_rollbacks > 0
    assert dms.server_load() == pre  # no orphaned payload bytes
    tr.down.clear()
    for sid in range(4):
        assert _key("fail") not in tr.keys(sid)  # no phantom directory entries
    np.testing.assert_array_equal(dms.get(_key("keep"), DOM), arr)  # untouched


def test_failed_reput_never_destroys_previous_data():
    """Rolling back a failed RE-put must not drop the key's previous
    incarnation: whatever mix of old/new blocks the failure left, every
    block stays readable (torn beats destroyed)."""
    old = np.ones((64, 64), np.float32)
    new = np.full((64, 64), 2.0, np.float32)
    # broadcast fails AFTER some directories acked (dead server mid-list)
    # and BEFORE any ack (dead server first): both paths must preserve
    for dead_sid in (3, 0):
        tr = FaultyTransport(4)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr)  # R=1 strict
        dms.put(_key(), DOM, old)
        tr.down.add(dead_sid)
        with pytest.raises(TransportError):
            dms.put(_key(), DOM, new)
        tr.down.clear()
        got = dms.get(_key(), DOM)  # must not raise: no entry may dangle
        assert np.isin(got, (1.0, 2.0)).all()
    # a fresh key alongside it still rolls back fully
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr)
    dms.put(_key(), DOM, old)
    pre = dms.server_load()
    tr.down.add(3)
    with pytest.raises(TransportError):
        dms.put(_key("fresh"), DOM, new)
    assert dms.server_load() == pre


def test_put_survives_stale_all_dead_liveness_cache():
    """A liveness cache that (stale-)marks EVERY server dead must not
    fail the put without trying: the fallback stores for real, the
    mirror of the read path's cache-dead fallback."""

    class AllDeadCache(FaultyTransport):
        def alive(self, server):
            return False  # every endpoint inside its backoff window

    tr = AllDeadCache(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    arr = np.random.default_rng(25).random((64, 64)).astype(np.float32)
    dms.put(_key(), DOM, arr)  # servers are actually fine: must succeed
    assert sum(dms.server_load()) == 2 * arr.nbytes
    np.testing.assert_array_equal(dms.get(_key(), DOM), arr)


def test_lookup_cost_r1_single_miss_lookup():
    """Satellite regression: at replication=1 every directory is strictly
    consistent, so a miss must cost exactly ONE lookup (the PR-3 cost);
    at R>1 the empty answer needs a second directory to confirm."""
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr)
    with pytest.raises(KeyError):
        dms.get(_key("absent"), DOM)
    assert tr.lookup_calls == 1

    tr2 = FaultyTransport(4)
    dms2 = DistributedMemoryStorage(DOM, (16, 16), transport=tr2, replication=2)
    with pytest.raises(KeyError):
        dms2.get(_key("absent"), DOM)
    assert tr2.lookup_calls == 2
    # hits pay one lookup at either factor
    arr = np.ones((64, 64), np.float32)
    for d, t in ((dms, tr), (dms2, tr2)):
        d.put(_key(), DOM, arr)
        t.lookup_calls = 0
        d.get(_key(), DOM)
        assert t.lookup_calls == 1


def test_read_balance_spreads_hot_key_over_replicas():
    """Healthy-fleet reads rotate over live replicas (balanced_fetches),
    never counting as fault failover; read_balance=False restores strict
    primary preference."""
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, replication=2)
    arr = np.random.default_rng(22).random((64, 64)).astype(np.float32)
    dms.put(_key(), DOM, arr)
    hot = BoundingBox((0, 0), (16, 16))  # single block: one replica pair
    for _ in range(20):
        np.testing.assert_array_equal(dms.get(_key(), hot), arr[:16, :16])
    assert dms.stats.failover_fetches == 0
    assert 6 <= dms.stats.balanced_fetches <= 14  # ~half served by the replica

    pinned = DistributedMemoryStorage(
        DOM, (16, 16), 4, replication=2, read_balance=False
    )
    pinned.put(_key(), DOM, arr)
    for _ in range(20):
        pinned.get(_key(), hot)
    assert pinned.stats.balanced_fetches == 0
    assert pinned.stats.failover_fetches == 0


def test_repair_refills_server_that_rejoined_empty():
    """Anti-entropy: wipe one server (crash + rejoin-empty analogue) and
    repair() restores every block to R confirmed copies and re-fills the
    wiped directory; a second sweep is a no-op."""
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    arr = np.random.default_rng(23).random((64, 64)).astype(np.float32)
    dms.put(_key(), DOM, arr)
    victim = tr.servers[2]
    was_on_2 = sum(
        1
        for _, (_, h) in tr.lookup(0, _key()).items()
        if 2 in decode_homes(h)
    )
    assert was_on_2 > 0
    victim._blocks.clear()
    victim._meta.clear()
    report = dms.repair()
    assert report["repaired"] == was_on_2
    assert report["lost"] == 0
    assert dms.stats.repaired_blocks == was_on_2
    assert len(tr.lookup(2, _key())) == 16  # directory re-filled too
    assert sum(dms.server_load()) == 2 * arr.nbytes
    np.testing.assert_array_equal(dms.get(_key(), DOM), arr)
    again = dms.repair()
    assert again["repaired"] == 0 and again["meta_fixes"] == 0  # converged
    # a holder that fed the repair can now die: the blocks it shared
    # with the wiped server serve from the re-stored copies — without
    # the sweep they would have had a single live replica left
    tr.down.add(1)
    np.testing.assert_array_equal(dms.get(_key(), DOM), arr)


def test_repair_rehomes_around_dead_servers_and_reports_lost():
    """repair() places new copies only on live servers; a block whose
    every holder is gone is counted lost, not silently dropped."""
    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    arr = np.ones((64, 64), np.float32)
    dms.put(_key(), DOM, arr)
    # wipe server 1's payload+meta AND kill server 2: repair must re-home
    # server 1's blocks onto live servers other than 2
    tr.servers[1]._blocks.clear()
    tr.servers[1]._meta.clear()
    tr.down.add(2)
    report = dms.repair()
    assert report["unreachable"] == 1
    assert report["repaired"] > 0
    for _, (_, h) in tr.lookup(0, _key()).items():
        homes = decode_homes(h)
        live_copies = [s for s in homes if s not in tr.down]
        assert len(live_copies) >= 2 or 2 in homes
    # lost blocks: wipe both replicas of everything, repair reports them
    tr2 = FaultyTransport(4)
    dms2 = DistributedMemoryStorage(DOM, (64, 64), transport=tr2, replication=2)
    dms2.put(_key(), DOM, arr)  # single block on 2 servers
    for s in tr2.servers:
        s._blocks.clear()
    homes = decode_homes(next(iter(tr2.lookup(0, _key()).values()))[1])
    for sid in homes:
        tr2.servers[sid]._meta.clear()
    report = dms2.repair()
    assert report["lost"] == 1
    assert dms2.stats.lost_blocks == 1


def test_auto_repair_background_thread():
    """start_auto_repair heals a wiped server without an explicit call;
    close() stops the thread."""
    import time

    tr = FaultyTransport(4)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    dms.start_auto_repair(0.05)
    with pytest.raises(RuntimeError, match="already running"):
        dms.start_auto_repair(0.05)
    arr = np.random.default_rng(24).random((64, 64)).astype(np.float32)
    dms.put(_key(), DOM, arr)
    tr.servers[1]._blocks.clear()
    tr.servers[1]._meta.clear()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        if dms.stats.repaired_blocks > 0 and len(tr.lookup(1, _key())) == 16:
            break
        time.sleep(0.02)
    assert dms.stats.repaired_blocks > 0
    assert sum(dms.server_load()) == 2 * arr.nbytes
    dms.close()
    assert dms._repair_thread is None
    with pytest.raises(ValueError, match="interval"):
        dms.start_auto_repair(0.0)


def test_throughput_accounting():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.ones((64, 64), np.float32)
    dms.put(_key(), DOM, arr)
    dms.get(_key(), DOM)
    assert dms.transport.stats.bytes_put == arr.nbytes
    assert dms.transport.stats.bytes_get == arr.nbytes
    assert dms.aggregate_throughput() > 0


def test_transport_stats_snapshot_is_atomic_under_hammer():
    """as_dict() must snapshot all counters under the stats lock: with
    writers always bumping (puts, bytes_put) together via add(), every
    snapshot a reader takes must show bytes_put == 64 * puts — skew
    means a torn cross-counter read (mirrors the GatewayStats hammer;
    TransportStats was the remaining PR-7 follow-up)."""
    import threading

    from repro.storage.dms import TransportStats

    stats = TransportStats()
    rounds, writers = 2000, 4
    stop = threading.Event()
    skews = []

    def writer():
        for _ in range(rounds):
            stats.add(puts=1, bytes_put=64, bytes_put_raw=64)

    def reader():
        while not stop.is_set():
            snap = stats.as_dict()
            if snap["bytes_put"] != 64 * snap["puts"]:
                skews.append(snap)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    threads = [threading.Thread(target=writer) for _ in range(writers)]
    for t in readers + threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    stop.set()
    for t in readers:
        t.join(timeout=10)
    assert not skews, skews[:3]
    final = stats.as_dict()
    assert final["puts"] == rounds * writers
    assert final["bytes_put"] == final["bytes_put_raw"] == 64 * rounds * writers
    stats.reset()
    assert all(v == 0 for v in stats.as_dict().values())
    with pytest.raises(AttributeError):
        stats.add(not_a_counter=1)  # typo'd counter names must not pass silently
