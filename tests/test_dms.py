"""Distributed memory storage (DataSpaces analogue) tests."""
import numpy as np
from tests._prop import given, st

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage

DOM = BoundingBox((0, 0), (64, 64))


def _key(name="R", ts=0, v=0):
    return RegionKey("t", name, ElementType.FLOAT32, ts, v)


def test_put_get_identity():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.random.default_rng(0).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    assert np.array_equal(dms.get(_key(), DOM), arr)


@given(
    st.integers(0, 63), st.integers(0, 63), st.data()
)
def test_roi_reads_match_numpy(y0, x0, data):
    y1 = data.draw(st.integers(y0 + 1, 64))
    x1 = data.draw(st.integers(x0 + 1, 64))
    dms = DistributedMemoryStorage(DOM, (16, 16), 3)
    arr = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    dms.put(_key(), DOM, arr)
    roi = BoundingBox((y0, x0), (y1, x1))
    assert np.array_equal(dms.get(_key(), roi), arr[roi.slices()])


def test_partial_put_roi_get():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.ones((32, 32), np.float32)
    part = BoundingBox((16, 16), (48, 48))
    dms.put(_key(), part, arr)
    got = dms.get(_key(), BoundingBox((20, 20), (40, 40)))
    assert got.shape == (20, 20) and (got == 1).all()


def test_uncovered_roi_raises():
    dms = DistributedMemoryStorage(DOM, (16, 16), 2)
    dms.put(_key(), BoundingBox((0, 0), (16, 16)), np.ones((16, 16), np.float32))
    import pytest

    with pytest.raises(KeyError):
        dms.get(_key(), DOM)


def test_overlapping_writes_last_staged_wins():
    """Paper S3.4: storage keeps the last staged version of overlaps."""
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    a = np.zeros((64, 64), np.float32)
    b = np.ones((32, 64), np.float32)
    dms.put(_key(), DOM, a)
    dms.put(_key(), BoundingBox((16, 0), (48, 64)), b)
    got = dms.get(_key(), DOM)
    assert (got[16:48] == 1).all() and (got[:16] == 0).all() and (got[48:] == 0).all()


def test_sfc_balances_servers():
    dms = DistributedMemoryStorage(DOM, (8, 8), 4)
    arr = np.random.default_rng(1).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    load = dms.server_load()
    assert len(load) == 4
    assert max(load) <= 2 * min(load)  # SFC range partition is balanced


def test_metadata_propagated_payload_single_home():
    dms = DistributedMemoryStorage(DOM, (32, 32), 4)
    arr = np.ones((32, 32), np.float32)
    dms.put(_key(), BoundingBox((0, 0), (32, 32)), arr)
    stats = dms.transport.stats
    assert stats.puts == 1  # one payload block, one home server
    assert stats.meta_msgs == 3  # metadata broadcast to the other servers
    # every server's directory can answer
    for srv in dms._servers:
        assert srv.lookup(_key())


def test_versioned_keys_coexist_and_query():
    dms = DistributedMemoryStorage(DOM, (16, 16), 2)
    dms.put(_key(ts=0), DOM, np.zeros((64, 64), np.float32))
    dms.put(_key(ts=1), DOM, np.ones((64, 64), np.float32))
    found = dms.query("t", "R")
    assert [k.timestamp for k, _ in found] == [0, 1]
    assert (dms.get(_key(ts=1), DOM) == 1).all()
    dms.delete(_key(ts=0))
    assert len(dms.query("t", "R")) == 1


def test_trailing_channel_dims():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    key = RegionKey("t", "RGB", ElementType.UINT8)
    arr = np.random.default_rng(2).integers(0, 255, (64, 64, 3), dtype=np.uint8)
    dms.put(key, DOM, arr)
    roi = BoundingBox((10, 20), (30, 60))
    assert np.array_equal(dms.get(key, roi), arr[10:30, 20:60])


def test_replication_places_blocks_on_ring_neighbors():
    """replication=2: every block lands on its home AND the next server
    along the SFC virtual-domain ring, doubling resident bytes but
    leaving reads bit-exact."""
    from repro.storage import decode_homes

    dms = DistributedMemoryStorage(DOM, (16, 16), 4, replication=2)
    arr = np.random.default_rng(3).random((64, 64), dtype=np.float32)
    dms.put(_key(), DOM, arr)
    assert np.array_equal(dms.get(_key(), DOM), arr)
    assert sum(dms.server_load()) == 2 * arr.nbytes  # write amplification = R
    directory = dms.transport.lookup(1, _key())
    assert len(directory) == 16
    for bc, (_, h) in directory.items():
        homes = decode_homes(h)
        assert homes == dms.replica_servers(bc)
        assert homes[0] == dms.home_server(bc)
        assert homes[1] == (homes[0] + 1) % 4
        # the payload really is resident on both replicas
        for sid in homes:
            assert dms._servers[sid].fetch(_key(), bc) is not None
    assert dms.stats.failover_fetches == 0  # healthy fleet: primaries serve


def test_replication_validation():
    import pytest

    with pytest.raises(ValueError, match="replication"):
        DistributedMemoryStorage(DOM, (16, 16), 4, replication=0)
    with pytest.raises(ValueError, match="replication"):
        DistributedMemoryStorage(DOM, (16, 16), 4, replication=5)
    # full replication (R == num_servers) is legal: every server holds all
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, replication=4)
    arr = np.ones((64, 64), np.float32)
    dms.put(_key(), DOM, arr)
    assert all(load == arr.nbytes for load in dms.server_load())
    assert np.array_equal(dms.get(_key(), DOM), arr)


def test_throughput_accounting():
    dms = DistributedMemoryStorage(DOM, (16, 16), 4)
    arr = np.ones((64, 64), np.float32)
    dms.put(_key(), DOM, arr)
    dms.get(_key(), DOM)
    assert dms.transport.stats.bytes_put == arr.nbytes
    assert dms.transport.stats.bytes_get == arr.nbytes
    assert dms.aggregate_throughput() > 0
