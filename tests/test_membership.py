"""Elastic fleet membership: RingView minimal-remap properties, exact
shares, wire roundtrip, adopt rule, and the TokenBucket pacer."""
from fractions import Fraction

import pytest

from repro.storage import RingView, TokenBucket, adopt_newer
from tests._prop import HAVE_HYPOTHESIS, given, settings, st

V = 64  # virtual-domain size used throughout (any value works)


# ---------------------------------------------------------------------------
# genesis: bit-identical to the legacy static partition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 3, 4, 7, 16])
def test_genesis_matches_legacy_range_partition(n):
    ring = RingView.genesis(n)
    assert ring.epoch == 0
    assert ring.servers == tuple(range(n))
    for rank in range(V):
        assert ring.owner(rank, V) == (rank * n) // V
        walk = ring.walk(rank, V)
        home = (rank * n) // V
        assert walk == [(home + i) % n for i in range(n)]


def test_genesis_rejects_empty_fleet():
    with pytest.raises(ValueError):
        RingView.genesis(0)


# ---------------------------------------------------------------------------
# join/leave: minimal remap + exact equal shares
# ---------------------------------------------------------------------------
def test_join_moves_only_newcomer_blocks():
    ring = RingView.genesis(3)
    grown = ring.join(7)
    assert grown.epoch == 1
    assert grown.servers == (0, 1, 2, 7)
    moved = 0
    for rank in range(V):
        before, after = ring.owner(rank, V), grown.owner(rank, V)
        if after == 7:
            moved += 1
        else:
            # minimal remap: nothing shuffles between incumbents
            assert after == before
    # equal shares -> the newcomer takes ~1/(m+1) of the blocks
    assert moved == pytest.approx(V // 4, abs=2)


def test_leave_moves_only_departed_blocks():
    ring = RingView.genesis(4)
    shrunk = ring.leave(1)
    assert shrunk.epoch == 1
    assert shrunk.servers == (0, 2, 3)
    for rank in range(V):
        if ring.owner(rank, V) != 1:
            assert shrunk.owner(rank, V) == ring.owner(rank, V)
        else:
            assert shrunk.owner(rank, V) in (0, 2, 3)


def test_shares_stay_exactly_equal_through_churn():
    ring = RingView.genesis(2)
    for sid in (5, 9, 12):
        ring = ring.join(sid)
    ring = ring.leave(0)
    ring = ring.leave(9)
    m = len(ring.servers)
    for sid in ring.servers:
        assert ring.share(sid) == Fraction(1, m)  # exact, not approximate
    assert sum((ring.share(s) for s in ring.servers), Fraction(0)) == 1


def test_join_leave_reject_bad_members():
    ring = RingView.genesis(2)
    with pytest.raises(ValueError):
        ring.join(1)  # already a member
    with pytest.raises(ValueError):
        ring.leave(5)  # not a member
    with pytest.raises(ValueError):
        RingView.genesis(1).leave(0)  # cannot empty the fleet


def test_walk_covers_fleet_in_ring_order_after_churn():
    ring = RingView.genesis(3).join(8).leave(1)
    for rank in range(V):
        walk = ring.walk(rank, V)
        assert walk[0] == ring.owner(rank, V)
        assert sorted(walk) == sorted(ring.servers)


# ---------------------------------------------------------------------------
# wire form + adopt rule
# ---------------------------------------------------------------------------
def test_json_roundtrip_and_checksum_stability():
    ring = RingView.genesis(3).join(5).leave(0)
    clone = RingView.from_json(ring.to_json())
    assert clone == ring
    assert clone.checksum() == ring.checksum()
    assert RingView.genesis(3).checksum() != ring.checksum()


def test_adopt_newer_keeps_highest_epoch():
    old = RingView.genesis(2)
    new = old.join(2)
    assert adopt_newer(old, new) is new
    assert adopt_newer(new, old) is new
    assert adopt_newer(None, old) is old
    assert adopt_newer(old, None) is old
    assert adopt_newer(old, old) is old  # tie keeps the incumbent


# ---------------------------------------------------------------------------
# property tests (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    churn = st.lists(
        st.tuples(st.sampled_from(["join", "leave"]), st.integers(0, 30)),
        max_size=8,
    )

    @given(n=st.integers(1, 12), ops=churn, vbits=st.integers(4, 10))
    @settings(max_examples=40, deadline=None)
    def test_prop_minimal_remap_and_exact_shares(n, ops, vbits):
        vsize = 1 << vbits
        ring = RingView.genesis(n)
        for op, sid in ops:
            if op == "join" and sid not in ring.servers:
                new = ring.join(sid)
                for rank in range(vsize):
                    if new.owner(rank, vsize) != sid:
                        assert new.owner(rank, vsize) == ring.owner(rank, vsize)
            elif op == "leave" and sid in ring.servers and len(ring.servers) > 1:
                new = ring.leave(sid)
                for rank in range(vsize):
                    if ring.owner(rank, vsize) != sid:
                        assert new.owner(rank, vsize) == ring.owner(rank, vsize)
            else:
                continue
            ring = new
            m = len(ring.servers)
            assert all(ring.share(s) == Fraction(1, m) for s in ring.servers)
            assert RingView.from_json(ring.to_json()) == ring


# ---------------------------------------------------------------------------
# TokenBucket pacer (deterministic via injected clock/sleep)
# ---------------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


def test_token_bucket_paces_beyond_burst():
    clk = _FakeClock()
    tb = TokenBucket(rate=10.0, burst=5.0, clock=clk, sleep=clk.sleep)
    waited = sum(tb.take() for _ in range(5))
    assert waited == 0.0  # burst absorbs the first 5
    w = tb.take()
    assert w == pytest.approx(0.1)  # then 1 token per 1/rate seconds
    assert sum(tb.take() for _ in range(10)) == pytest.approx(1.0)


def test_token_bucket_refills_while_idle_up_to_burst():
    clk = _FakeClock()
    tb = TokenBucket(rate=100.0, burst=3.0, clock=clk, sleep=clk.sleep)
    for _ in range(3):
        tb.take()
    clk.t += 60.0  # refill far past burst -> clamps at burst
    assert [tb.take() for _ in range(3)] == [0.0, 0.0, 0.0]
    assert tb.take() > 0.0


def test_token_bucket_rejects_bad_rate():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0)
