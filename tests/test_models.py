"""Model family behaviour: forward, prefill/decode==forward, MoE routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, encdec as ED, registry, spec, transformer as T

BASE = dict(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab=128, param_dtype=jnp.float32, compute_dtype=jnp.float32,
    remat="none",
)

FAMILIES = {
    "dense": ModelConfig(name="d", family="dense", qk_norm=True, **BASE),
    "relu2_ln": ModelConfig(name="n", family="dense", mlp_kind="relu2",
                            norm_type="layernorm", **BASE),
    "geglu_tied": ModelConfig(name="g", family="dense", mlp_kind="geglu",
                              embed_scale=True, tie_embeddings=True, **BASE),
    "moe": ModelConfig(name="m", family="moe", num_experts=4, experts_per_token=2,
                       num_shared_experts=1, first_k_dense=1, dense_d_ff=128,
                       capacity_factor=4.0, **BASE),
    "mla": ModelConfig(name="mla", family="moe", attn_kind="mla", kv_lora_rank=32,
                       qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                       num_experts=4, experts_per_token=2, capacity_factor=4.0, **BASE),
    "ssm": ModelConfig(name="s", family="ssm", ssm_state=16, ssm_headdim=16, **BASE),
    "hybrid": ModelConfig(name="h", family="hybrid", window=8, num_global_layers=1,
                          ssm_state=8, ssm_headdim=16, **{**BASE, "num_layers": 3}),
}


def _params(cfg, seed=1):
    return spec.materialize(jax.random.key(seed), registry.abstract_params(cfg))


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_shapes_and_finite(fam):
    cfg = FAMILIES[fam]
    params = _params(cfg)
    toks = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % cfg.vocab
    logits, aux = T.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_prefill_decode_matches_forward(fam):
    cfg = FAMILIES[fam]
    params = _params(cfg)
    rng = np.random.default_rng(0)
    B, S, prompt = 2, 12, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = T.forward(params, toks, cfg)
    cache = T.init_cache(cfg, B, S + 2)
    lp, cache = T.prefill(params, toks[:, :prompt], cfg, cache)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(full[:, prompt - 1]), rtol=3e-4, atol=3e-4
    )
    for i in range(prompt, S):
        ld, cache = T.decode_step(params, toks[:, i : i + 1], cfg, cache, jnp.asarray(i))
        np.testing.assert_allclose(
            np.asarray(ld[:, 0]), np.asarray(full[:, i]), rtol=5e-4, atol=5e-4
        )


def test_encdec_prefill_decode_matches_forward():
    cfg = ModelConfig(name="e", family="encdec", enc_layers=2, cross_attention=True, **BASE)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    B, S, prompt = 2, 10, 5
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    frames = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)).astype(np.float32)) * 0.1
    full, _ = ED.forward(params, frames, toks, cfg)
    cache = ED.init_cache(cfg, B, S + 2, 8)
    lp, cache = ED.prefill(params, frames, toks[:, :prompt], cfg, cache)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, prompt - 1]),
                               rtol=3e-4, atol=3e-4)
    for i in range(prompt, S):
        ld, cache = ED.decode_step(params, toks[:, i : i + 1], cfg, cache, jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, i]),
                                   rtol=5e-4, atol=5e-4)


def test_vlm_prefix_shifts_logits():
    cfg = ModelConfig(name="v", family="dense", frontend="patch", frontend_len=4, **BASE)
    params = _params(cfg)
    toks = jnp.arange(16, dtype=jnp.int32).reshape(1, 16) % cfg.vocab
    pre = jnp.ones((1, 4, cfg.d_model), jnp.float32) * 0.02
    logits, _ = T.forward(params, toks, cfg, prefix_embeds=pre)
    assert logits.shape == (1, 20, cfg.vocab)


def test_moe_routing_respects_topk_and_capacity():
    cfg = FAMILIES["moe"]
    from repro.models import layers as L

    p = spec.materialize(jax.random.key(0), L.moe_spec(cfg))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 64)), jnp.float32)
    out, aux = L.moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 0.0
    # capacity sanity: zero-capacity config must not crash, output may drop
    tiny = cfg.replace(capacity_factor=0.01)
    out2, _ = L.moe_forward(p, x, tiny)
    assert out2.shape == x.shape


def test_moe_matches_dense_per_token_oracle():
    """Sort-based dispatch == naive per-token expert loop (big capacity)."""
    cfg = ModelConfig(name="m0", family="moe", num_experts=4, experts_per_token=2,
                      capacity_factor=8.0, **{**BASE, "num_layers": 1})
    from repro.models import layers as L

    p = spec.materialize(jax.random.key(3), L.moe_spec(cfg))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 6, 64)), jnp.float32)
    out, _ = L.moe_forward(p, x, cfg)

    # oracle
    xf = np.asarray(x).reshape(6, 64)
    logits = xf @ np.asarray(p["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros_like(xf)
    for t in range(6):
        top = np.argsort(-probs[t])[:2]
        w = probs[t][top] / probs[t][top].sum()
        for e, wi in zip(top, w):
            h1 = xf[t] @ np.asarray(p["w1"][e], np.float64)
            h3 = xf[t] @ np.asarray(p["w3"][e], np.float64)
            h = h1 / (1 + np.exp(-h1)) * h3
            want[t] += wi * (h @ np.asarray(p["w2"][e], np.float64))
    np.testing.assert_allclose(np.asarray(out)[0], want, rtol=2e-3, atol=2e-3)


def test_param_counts_match_published():
    from repro.configs import get_config

    expected = {
        "qwen3-0.6b": (0.55e9, 0.65e9),
        "gemma-2b": (2.4e9, 2.6e9),
        "nemotron-4-340b": (330e9, 350e9),
        "qwen3-moe-235b-a22b": (230e9, 240e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "mamba2-2.7b": (2.6e9, 2.9e9),
        "hymba-1.5b": (1.4e9, 1.8e9),
        "granite-20b": (19e9, 21.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = registry.count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    active = registry.count_active_params(get_config("qwen3-moe-235b-a22b"))
    assert 20e9 <= active <= 24e9


def test_grouped_moe_matches_global_dispatch():
    """moe_groups > 1 (shard-local dispatch) == global dispatch when
    capacity is ample — the §Perf collective fix must not change math."""
    from repro.models import layers as L

    base = dict(num_layers=1, d_model=32, num_heads=4, num_kv_heads=4, d_ff=64,
                vocab=64, param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg_g = ModelConfig(name="m", family="moe", num_experts=4, experts_per_token=2,
                        capacity_factor=16.0, moe_groups=2, **base)
    cfg_1 = cfg_g.replace(moe_groups=1)
    p = spec.materialize(jax.random.key(0), L.moe_spec(cfg_1))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 32)), jnp.float32)
    y1, a1 = L.moe_forward(p, x, cfg_1)
    y2, a2 = L.moe_forward(p, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_streaming_prefill_matches_masked_path_numerics():
    """Prefill through the streaming attention path (attn_impl honored)
    must equal the xla full-forward logits for every impl."""
    cfg_x = FAMILIES["dense"].replace(attn_impl="xla")
    cfg_c = FAMILIES["dense"].replace(attn_impl="chunked")
    params = _params(cfg_x)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg_x.vocab, (2, 10)), jnp.int32)
    full, _ = T.forward(params, toks, cfg_x)
    for cfg in (cfg_x, cfg_c):
        cache = T.init_cache(cfg, 2, 12)
        lp, _ = T.prefill(params, toks, cfg, cache)
        np.testing.assert_allclose(
            np.asarray(lp[:, 0]), np.asarray(full[:, -1]), rtol=3e-4, atol=3e-4
        )
