import os

# Tests run on the single real CPU device (the 512-device override is
# dryrun-only, per the brief). Keep hypothesis deadlines off: CI boxes jit.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", deadline=None, max_examples=25, derandomize=True)
settings.load_profile("ci")
