import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dryrun-only, per the brief). Keep hypothesis deadlines off: CI boxes jit.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make `from tests._prop import ...` work regardless of rootdir layout.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tests._prop import HAVE_HYPOTHESIS, settings

if HAVE_HYPOTHESIS:
    settings.register_profile("ci", deadline=None, max_examples=25, derandomize=True)
    settings.load_profile("ci")

# The envdrift marker machinery that used to live here is gone: the jax
# API drifts it tracked (jax.sharding.AxisType, jax.shard_map) are fixed
# with version-tolerant accessors, so the whole suite runs unconditionally.
