import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dryrun-only, per the brief). Keep hypothesis deadlines off: CI boxes jit.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make `from tests._prop import ...` work regardless of rootdir layout.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tests._prop import HAVE_HYPOTHESIS, settings

if HAVE_HYPOTHESIS:
    settings.register_profile("ci", deadline=None, max_examples=25, derandomize=True)
    settings.load_profile("ci")

# The envdrift marker machinery that used to live here is gone: the jax
# API drifts it tracked (jax.sharding.AxisType, jax.shard_map) are fixed
# with version-tolerant accessors, so the whole suite runs unconditionally.

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _lock_witness(request):
    """Runtime lock-order witness (tools/relint/witness.py).

    Off by default; the CI net/chaos legs set REPRO_LOCK_WITNESS=1 so
    every test in those legs records real lock-acquisition orders and
    fails on an order-graph cycle or a blocking call under a held lock.
    Tests that install their own witness (the relint suite's deliberate
    cycles) opt out with @pytest.mark.no_lock_witness.
    """
    if not os.environ.get("REPRO_LOCK_WITNESS") or request.node.get_closest_marker(
        "no_lock_witness"
    ):
        yield
        return
    from tools.relint.witness import LockWitness

    witness = LockWitness()
    witness.install()
    try:
        yield
    finally:
        witness.uninstall()
    witness.check()
