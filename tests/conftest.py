import os
import sys

import pytest

# Tests run on the single real CPU device (the 512-device override is
# dryrun-only, per the brief). Keep hypothesis deadlines off: CI boxes jit.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make `from tests._prop import ...` work regardless of rootdir layout.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tests._prop import HAVE_HYPOTHESIS, settings

if HAVE_HYPOTHESIS:
    settings.register_profile("ci", deadline=None, max_examples=25, derandomize=True)
    settings.load_profile("ci")

# --- envdrift: pre-existing environment/API drifts (ROADMAP "Open items") ---
# One source of truth for the unhealthy set, so plain `pytest` and CI agree.
# These are not regressions; they are jax API drift / sandbox limitations
# tracked for burn-down.  Run them anyway with REPRO_RUN_ENVDRIFT=1.
ENVDRIFT_MODULES = {"test_cells.py"}
ENVDRIFT_TESTS = {
    ("test_compression.py", "test_compressed_psum_multi_device_subprocess"),
    ("test_system.py", "test_train_driver_end_to_end_with_restart"),
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "envdrift: pre-existing environment/API drift (skipped unless "
        "REPRO_RUN_ENVDRIFT=1); tracked in ROADMAP.md open items",
    )


def pytest_collection_modifyitems(config, items):
    run_drift = bool(os.environ.get("REPRO_RUN_ENVDRIFT"))
    skip = pytest.mark.skip(
        reason="envdrift: pre-existing environment/API drift (ROADMAP open "
        "item); set REPRO_RUN_ENVDRIFT=1 to run"
    )
    for item in items:
        fname = os.path.basename(str(item.fspath))
        base = item.name.split("[", 1)[0]
        if fname in ENVDRIFT_MODULES or (fname, base) in ENVDRIFT_TESTS:
            item.add_marker(pytest.mark.envdrift)
            if not run_drift:
                item.add_marker(skip)
