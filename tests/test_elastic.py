"""Elastic fleet E2E over the in-process transport: live join/leave,
paced + resumable rebalancing, minimal migration, directory agreement,
the rejoin liveness reset, and the plumbing (TieredStore / gateway /
placement ``when`` rule)."""
import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import (
    DistributedMemoryStorage,
    PlacementPolicy,
    RingView,
    ServerGroup,
    SocketTransport,
    TokenBucket,
    TransportError,
    when,
)

DOM = BoundingBox((0, 0), (64, 64))


def _key(name="R", ns="t"):
    return RegionKey(ns, name, ElementType.FLOAT32, 0)


def _fill(dms, key, seed=7):
    arr = np.random.default_rng(seed).normal(size=DOM.shape).astype(np.float32)
    dms.put(key, DOM, arr)
    return arr


def _block_homes(dms):
    """{block coord -> ideal home under the current epoch} via the public
    placement surface."""
    out = {}
    for bc in np.ndindex(*dms._grid):
        out[tuple(bc)] = dms.home_server(tuple(bc))
    return out


def test_genesis_ring_is_bitexact_with_static_placement():
    """The refactor must not move a single block on a never-resized
    fleet: epoch-0 homes == the legacy (rank*n)//V partition."""
    dms = DistributedMemoryStorage(DOM, (8, 8), 4)
    legacy = DistributedMemoryStorage(DOM, (8, 8), 4)
    assert dms.epoch == 0
    assert _block_homes(dms) == _block_homes(legacy)
    assert dms.membership == RingView.genesis(4)
    dms.close()
    legacy.close()


def test_join_rebalance_minimal_and_idempotent():
    dms = DistributedMemoryStorage(DOM, (8, 8), 3, replication=2)
    key = _key()
    arr = _fill(dms, key)
    before = _block_homes(dms)

    sid = dms.add_server()
    assert sid == 3 and dms.epoch == 1
    after = _block_homes(dms)
    # minimal remap: a home changed iff the newcomer took it
    changed = {bc for bc in before if after[bc] != before[bc]}
    assert changed == {bc for bc in after if after[bc] == sid}
    assert len(changed) > 0

    rep = dms.rebalance()
    assert rep["epoch"] == 1
    assert rep["lost"] == 0 and rep["unreachable"] == 0
    assert rep["complete"] and rep["directories_agree"]
    # only blocks whose R-replica set changed migrate
    assert rep["migrated"] >= len(changed)
    assert rep["scanned"] == 64

    rep2 = dms.rebalance()  # second sweep is a no-op
    assert (rep2["migrated"], rep2["copies_added"], rep2["trimmed"]) == (0, 0, 0)

    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    # every block now sits on its ideal epoch-1 replica set
    for bc in np.ndindex(*dms._grid):
        ideal = dms.replica_servers(tuple(bc))
        for s in ideal:
            found = dms.transport.lookup(s, key)
            assert tuple(bc) in found
    dms.close()


def test_remove_server_drains_with_zero_failed_reads():
    dms = DistributedMemoryStorage(DOM, (8, 8), 4, replication=2)
    key = _key()
    arr = _fill(dms, key)
    rep = dms.remove_server(0)
    assert rep["lost"] == 0 and rep["directories_agree"]
    assert dms.epoch == 1
    assert dms.membership.servers == (1, 2, 3)
    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    # purged: the departed shard no longer holds payloads
    assert 0 not in set(dms.membership.servers)
    dms.close()


def test_remove_server_defers_purge_until_drain_is_clean():
    """A drain that cannot reach an ideal target must NOT purge the
    departed shard: the partial-migration branch keeps the departed sid
    recorded as a holder, so its copy may still be a block's only
    redundancy.  The purge waits for a retry whose sweep leaves nothing
    homed on the sid."""
    dms = DistributedMemoryStorage(DOM, (8, 8), 3, replication=2)
    key = _key()
    arr = _fill(dms, key)
    # make server 1 unreachable: migrations targeting it go partial
    dms.transport.remove_endpoint(1)
    rep = dms.remove_server(0)
    assert rep["lost"] == 0 and rep["complete"]
    assert not rep["drained"] and not rep["purged"]
    # the departed shard keeps serving the copies the directory records
    assert dms._servers[0].payload_bytes > 0
    assert 0 in dms.transport.known_servers()
    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    # the target recovers: the retry finishes the drain, THEN purges
    dms.transport.reset_liveness(1)
    rep = dms.remove_server(0)
    assert rep["drained"] and rep["purged"]
    assert dms._servers[0].payload_bytes == 0
    assert 0 not in dms.transport.known_servers()
    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    dms.close()


def test_remove_server_refuses_shrink_below_replication():
    """The constructor enforces replication <= num_servers; a live
    shrink must not silently void the invariant (replica_servers would
    quietly return fewer than R targets forever after)."""
    dms = DistributedMemoryStorage(DOM, (8, 8), 2, replication=2)
    with pytest.raises(ValueError, match="replication"):
        dms.remove_server(0)
    assert dms.epoch == 0 and dms.membership.servers == (0, 1)
    dms.close()


def test_add_endpoint_gap_sids_are_absent_not_aliased():
    """Skipping ahead in the sid space must not leave placeholder rows
    that dial the newcomer's address (or crash endpoint parsing): gap
    sids answer dead and refuse ops fast."""
    tr = SocketTransport(["127.0.0.1:9"])
    assert tr.add_endpoint("127.0.0.1:11", sid=3) == 3
    assert tr.known_servers() == [0, 3]
    assert not tr.alive(1) and not tr.alive(2)
    with pytest.raises(TransportError, match="left the fleet"):
        tr.keys(1)
    tr.close()


def test_server_group_rejects_skip_ahead_sid():
    group = ServerGroup([], [])
    with pytest.raises(ValueError, match="skips ahead"):
        group.add_server(sid=2)
    assert group.endpoints == []


def test_rebalance_max_blocks_resumes_where_it_stopped():
    dms = DistributedMemoryStorage(DOM, (8, 8), 2)
    key = _key()
    arr = _fill(dms, key)
    dms.add_server()
    first = dms.rebalance(max_blocks=5)
    assert not first["complete"]
    assert first["migrated"] <= 5
    total = first["migrated"]
    for _ in range(40):
        rep = dms.rebalance(max_blocks=5)
        total += rep["migrated"]
        if rep["complete"] and rep["migrated"] == 0:
            break
    else:
        pytest.fail("rebalance never converged")
    assert dms.rebalance()["migrated"] == 0
    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    dms.close()


def test_rebalance_is_paced_by_token_bucket():
    clock = {"t": 0.0}

    def fake_clock():
        return clock["t"]

    def fake_sleep(s):
        clock["t"] += s

    dms = DistributedMemoryStorage(DOM, (8, 8), 2)
    _fill(dms, _key())
    dms.add_server()
    pacer = TokenBucket(rate=1000.0, burst=1.0, clock=fake_clock, sleep=fake_sleep)
    rep = dms.rebalance(pacer=pacer)
    assert rep["migrated"] > 1
    # burst=1: every migration past the first had to wait for a token
    assert rep["paced_wait_s"] > 0.0
    assert clock["t"] >= (rep["migrated"] - 1) / 1000.0 * 0.99
    dms.close()


def test_rejoin_same_sid_is_not_stale_dead():
    """leave + rejoin must reset liveness: the returning sid answers
    probes instead of inheriting a cached dead verdict."""
    dms = DistributedMemoryStorage(DOM, (8, 8), 3, replication=2)
    key = _key()
    arr = _fill(dms, key)
    dms.remove_server(2)
    assert not dms.transport.alive(2)
    sid = dms.add_server(sid=2)
    assert sid == 2
    assert dms.transport.alive(2)
    assert dms.membership.servers == (0, 1, 2)
    rep = dms.rebalance()
    assert rep["lost"] == 0 and rep["directories_agree"]
    np.testing.assert_array_equal(dms.get(key, DOM), arr)
    dms.close()


def test_membership_announcement_reaches_peer_clients():
    """A second client over the same shards adopts the bumped epoch via
    sync_membership (epoch gossip), not via shared Python state."""
    a = DistributedMemoryStorage(DOM, (8, 8), 2)
    b = DistributedMemoryStorage(DOM, (8, 8), 2, transport=a.transport)
    a.add_server()
    assert a.epoch == 1 and b.epoch == 0
    b.sync_membership()
    assert b.epoch == 1
    assert b.membership == a.membership
    a.close()


def test_rebalance_stats_surface():
    dms = DistributedMemoryStorage(DOM, (8, 8), 2)
    _fill(dms, _key())
    st = dms.rebalance_stats()
    assert st["epoch"] == 0 and not st["rebalancing"]
    assert st["last_sweep"] is None
    dms.add_server()
    dms.rebalance()
    st = dms.rebalance_stats()
    assert st["epoch"] == 1
    assert st["last_sweep"]["directories_agree"]
    assert st["ring_checksum"] == dms.membership.checksum()
    assert dms.stats.rebalanced_blocks > 0
    dms.close()


def test_directory_checksums_agree_across_members():
    dms = DistributedMemoryStorage(DOM, (8, 8), 3)
    _fill(dms, _key())
    sums = dms.directory_checksums()
    assert set(sums) == {0, 1, 2}
    assert len(set(sums.values())) == 1


# ---------------------------------------------------------------------------
# plumbing: TieredStore / gateway passthrough / placement when() rule
# ---------------------------------------------------------------------------
def test_tiered_store_standard_forwards_membership(tmp_path):
    from repro.storage import TieredStore

    ring = RingView.genesis(4)
    store = TieredStore.standard(
        DOM, (8, 8), root=str(tmp_path), num_servers=4, membership=ring
    )
    dms = store.tiers[-1].backend
    assert dms.membership == ring
    sid = dms.add_server()
    assert dms.epoch == 1 and sid == 4
    store.close()


def test_gateway_storage_stats_exposes_rebalance(tmp_path):
    from repro.serve.gateway import RegionGateway
    from repro.storage import TieredStore

    store = TieredStore.standard(DOM, (8, 8), root=str(tmp_path), num_servers=2)
    gw = RegionGateway(store)
    try:
        dms = store.tiers[-1].backend
        dms.add_server()
        dms.rebalance()
        stats = gw.storage_stats()
        reb = stats["dms"][dms.name]["rebalance"]
        assert reb["epoch"] == 1
        assert reb["last_sweep"]["complete"]
        assert reb["ring_checksum"] == dms.membership.checksum()
    finally:
        gw.close()


def test_when_rule_routes_matching_regions():
    hits = []

    def is_mask(key, bb, nbytes, dtype):
        hits.append(key.name)
        return key.name.startswith("mask")

    policy = PlacementPolicy([when(is_mask, "DMS", pinned=True)])
    p = policy.place(_key("mask_a"), DOM, 1024, np.float32)
    assert p.tier == "DMS" and p.pinned
    p = policy.place(_key("rgb"), DOM, 1024, np.float32)
    assert p.tier is None and not p.pinned
    assert hits == ["mask_a", "rgb"]
    assert "when:" in repr(policy)
