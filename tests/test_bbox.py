"""BoundingBox unit + property tests."""
import numpy as np
import pytest
from tests._prop import given, st

from repro.core import BoundingBox, union_all


def boxes(rank=2, lo=-50, hi=50):
    def mk(draw):
        los = [draw(st.integers(lo, hi - 1)) for _ in range(rank)]
        his = [draw(st.integers(l, hi)) for l in los]
        return BoundingBox(tuple(los), tuple(his))

    return st.composite(lambda draw: mk(draw))()


@given(boxes(), boxes())
def test_intersect_symmetric_and_contained(a, b):
    i1, i2 = a.intersect(b), b.intersect(a)
    assert i1.shape == i2.shape
    if not i1.is_empty:
        assert a.contains(i1) and b.contains(i1)
        assert a.intersects(b) and b.intersects(a)


@given(boxes(), boxes())
def test_union_contains_both(a, b):
    u = a.union(b)
    assert u.contains(a) and u.contains(b)
    assert union_all([a, b]).shape == u.shape


@given(boxes())
def test_inflate_shrink_roundtrip(a):
    if a.is_empty:
        return
    assert a.inflate(3).shrink(3) == a


def test_tiles_partition_exactly():
    box = BoundingBox((0, 0), (100, 100))
    tiles = list(box.tiles((50, 50)))
    assert len(tiles) == 4
    assert sum(t.volume for t in tiles) == box.volume
    # pairwise disjoint
    for i, t1 in enumerate(tiles):
        for t2 in tiles[i + 1 :]:
            assert not t1.intersects(t2)
    # paper's example: partition 4 of a <0,0;99,99>-ish domain
    assert tiles[-1] == BoundingBox((50, 50), (100, 100))


@given(st.integers(1, 7), st.integers(1, 97))
def test_tiles_cover_irregular(nt, extent):
    box = BoundingBox((0,), (extent,))
    tiles = list(box.tiles((nt,)))
    assert sum(t.volume for t in tiles) == extent


def test_split_weighted_covers():
    box = BoundingBox((0, 0), (100, 20))
    parts = box.split_weighted([1, 2, 7], axis=0)
    assert sum(p.volume for p in parts) == box.volume
    assert parts[0].hi[0] == 10 and parts[1].hi[0] == 30


def test_local_slices_and_ghost_cells():
    outer = BoundingBox((0, 0), (100, 100))
    part = BoundingBox((50, 50), (100, 100))
    roi = part.inflate(2, within=outer)  # ghost cells clipped at the border
    assert roi == BoundingBox((48, 48), (100, 100))
    arr = np.zeros(outer.shape)
    arr[roi.slices()] = 1
    assert arr.sum() == roi.volume
    back = roi.shrink(0)
    assert back == roi


def test_invalid_boxes_raise():
    with pytest.raises(ValueError):
        BoundingBox((0, 0), (1,))
    with pytest.raises(ValueError):
        BoundingBox((5,), (2,))
