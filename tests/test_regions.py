"""Region template / data region semantics (paper S3.3)."""
import numpy as np
import pytest

from repro.core import (
    BoundingBox,
    ElementType,
    RegionKey,
    RegionTemplate,
    StorageRegistry,
)
from repro.storage import DistributedMemoryStorage


def test_template_bb_grows_to_minimum_cover():
    rt = RegionTemplate("Patient")
    rt.new_region("RGB", BoundingBox((0, 0), (50, 50)), np.float32)
    assert rt.bb == BoundingBox((0, 0), (50, 50))
    rt.new_region("Mask", BoundingBox((25, 25), (100, 80)), np.int32)
    assert rt.bb == BoundingBox((0, 0), (100, 80))


def test_versioning_latest_wins():
    rt = RegionTemplate("P")
    bb = BoundingBox((0, 0), (4, 4))
    rt.new_region("RGB", bb, np.float32, timestamp=0, version=0)
    rt.new_region("RGB", bb, np.float32, timestamp=0, version=1)
    rt.new_region("RGB", bb, np.float32, timestamp=3, version=0)
    assert rt.get("RGB").key.timestamp == 3
    assert rt.get("RGB", timestamp=0).key.version == 1
    assert rt.get("RGB", timestamp=0, version=0).key.version == 0
    assert len(rt.versions("RGB")) == 3


def test_duplicate_key_rejected():
    rt = RegionTemplate("P")
    bb = BoundingBox((0, 0), (4, 4))
    rt.new_region("RGB", bb, np.float32)
    with pytest.raises(ValueError):
        rt.new_region("RGB", bb, np.float32)


def test_lazy_instantiate_and_write_through_storage():
    reg = StorageRegistry()
    dom = BoundingBox((0, 0), (16, 16))
    dms = reg.register(DistributedMemoryStorage(dom, (8, 8), 2, name="DMS"))
    data = np.arange(256, dtype=np.float32).reshape(16, 16)
    key = RegionKey("default", "RGB", ElementType.FLOAT32)
    dms.put(key, dom, data)

    rt = RegionTemplate("P")
    r = rt.new_region("RGB", dom, np.float32, input_storage="DMS", lazy=True)
    assert r.empty()
    got = r.instantiate(reg)
    assert np.array_equal(got, data)
    assert r.stats["reads"] == 1

    # ROI view + write-back with bumped version
    roi = BoundingBox((4, 4), (12, 12))
    view = r.with_roi(roi)
    view.input_storage = "DMS"
    view.instantiate(reg)
    view.key = view.key.bump()
    view.output_storage = "DMS"
    view.set_data(np.asarray(view.data) + 1)
    view.write(reg)
    assert np.array_equal(dms.get(view.key, roi), data[4:12, 4:12] + 1)


def test_pack_unpack_metadata_only():
    rt = RegionTemplate("P", "ns")
    bb = BoundingBox((0, 0), (8, 8))
    r = rt.new_region("RGB", bb, np.uint8, data=np.zeros((8, 8), np.uint8),
                      input_storage="DMS", output_storage="DISK")
    blob = rt.pack()
    rt2 = RegionTemplate.unpack(blob)
    r2 = rt2.get("RGB")
    assert r2.key == r.key and r2.bb == bb
    assert r2.empty()  # payloads never ride the control channel
    assert r2.input_storage == "DMS" and r2.output_storage == "DISK"
    assert rt2.bb == rt.bb


def test_elementtype_roundtrip():
    import jax.numpy as jnp

    for dt in (np.uint8, np.int32, np.int64, np.float32, np.float64, np.bool_, jnp.bfloat16):
        et = ElementType.from_dtype(dt)
        assert et.to_dtype() == np.dtype(dt)
