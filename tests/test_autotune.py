"""I/O configuration auto-tuner (paper §5.3 future work)."""
from repro.storage.autotune import autotune_io, default_space


def test_space_is_reasonable():
    space = default_space(8)
    assert len(space) >= 8
    assert any(c.transport == "posix" for c in space)
    assert any(c.io_mode == "separated" for c in space)


def test_autotune_prefers_colocated_small_groups():
    """The paper's finding: co-located + small groups wins; the tuner
    should rediscover it from the virtual-time model."""
    res = autotune_io(num_writers=8, workload_chunks=32)
    assert res.best.io_mode == "colocated"
    assert res.best.io_group_size <= 4
    assert res.virtual_s > 0
    # the winner must come from the final (full-workload) round
    finals = res.trials[-4:]
    assert res.virtual_s == min(t for _, t in finals)
