"""Tiered staging subsystem: promotion, spill-down, write-back, locality."""
import threading
import time

import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey, StorageBackend, StorageRegistry
from repro.runtime.dag import Task, TaskCost
from repro.runtime.scheduler import SchedulerConfig
from repro.storage import (
    MemoryTier,
    PlacementPolicy,
    Tier,
    TieredStore,
    pin_namespace,
    size_threshold,
)

DOM = BoundingBox((0, 0), (128, 128))
TILE_BYTES = 128 * 128 * 4  # one float32 domain-sized region


def _key(name: str, ns: str = "t") -> RegionKey:
    return RegionKey(ns, name, ElementType.FLOAT32)


def _arr(seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).random((128, 128)).astype(np.float32)


def _mem_stack(capacity_tiles: int = 2, **kw) -> TieredStore:
    """Three in-memory tiers: deterministic, no disk/DMS setup needed."""
    return TieredStore(
        [
            Tier("MEM", MemoryTier(name="MEM"), capacity_tiles * TILE_BYTES),
            Tier("DISK", MemoryTier(name="DISK")),
            Tier("DMS", MemoryTier(name="DMS")),
        ],
        **kw,
    )


def test_protocol_and_registry_drop_in():
    ts = _mem_stack()
    assert isinstance(ts, StorageBackend)
    reg = StorageRegistry()
    reg.register(ts)
    assert reg.get("TIERED") is ts
    k, a = _key("r"), _arr()
    ts.put(k, DOM, a)
    np.testing.assert_array_equal(reg.get("TIERED").get(k, DOM), a)
    assert reg.locality("TIERED", k) == "MEM"
    ts.close()


def test_promotion_on_repeat_read():
    ts = _mem_stack(capacity_tiles=4, promote_after=2)
    k, a = _key("hot"), _arr()
    # stage directly into the bottom tier (externally produced data):
    # metadata-only locality cannot see it, the probing form can
    ts.tiers[-1].backend.put(k, DOM, a)
    assert ts.locality(k) is None
    assert ts.locality(k, probe=True) == "DMS"
    ts.get(k, DOM)
    assert ts.locality(k) == "DMS"  # below the promotion threshold
    ts.get(k, DOM)  # second read crosses promote_after -> straight to RAM
    assert ts.locality(k) == "MEM"
    stats = ts.tier_stats()
    assert stats["MEM"].promotions == 1
    assert stats["MEM"].bytes_promoted == a.nbytes
    # the promoted copy serves subsequent reads from RAM
    before = stats["MEM"].hits
    np.testing.assert_array_equal(ts.get(k, DOM), a)
    assert ts.tier_stats()["MEM"].hits == before + 1
    ts.close()


def test_capacity_eviction_demotes_not_drops():
    ts = _mem_stack(capacity_tiles=2, write_policy="write_back")
    keys = [_key(f"r{i}") for i in range(4)]
    arrs = [_arr(i) for i in range(4)]
    for k, a in zip(keys, arrs):
        ts.put(k, DOM, a)
    # MEM holds at most 2 tiles; older tiles must have been spilled DOWN
    assert ts.used_bytes("MEM") <= 2 * TILE_BYTES
    assert ts.tier_stats()["MEM"].demotions >= 2
    for k, a in zip(keys, arrs):  # nothing was dropped
        np.testing.assert_array_equal(ts.get(k, DOM), a)
    demoted = [k for k in keys if ts.locality(k) != "MEM"]
    assert demoted, "older regions should live in a lower tier"
    ts.close()


def test_write_through_is_immediately_durable():
    ts = _mem_stack(write_policy="write_through")
    k, a = _key("wt"), _arr()
    ts.put(k, DOM, a)
    np.testing.assert_array_equal(ts.tiers[-1].backend.get(k, DOM), a)
    assert not ts.dirty(k)
    ts.close()


def test_write_back_drain_makes_bottom_durable():
    ts = _mem_stack(write_policy="write_back")
    k, a = _key("wb"), _arr()
    ts.put(k, DOM, a)
    ts.drain()
    assert not ts.dirty(k)
    np.testing.assert_array_equal(ts.tiers[-1].backend.get(k, DOM), a)
    # delete cancels any still-queued flush without resurrecting the key
    k2 = _key("wb2")
    ts.put(k2, DOM, a)
    ts.delete(k2)
    ts.drain()
    with pytest.raises(KeyError):
        ts.get(k2, DOM)
    ts.close()


def test_concurrent_readers_and_flusher():
    ts = _mem_stack(capacity_tiles=3, write_policy="write_back", promote_after=1)
    keys = [_key(f"c{i}") for i in range(6)]
    arrs = [_arr(100 + i) for i in range(6)]
    for k, a in zip(keys, arrs):  # pre-populate: reads must NEVER fail
        ts.put(k, DOM, a)
    errors: list[BaseException] = []

    def writer():
        try:
            for _ in range(3):
                for k, a in zip(keys, arrs):
                    ts.put(k, DOM, a)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def reader(seed: int):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                i = int(rng.integers(len(keys)))
                # demotion/promotion/flush churn must never surface as a
                # missing key or torn payload
                got = ts.get(keys[i], DOM)
                np.testing.assert_array_equal(got, arrs[i])
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader, args=(s,)) for s in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    ts.drain()
    assert not errors, errors
    bottom = ts.tiers[-1].backend
    for k, a in zip(keys, arrs):  # every write-back reached the bottom tier
        np.testing.assert_array_equal(bottom.get(k, DOM), a)
    ts.close()


def test_locality_reporting_tracks_movement():
    ts = _mem_stack(capacity_tiles=1, write_policy="write_through")
    k1, k2 = _key("a"), _key("b")
    ts.put(k1, DOM, _arr(1))
    assert ts.locality(k1) == "MEM"
    ts.put(k2, DOM, _arr(2))  # evicts k1 from the 1-tile MEM budget
    assert ts.locality(k2) == "MEM"
    assert ts.locality(k1) in ("DISK", "DMS")
    assert ts.locality(_key("missing")) is None
    ts.close()


def test_placement_pin_and_size_threshold():
    policy = PlacementPolicy(
        [
            pin_namespace("hot", "MEM"),
            size_threshold(TILE_BYTES // 2, "DMS"),
        ]
    )
    ts = _mem_stack(capacity_tiles=1, policy=policy)
    hot, big = _key("h", ns="hot"), _key("big")
    ts.put(hot, DOM, _arr(0))
    ts.put(big, DOM, _arr(1))  # > threshold: bypasses MEM straight to DMS
    assert ts.locality(big) == "DMS"
    # pinned region is never evicted from MEM even over budget
    ts.put(_key("h2", ns="hot"), DOM, _arr(2))
    assert ts.locality(hot) == "MEM"
    ts.close()


def test_lazy_drain_pushes_down_to_bottom():
    ts = _mem_stack(write_policy="lazy")
    k, a = _key("lz"), _arr()
    ts.put(k, DOM, a)
    assert ts.dirty(k)  # resident in MEM only
    with pytest.raises(KeyError):
        ts.tiers[-1].backend.get(k, DOM)
    ts.drain()
    assert not ts.dirty(k)
    np.testing.assert_array_equal(ts.tiers[-1].backend.get(k, DOM), a)
    ts.close()


def test_roi_granularity_spill():
    policy = PlacementPolicy(spill_block=(64, 64))
    ts = _mem_stack(capacity_tiles=1, policy=policy, write_policy="lazy")
    k1, k2 = _key("s1"), _key("s2")
    a1 = _arr(1)
    ts.put(k1, DOM, a1)
    ts.put(k2, DOM, _arr(2))  # k1 spills to DISK in 4 (64, 64) blocks
    disk = ts.tiers[1].backend
    found = dict(disk.query("t", "s1"))
    assert found[k1] == DOM  # union of the spill tiles covers the domain
    assert len(disk._chunks[k1]) == 4
    roi = BoundingBox((0, 0), (64, 64))
    np.testing.assert_array_equal(ts.get(k1, roi), a1[:64, :64])
    ts.close()


def test_scheduler_transfer_impact_refinement():
    ts = _mem_stack(capacity_tiles=1)
    mem_key, far_key = _key("near"), _key("far")
    ts.put(mem_key, DOM, _arr(0))
    ts.tiers[-1].backend.put(far_key, DOM, _arr(1))
    cfg = SchedulerConfig(
        data_locality=True,
        transfer_impact=0.3,
        locality_fn=ts.locality,
        tier_bandwidth={"MEM": 2e10, "DISK": 1.2e9, "DMS": 6e9},
    )
    cost = TaskCost(cpu_s=1e-3, speedup=2.0, input_bytes=TILE_BYTES)
    near = Task("near", cpu_fn=lambda: None, cost=cost, region_key=mem_key)
    far = Task("far", cpu_fn=lambda: None, cost=cost, region_key=far_key)
    unknown = Task("unknown", cpu_fn=lambda: None, cost=cost)
    # memory-resident input -> near-zero impact; DMS-resident -> larger
    assert cfg.transfer_impact_for(near) < 0.05
    assert cfg.transfer_impact_for(far) > cfg.transfer_impact_for(near)
    # no locality info -> the paper's flat user-provided impact
    assert cfg.transfer_impact_for(unknown) == pytest.approx(0.3)
    assert SchedulerConfig().transfer_impact_for(near) == pytest.approx(0.2)
    ts.close()


def test_query_unions_across_tiers():
    ts = _mem_stack(capacity_tiles=1)
    k1, k2 = _key("q", ns="qq"), _key("q2", ns="qq")
    ts.put(k1, DOM, _arr(1))
    ts.put(k2, DOM, _arr(2))  # k1 demoted out of MEM
    assert dict(ts.query("qq", "q"))[k1] == DOM
    assert dict(ts.query("qq", "q2"))[k2] == DOM
    ts.close()


def test_delete_removes_from_all_tiers():
    ts = _mem_stack(capacity_tiles=1)
    k = _key("d")
    ts.put(k, DOM, _arr())
    ts.put(_key("d2"), DOM, _arr(2))  # push k down
    ts.delete(k)
    assert ts.locality(k) is None
    with pytest.raises(KeyError):
        ts.get(k, DOM)
    ts.close()


def test_overwrite_survives_demotion_with_stale_lower_copy():
    """A lazy overwrite in MEM must be spilled (not dropped) on eviction
    even though a lower tier still holds the previous generation."""
    ts = _mem_stack(capacity_tiles=1, write_policy="lazy")
    k1, k2, k3 = _key("v"), _key("f1"), _key("f2")
    v1, v2 = _arr(1), _arr(2)
    ts.put(k1, DOM, v1)
    ts.put(k2, DOM, _arr(3))  # evict k1 -> spilled to DISK (gen 1)
    ts.get(k1, DOM)
    ts.get(k1, DOM)  # promote k1 back to MEM (DISK keeps the gen-1 copy)
    ts.put(k1, DOM, v2)  # lazy overwrite: MEM gen 2, DISK still gen 1
    ts.put(k3, DOM, _arr(4))  # evict k1 again — must spill v2, not drop
    np.testing.assert_array_equal(ts.get(k1, DOM), v2)
    ts.drain()  # checkpoint must also carry the new generation
    np.testing.assert_array_equal(ts.tiers[-1].backend.get(k1, DOM), v2)
    ts.close()


def test_cross_tier_roi_assembly():
    """Placement can split one key's chunks across tiers; a spanning ROI
    must still assemble (the flat backends honor this contract)."""
    from repro.storage import size_threshold

    threshold = 32 * 128 * 4  # the small chunk's exact size
    policy = PlacementPolicy([size_threshold(threshold, "DMS")])
    ts = _mem_stack(policy=policy, write_policy="lazy")
    k = _key("split")
    top = BoundingBox((0, 0), (32, 128))
    bottom = BoundingBox((32, 0), (128, 128))
    small = _arr(1)[:32]  # == threshold -> stays in MEM
    big = _arr(2)[:96]  # > threshold -> routed to DMS
    ts.put(k, top, small)
    ts.put(k, bottom, big)
    got = ts.get(k, DOM)  # spans both tiers
    np.testing.assert_array_equal(got[:32], small)
    np.testing.assert_array_equal(got[32:], big)
    ts.close()


def test_fresh_overwrite_wins_over_stale_faster_tier():
    """A fresh overwrite routed to a slower tier must win over stale
    chunks lingering in a faster tier, and locality must report the
    serving tier."""
    policy = PlacementPolicy([size_threshold(64 * 128 * 4, "DISK")])
    ts = _mem_stack(policy=policy, write_policy="lazy")
    k = _key("ow")
    top = BoundingBox((0, 0), (64, 128))
    bottom = BoundingBox((64, 0), (128, 128))
    ts.put(k, top, np.full((64, 128), 1.0, np.float32))  # gen1 -> MEM
    ts.put(k, bottom, np.full((64, 128), 2.0, np.float32))  # gen2 -> MEM
    ts.put(k, DOM, np.full((128, 128), 9.0, np.float32))  # gen3 -> DISK
    assert (ts.get(k, DOM) == 9.0).all()
    assert ts.locality(k) == "DISK"
    ts.close()


def test_placement_write_policy_validated():
    from repro.storage import Placement

    with pytest.raises(ValueError):
        Placement(write_policy="writeback")  # typo must fail loudly
    Placement(write_policy="write_back")  # valid values pass


def test_delete_then_reput_does_not_lose_new_data():
    """Generations stay monotonic across delete/re-put, so a late flush
    of the old incarnation can never shadow the new one."""
    ts = _mem_stack(write_policy="write_back")
    k = _key("re")
    v1, v2 = _arr(1), _arr(2)
    ts.put(k, DOM, v1)
    ts.delete(k)
    ts.put(k, DOM, v2)
    ts.drain()
    np.testing.assert_array_equal(ts.get(k, DOM), v2)
    np.testing.assert_array_equal(ts.tiers[-1].backend.get(k, DOM), v2)
    ts.close()


def test_standard_stack_opt_in_auto_repair(tmp_path):
    """TieredStore.standard(repair_interval=...) runs the DMS tier's
    anti-entropy sweep in the background; close() stops it."""
    store = TieredStore.standard(
        BoundingBox((0, 0), (64, 64)),
        (16, 16),
        root=str(tmp_path),
        num_servers=4,
        replication=2,
        repair_interval=0.05,
    )
    dms = store.tiers[2].backend
    assert dms._repair_thread is not None and dms._repair_thread.is_alive()
    key = RegionKey("t", "heal", ElementType.FLOAT32)
    arr = np.random.default_rng(9).random((64, 64)).astype(np.float32)
    dms.put(key, BoundingBox((0, 0), (64, 64)), arr)
    shard = dms.transport.servers[1]
    shard._blocks.clear()
    shard._meta.clear()
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline and dms.stats.repaired_blocks == 0:
        time.sleep(0.02)
    assert dms.stats.repaired_blocks > 0  # healed without an explicit call
    store.close()
    assert dms._repair_thread is None


def test_wsi_pipeline_runs_unmodified_on_tiered_storage(tmp_path):
    """Acceptance: the RT two-stage pipeline runs against TieredStore
    registered under the same names, with zero call-site changes."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.configs.wsi import WSIConfig
    from repro.core import Intent, RegionTemplate
    from repro.pipeline import (
        FeatureStage,
        SegmentationStage,
        analyze_tile,
        make_tile,
        make_wsi_storage,
    )
    from repro.runtime import SysEnv

    rgb, _ = make_tile(96, num_nuclei=6, seed=5)
    h, w = rgb.shape[1:]
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=16)
    plain = analyze_tile(jnp.asarray(rgb), cfg, impl="xla")

    reg = make_wsi_storage(h, w, mode="tiered", num_servers=1, root=str(tmp_path))
    dom3 = BoundingBox((0, 0, 0), (3, h, w))
    dom2 = BoundingBox((0, 0), (h, w))
    rt = RegionTemplate("Patient")
    rgb_region = rt.new_region("RGB", dom3, np.float32, input_storage="DMS3", lazy=True)
    reg.get("DMS3").put(rgb_region.key, dom3, np.asarray(rgb))

    env = SysEnv(num_workers=1, cpus_per_worker=2, accels_per_worker=1, registry=reg)
    seg = SegmentationStage(cfg, impl="xla")
    seg.add_region_template(rt, "RGB", dom3, Intent.INPUT, read_storage="DMS3")
    seg.add_region_template(rt, "Mask", dom2, Intent.OUTPUT, storage="DMS2")
    seg.add_region_template(rt, "Hema", dom2, Intent.OUTPUT, storage="DMS2")
    feat = FeatureStage(cfg, impl="xla")
    feat.add_region_template(rt, "Mask", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_region_template(rt, "Hema", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_dependency(seg)
    env.execute_component(seg)
    env.execute_component(feat)
    env.startup_execution()
    env.finalize_system()

    mask_key = seg.templates["Patient"].get("Mask").key
    got_mask = reg.get("DMS2").get(mask_key, dom2)
    np.testing.assert_array_equal(got_mask, np.asarray(plain["labels"]))
    got = feat.templates["Patient"].get("Features").data
    np.testing.assert_allclose(got["features"], plain["features"], rtol=1e-4, atol=1e-4)

    # the hierarchy actually absorbed the traffic + locality events flowed
    stats = reg.get("DMS2").tier_stats()
    assert stats["MEM"].puts > 0
    assert any(ev == "locality" for ev, _ in env.manager.events)
    for backend_name in ("DMS3", "DMS2"):
        reg.get(backend_name).close()
