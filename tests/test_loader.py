"""RT-backed data loader: parity with source + region retirement."""
import numpy as np

from repro.core import BoundingBox
from repro.data import RegionTemplateLoader, SyntheticTokens
from repro.storage import DistributedMemoryStorage


def test_loader_batches_match_source():
    src = SyntheticTokens(64, 16, 4, seed=1, num_steps=6)
    dms = DistributedMemoryStorage(
        BoundingBox((0, 0), (4, 16)), (4, 16), 2, name="DATA"
    )
    loader = RegionTemplateLoader(src, dms, device_prefetch=2)
    got = []
    for i, batch in enumerate(loader):
        got.append(batch)
        if i == 5:
            break
    loader.close()
    for i, b in enumerate(got):
        want = SyntheticTokens(64, 16, 4, seed=1).batch_at(i)
        np.testing.assert_array_equal(np.asarray(b["tokens"]), want["tokens"])
        np.testing.assert_array_equal(np.asarray(b["labels"]), want["labels"])
    # consumed regions retired from the store
    assert dms.query("data", "tokens") == []


def test_synthetic_tokens_deterministic_and_learnable():
    a = SyntheticTokens(128, 32, 2, seed=7).batch_at(3)
    b = SyntheticTokens(128, 32, 2, seed=7).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert (a["labels"][:, -1] == -1).all()
    # markov structure: each token has at most `branching` successors
    src = SyntheticTokens(32, 256, 1, seed=0, branching=4)
    toks = src.batch_at(0)["tokens"][0]
    succ = {}
    for t in range(len(toks) - 1):
        succ.setdefault(int(toks[t]), set()).add(int(toks[t + 1]))
    assert max(len(v) for v in succ.values()) <= 4
