"""Region-serving gateway: bit-exactness under concurrency, coalescing
(asserted via transport frame counts), TierStats admission control,
clean shutdown, and the make_wsi_storage(serve=...) wiring."""
import threading
import time

import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.serve.gateway import (
    GatewayClosed,
    GatewayConfig,
    Overloaded,
    RegionGateway,
)
from repro.storage import (
    DistributedMemoryStorage,
    MemoryTier,
    Tier,
    TieredStore,
    TransportError,
)

DOM = BoundingBox((0, 0), (128, 128))
TILE = 32
TILE_BYTES = TILE * TILE * 4


def _key(name="Slide", ts=0):
    return RegionKey("g", name, ElementType.FLOAT32, ts)


def _dms_store() -> tuple[TieredStore, np.ndarray]:
    """Single DMS tier (every read pays the transport) + a staged slide."""
    dms = DistributedMemoryStorage(DOM, (TILE, TILE), 4)
    store = TieredStore([Tier("DMS", dms)], name="GWT")
    slide = np.random.default_rng(0).random((128, 128)).astype(np.float32)
    for tile in DOM.tiles((TILE, TILE)):
        store.put(_key(), tile, slide[tile.slices()])
    return store, slide


def test_concurrent_clients_bit_exact_vs_direct_reads():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=3))
    rois = [
        BoundingBox((y, x), (min(y + 48, 128), min(x + 48, 128)))
        for y in range(0, 112, 16)
        for x in range(0, 112, 16)
    ]
    errors = []

    def client(sub):
        try:
            for roi in sub:
                got = gw.get(_key(), roi)
                want = store.get(_key(), roi)  # direct, bypassing the gateway
                np.testing.assert_array_equal(got, want)
                np.testing.assert_array_equal(got, slide[roi.slices()])
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(rois[i::6],)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert gw.stats.served == gw.stats.requests > 0
    gw.close()


def test_coalescing_merges_overlapping_rois_fewer_transport_frames():
    store, slide = _dms_store()
    transport = store.tiers[0].backend.transport
    # an overlapping horizontal band: 7 reads, stride 16, window 32
    rois = [BoundingBox((0, x), (32, x + 32)) for x in range(0, 97, 16)]

    transport.reset()
    naive = [store.get(_key(), roi) for roi in rois]
    naive_frames = transport.stats.gets + transport.stats.meta_msgs

    gw = RegionGateway(store, config=GatewayConfig(workers=2, batch_window=16))
    gw.pause()  # queue the whole burst so one drain serves it
    tickets = [gw.submit(_key(), roi) for roi in rois]
    transport.reset()
    gw.resume()
    outs = [t.result(30.0) for t in tickets]
    gw_frames = transport.stats.gets + transport.stats.meta_msgs

    for roi, out, base in zip(rois, outs, naive):
        np.testing.assert_array_equal(out, base)
        np.testing.assert_array_equal(out, slide[roi.slices()])
    # the band merges into one window -> one store read instead of seven
    assert gw_frames < naive_frames, (gw_frames, naive_frames)
    assert gw.stats.windows < len(rois)
    assert gw.stats.coalesced >= len(rois)
    assert gw.stats.window_fallbacks == 0
    gw.close()


def test_duplicate_rois_dedup_into_one_window():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((16, 16), (64, 64))
    gw.pause()
    tickets = [gw.submit(_key(), roi) for _ in range(5)]
    gw.resume()
    outs = [t.result(30.0) for t in tickets]
    for out in outs:
        np.testing.assert_array_equal(out, slide[roi.slices()])
    # callers never alias the shared window payload (or each other)
    assert not any(np.shares_memory(a, b) for a in outs for b in outs if a is not b)
    assert gw.stats.windows == 1 and gw.stats.coalesced == 5
    gw.close()


def test_cancelled_ticket_does_not_poison_the_batch():
    """A client cancelling its queued ticket must not fail other
    requests drained into the same batch."""
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    far_a = BoundingBox((0, 0), (16, 16))
    far_b = BoundingBox((96, 96), (128, 128))  # too far to coalesce
    gw.pause()
    doomed = gw.submit(_key(), far_a)
    kept = gw.submit(_key(), far_b)
    assert doomed.cancel()
    gw.resume()
    np.testing.assert_array_equal(kept.result(30.0), slide[far_b.slices()])
    assert gw.stats.served == 1
    gw.close()


def test_duplicate_rois_do_not_inflate_the_waste_budget():
    """The waste bound counts distinct requested cells: duplicated ROIs
    must not let diagonally-touching windows merge into one oversized
    (and hole-doomed) fetch."""
    store, slide = _dms_store()
    a = BoundingBox((0, 0), (32, 32))
    b = BoundingBox((32, 32), (64, 128))  # touches a only at one corner
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    gw.pause()
    tickets = [gw.submit(_key(), a) for _ in range(4)] + [gw.submit(_key(), b)]
    gw.resume()
    for t in tickets:
        np.testing.assert_array_equal(t.result(30.0), slide[t.roi.slices()])
    # one window for the 4 duplicates of a, one for b — never a merged
    # (0,0)-(64,128) window that is 2x the requested cells
    assert gw.stats.windows == 2
    assert gw.stats.window_fallbacks == 0
    gw.close()


def test_window_hole_falls_back_to_per_request_reads():
    """Two touching ROIs merge into a window whose corners were never
    written; the window fetch fails with KeyError and the gateway must
    degrade to per-request reads, still bit-exact."""
    dms = DistributedMemoryStorage(DOM, (TILE, TILE), 4)
    store = TieredStore([Tier("DMS", dms)], name="HOLE")
    rng = np.random.default_rng(1)
    a_box = BoundingBox((0, 0), (32, 32))
    b_box = BoundingBox((32, 16), (64, 48))
    a = rng.random((32, 32)).astype(np.float32)
    b = rng.random((32, 32)).astype(np.float32)
    store.put(_key("holey"), a_box, a)
    store.put(_key("holey"), b_box, b)

    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    gw.pause()
    ta = gw.submit(_key("holey"), a_box)
    tb = gw.submit(_key("holey"), b_box)
    gw.resume()
    np.testing.assert_array_equal(ta.result(30.0), a)
    np.testing.assert_array_equal(tb.result(30.0), b)
    assert gw.stats.window_fallbacks == 1
    assert gw.stats.served == 2
    gw.close()


def test_timed_out_get_abandons_ticket():
    """A get() that times out must cancel its ticket — the worker then
    skips it instead of fetching a window for a caller that gave up and
    counting the orphan as served."""
    store, _ = _dms_store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, request_timeout=0.15)
    )
    gw.pause()  # the ticket stays queued past the request timeout
    with pytest.raises(TimeoutError):
        gw.get(_key(), BoundingBox((0, 0), (TILE, TILE)))
    assert gw.stats.abandoned == 1
    gw.resume()
    deadline = time.monotonic() + 10.0
    while gw.queue_depth() > 0 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert gw.queue_depth() == 0
    assert gw.stats.served == 0  # the abandoned ticket was never "served"
    gw.close()


class _PartialOutageStore:
    """StorageBackend where only one ROI survives a transport outage —
    the shape of a TieredStore whose RAM tier still holds some members
    while the DMS tier is down (gateway TransportError-path fixture)."""

    name = "OUTAGESTORE"

    def __init__(self, alive_roi, payload) -> None:
        self.alive_roi = alive_roi
        self.payload = payload
        self.gets = 0

    def get(self, key, roi):
        self.gets += 1
        if roi == self.alive_roi:
            return self.payload.copy()  # e.g. served from the RAM tier
        raise TransportError("every replica down")

    def put(self, key, bb, array) -> None:
        pass

    def query(self, namespace, name):
        return []

    def delete(self, key) -> None:
        pass


def test_window_transport_error_degrades_per_request():
    """A TransportError on the merged window is an infrastructure
    failure (counted under window_failures, not window_fallbacks) but
    still degrades to per-request reads: a member whose ROI an upper
    tier can serve succeeds, the others fail with the real error."""
    a = BoundingBox((0, 0), (32, 32))
    b = BoundingBox((0, 16), (32, 48))  # overlaps a -> one merged window
    alive = np.full((32, 32), 3.0, np.float32)
    store = _PartialOutageStore(a, alive)
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    gw.pause()
    ta = gw.submit(_key(), a)
    tb = gw.submit(_key(), b)
    gw.resume()
    np.testing.assert_array_equal(ta.result(30.0), alive)  # survived outage
    with pytest.raises(TransportError, match="every replica down"):
        tb.result(30.0)
    assert store.gets == 3  # 1 failed window + 2 per-request reads
    assert gw.stats.window_failures == 1
    assert gw.stats.window_fallbacks == 0
    assert gw.stats.served == 1 and gw.stats.failed == 1
    gw.close(close_store=False)


def test_admission_rejects_under_tiny_ram_tier_pressure():
    """A full bounded RAM tier shrinks the admission queue and turns the
    bounded wait into immediate load shedding."""
    dms = DistributedMemoryStorage(DOM, (TILE, TILE), 4)
    store = TieredStore(
        [Tier("MEM", MemoryTier(), TILE_BYTES), Tier("DMS", dms)],
        name="TINY",
    )
    tile0 = BoundingBox((0, 0), (TILE, TILE))
    payload = np.ones((TILE, TILE), np.float32)
    store.put(_key("hot"), tile0, payload)  # MEM now exactly at capacity
    gw = RegionGateway(
        store,
        config=GatewayConfig(
            workers=1, max_queue=8, shed_queue_factor=0.25, admit_timeout=10.0
        ),
    )
    assert gw.pressure() == pytest.approx(1.0)
    gw.pause()
    admitted = [gw.submit(_key("hot"), tile0) for _ in range(2)]  # 8 * 0.25
    t0 = time.monotonic()
    with pytest.raises(Overloaded, match="shedding"):
        gw.submit(_key("hot"), tile0)
    # shedding is immediate, not a 10s bounded wait (never deadlocks)
    assert time.monotonic() - t0 < 2.0
    assert gw.stats.rejected == 1
    gw.resume()
    for t in admitted:
        np.testing.assert_array_equal(t.result(30.0), payload)
    gw.close()


def test_admission_bounded_wait_then_rejects_without_pressure():
    store, _ = _dms_store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, max_queue=2, admit_timeout=0.2)
    )
    assert gw.pressure() == 0.0  # single unbounded tier: no RAM signal
    gw.pause()
    roi = BoundingBox((0, 0), (TILE, TILE))
    admitted = [gw.submit(_key(), roi) for _ in range(2)]
    t0 = time.monotonic()
    with pytest.raises(Overloaded, match="bounded wait"):
        gw.submit(_key(), roi)
    waited = time.monotonic() - t0
    assert 0.15 <= waited < 5.0  # waited for the slot, then shed
    gw.resume()
    for t in admitted:
        assert t.result(30.0) is not None
    gw.close()


def test_clean_shutdown_completes_inflight_requests():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    roi = BoundingBox((0, 0), (64, 64))
    gw.pause()  # pile up in-flight work, then close while it is queued
    tickets = [gw.submit(_key(), roi) for _ in range(6)]
    closer = threading.Thread(target=gw.close)
    closer.start()
    for t in tickets:
        np.testing.assert_array_equal(t.result(30.0), slide[roi.slices()])
    closer.join(timeout=30)
    assert not closer.is_alive()
    with pytest.raises(GatewayClosed):
        gw.submit(_key(), roi)
    assert gw.stats.served == 6


def test_gateway_is_a_transparent_storage_backend():
    """StorageBackend protocol + delegation: the gateway registers under
    the store's name and passes writes/queries/locality through."""
    store, _ = _dms_store()
    gw = RegionGateway(store)
    assert gw.name == store.name
    key = _key("w")
    bb = BoundingBox((0, 0), (TILE, TILE))
    arr = np.full((TILE, TILE), 7.0, np.float32)
    gw.put(key, bb, arr)
    assert [k for k, _ in gw.query("g", "w")] == [key]
    np.testing.assert_array_equal(gw.get(key, bb), arr)
    assert gw.locality(key) == "DMS"  # delegated to the TieredStore
    assert "DMS" in gw.tier_stats()
    gw.delete(key)
    assert gw.query("g", "w") == []
    gw.close()


def test_storage_stats_surfaces_dms_availability_counters():
    """Operators polling the gateway see the replica failover / repair
    activity of the DMS tier below it in one structured view."""
    store, slide = _dms_store()
    gw = RegionGateway(store)
    roi = BoundingBox((0, 0), (TILE, TILE))
    np.testing.assert_array_equal(gw.get(_key(), roi), slide[roi.slices()])
    stats = gw.storage_stats()
    assert stats["gateway"]["served"] >= 1
    assert "DMS" in stats["tiers"]
    dms_entry = stats["dms"]["DMS"]
    assert set(dms_entry["dms"]) >= {
        "failover_fetches",
        "balanced_fetches",
        "put_failovers",
        "put_rollbacks",
        "repaired_blocks",
    }
    assert dms_entry["transport"]["bytes_get"] > 0
    # the sweep itself is reachable through the facade too
    report = gw.store.tiers[0].backend.repair()
    assert report["lost"] == 0
    gw.close()


def test_custom_pressure_fn_overrides_tier_accounting():
    store, _ = _dms_store()
    level = {"p": 0.0}
    gw = RegionGateway(
        store,
        config=GatewayConfig(workers=1, max_queue=4, shed_queue_factor=0.25),
        pressure_fn=lambda: level["p"],
    )
    gw.pause()
    roi = BoundingBox((0, 0), (TILE, TILE))
    gw.submit(_key(), roi)
    level["p"] = 1.0  # external signal: shed everything beyond 1 slot
    with pytest.raises(Overloaded):
        gw.submit(_key(), roi)
    level["p"] = 0.0
    gw.resume()
    gw.close()


def test_make_wsi_storage_serve_wraps_stores_in_gateways():
    from repro.pipeline import make_wsi_storage

    reg = make_wsi_storage(64, 64, mode="tiered", serve=True, tile=32)
    gw3 = reg.get("DMS3")
    assert isinstance(gw3, RegionGateway)
    assert gw3.name == "DMS3"
    key = RegionKey("t", "RGB", ElementType.FLOAT32)
    dom3 = BoundingBox((0, 0, 0), (3, 64, 64))
    rgb = np.random.default_rng(2).random((3, 64, 64)).astype(np.float32)
    gw3.put(key, dom3, rgb)
    np.testing.assert_array_equal(gw3.get(key, dom3), rgb)
    gw3.drain()  # delegated through to the tiered store
    assert not gw3.dirty(key)
    # a custom config rides through serve=
    reg2 = make_wsi_storage(
        64, 64, mode="tiered", serve=GatewayConfig(workers=1, max_queue=3), tile=32
    )
    assert reg2.get("DMS2").config.max_queue == 3
    for r in (reg, reg2):
        for name in ("DMS3", "DMS2"):
            r.get(name).close()  # closes gateway AND the tiered store


def test_stats_snapshot_is_atomic_under_hammer():
    """as_dict() must snapshot all counters under the stats lock: with
    writers always bumping (requests, served) together via add(), every
    snapshot a reader takes must show the two counters equal — a torn
    read (pre-lock as_dict built the dict field by field) shows skew."""
    from repro.serve.gateway import GatewayStats

    stats = GatewayStats()
    rounds, writers = 2000, 4
    stop = threading.Event()
    skews = []

    def writer():
        for _ in range(rounds):
            stats.add(requests=1, served=1)
            stats.peak("queue_peak", stats.as_dict()["requests"] % 97)

    def reader():
        while not stop.is_set():
            snap = stats.as_dict()
            if snap["requests"] != snap["served"]:
                skews.append(snap)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    threads = [threading.Thread(target=writer) for _ in range(writers)]
    for t in readers + threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    stop.set()
    for t in readers:
        t.join(timeout=10)
    assert not skews, skews[:3]
    final = stats.as_dict()
    assert final["requests"] == final["served"] == rounds * writers
    with pytest.raises(AttributeError):
        stats.add(not_a_counter=1)  # typo'd counter names must not pass silently
