"""High-performance disk storage tests (transports x io modes x groups)."""
import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DiskStorage

DOM = BoundingBox((0, 0), (32, 32))


def _key(name="R", ts=0):
    return RegionKey("t", name, ElementType.FLOAT32, ts)


@pytest.mark.parametrize("transport", ["posix", "aggregated"])
@pytest.mark.parametrize("io_mode,workers", [("colocated", 0), ("separated", 3)])
@pytest.mark.parametrize("group", [1, 2])
def test_roundtrip_all_configs(tmp_path, transport, io_mode, workers, group):
    store = DiskStorage(
        str(tmp_path),
        transport=transport,
        io_mode=io_mode,
        num_io_workers=workers,
        io_group_size=group,
        queue_threshold=2,
    )
    arr = np.random.default_rng(0).random((32, 32), dtype=np.float32)
    for tile in DOM.tiles((16, 16)):
        store.put(_key(), tile, arr[tile.slices()])
    store.flush()
    got = store.get(_key(), DOM)
    assert np.array_equal(got, arr)
    roi = BoundingBox((5, 7), (25, 31))
    assert np.array_equal(store.get(_key(), roi), arr[roi.slices()])


def test_manifest_reopen(tmp_path):
    store = DiskStorage(str(tmp_path), transport="aggregated", queue_threshold=3)
    arr = np.arange(1024, dtype=np.float32).reshape(32, 32)
    store.put(_key(), DOM, arr)
    store.flush()
    # a fresh process sees the data (crash-recovery path)
    store2 = DiskStorage(str(tmp_path))
    assert np.array_equal(store2.get(_key(), DOM), arr)
    assert store2.keys() == [_key()]


def test_aggregated_fewer_files(tmp_path):
    agg = DiskStorage(str(tmp_path / "agg"), transport="aggregated", queue_threshold=4)
    pos = DiskStorage(str(tmp_path / "pos"), transport="posix")
    arr = np.ones((8, 8), np.float32)
    for i in range(8):
        box = BoundingBox((0, i * 8), (8, (i + 1) * 8))
        agg.put(_key(), box, arr)
        pos.put(_key(), box, arr)
    agg.flush()
    assert agg.stats.files_written < pos.stats.files_written
    assert agg.stats.chunks_written == pos.stats.chunks_written == 8


def test_group_size_reduces_sync_cost(tmp_path):
    """The paper's core disk claim: small I/O groups cut synchronization."""
    def run(group):
        s = DiskStorage(
            str(tmp_path / f"g{group}"), transport="aggregated",
            io_mode="separated", num_io_workers=8, io_group_size=group,
            queue_threshold=2,
        )
        arr = np.ones((8, 8), np.float32)
        for i in range(32):
            s.put(_key(ts=i), BoundingBox((0, 0), (8, 8)), arr)
        s.flush()
        return s.stats

    small = run(1)
    big = run(8)
    assert small.virtual_sync_s < big.virtual_sync_s
    assert small.bytes_written == big.bytes_written


def test_delete_hides_key(tmp_path):
    store = DiskStorage(str(tmp_path))
    store.put(_key(), DOM, np.zeros((32, 32), np.float32))
    store.delete(_key())
    with pytest.raises(KeyError):
        store.get(_key(), DOM)


def test_bad_config_rejected(tmp_path):
    with pytest.raises(ValueError):
        DiskStorage(str(tmp_path), transport="carrier-pigeon")
    with pytest.raises(ValueError):
        DiskStorage(str(tmp_path), io_mode="telepathy")
