"""Cell builder: sharding trees match argument trees (structure checks on
a 1-device host mesh — no compilation, catches drift between models,
caches and sharding derivation)."""
import jax
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config
from repro.launch.cells import build_cell
from repro.launch.mesh import make_host_mesh


def _tree_shapes_match(args, shardings):
    la = jax.tree_util.tree_structure(args)
    ls = jax.tree_util.tree_structure(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding) or x is None
    )
    return la == ls or len(jax.tree_util.tree_leaves(args)) == len(
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_cell_builds_and_shardings_align(arch, shape):
    ok, _ = cell_supported(get_config(arch), shape)
    if not ok:
        pytest.skip("documented arch x shape skip")
    mesh = make_host_mesh(1, 1)
    cell = build_cell(arch, shape, mesh)
    assert len(cell.args) == len(cell.in_shardings)
    for arg, sh in zip(cell.args, cell.in_shardings):
        assert _tree_shapes_match(arg, sh), f"{arch}/{shape}: sharding tree mismatch"
    assert cell.meta["tokens"] > 0
    # abstract inputs only — nothing allocated
    leaves = jax.tree_util.tree_leaves(cell.args)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_unsupported_cell_raises():
    mesh = make_host_mesh(1, 1)
    with pytest.raises(ValueError, match="unsupported"):
        build_cell("gemma-2b", "long_500k", mesh)


def test_decode_cell_shapes_match_spec():
    mesh = make_host_mesh(1, 1)
    cell = build_cell("qwen3-0.6b", "decode_32k", mesh)
    params, tokens, cache, pos = cell.args
    spec = SHAPES["decode_32k"]
    assert tokens.shape == (spec.global_batch, 1)
    assert cache["layers"]["k"].shape[3] == spec.seq_len
    assert pos.shape == ()


def test_train_cell_batch_matches_spec():
    mesh = make_host_mesh(1, 1)
    cell = build_cell("internvl2-1b", "train_4k", mesh)
    state, batch = cell.args
    spec = SHAPES["train_4k"]
    cfg = cell.cfg
    assert batch["tokens"].shape == (spec.global_batch, spec.seq_len - cfg.frontend_len)
    assert batch["prefix"].shape == (spec.global_batch, cfg.frontend_len, cfg.d_model)
