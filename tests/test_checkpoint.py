"""Checkpoint engine: roundtrip, async, retention, commit protocol, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import CheckpointManager, DiskStorage


def _tree():
    return {
        "params": {"w": jnp.arange(24.0).reshape(4, 6), "b": jnp.ones((6,))},
        "opt": [jnp.zeros((2, 3)), jnp.asarray(7)],
        "step": jnp.asarray(42),
    }


def _target(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), tree
    )


def test_roundtrip(tmp_path):
    ck = CheckpointManager(DiskStorage(str(tmp_path)), keep=3)
    tree = _tree()
    ck.save(10, tree)
    out = ck.restore(_target(tree), 10)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.allclose(np.asarray(a, np.float64), np.asarray(b, np.float64))


def test_async_save_and_wait(tmp_path):
    ck = CheckpointManager(DiskStorage(str(tmp_path)), keep=3)
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.steps() == [1]


def test_retention_gc(tmp_path):
    ck = CheckpointManager(DiskStorage(str(tmp_path)), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    assert ck.steps() == [3, 4]
    with pytest.raises(FileNotFoundError):
        ck.restore(_target(_tree()), 1)


def test_uncommitted_invisible(tmp_path):
    store = DiskStorage(str(tmp_path))
    ck = CheckpointManager(store, keep=3)
    tree = _tree()
    # write leaves WITHOUT commit (simulates a crash mid-save)
    key = RegionKey("ckpt", "params/w", ElementType.FLOAT32, timestamp=9)
    store.put(key, BoundingBox.from_shape((4, 6)), np.zeros((4, 6), np.float32))
    assert ck.steps() == []
    with pytest.raises(FileNotFoundError):
        ck.restore(_target(tree))
    ck.save(10, tree)
    assert ck.latest_step() == 10


def test_restart_new_process_view(tmp_path):
    ck = CheckpointManager(DiskStorage(str(tmp_path)), keep=3)
    ck.save(5, _tree())
    # fresh manager over a fresh store handle = restarted job
    ck2 = CheckpointManager(DiskStorage(str(tmp_path)), keep=3)
    assert ck2.latest_step() == 5
    out = ck2.restore(_target(_tree()))
    assert np.allclose(np.asarray(out["params"]["w"]), np.arange(24.0).reshape(4, 6))


def test_elastic_restore_from_chunked_shards(tmp_path):
    """Shards written as separate bounding-box chunks reassemble for a
    different target partitioning (elastic re-mesh on restore)."""
    store = DiskStorage(str(tmp_path))
    ck = CheckpointManager(store, keep=3)
    full = np.arange(64, dtype=np.float32).reshape(8, 8)
    # simulate a 2-shard save (row-split), as a 2-device mesh would produce
    key = RegionKey("ckpt", "w", ElementType.FLOAT32, timestamp=1)
    store.put(key, BoundingBox((0, 0), (4, 8)), full[:4])
    store.put(key, BoundingBox((4, 0), (8, 8)), full[4:])
    store.put(
        RegionKey("ckpt", "__ckpt_commit__", ElementType.INT64, timestamp=1),
        BoundingBox((0,), (1,)),
        np.asarray([1]),
    )
    # restore onto a "different mesh": single-device target, and a
    # column-ROI read (what a 2-way model-sharded restore would issue)
    out = ck.restore({"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}, 1)
    assert np.array_equal(np.asarray(out["w"]), full)
    col = store.get(key, BoundingBox((0, 4), (8, 8)))
    assert np.array_equal(col, full[:, 4:])


def test_sharded_jax_array_roundtrip(tmp_path):
    ck = CheckpointManager(DiskStorage(str(tmp_path)), keep=3)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    arr = jax.device_put(jnp.arange(16.0), sh)
    ck.save(2, {"a": arr})
    out = ck.restore({"a": jax.ShapeDtypeStruct((16,), jnp.float32, sharding=sh)}, 2)
    assert isinstance(out["a"], jax.Array)
    assert np.array_equal(np.asarray(out["a"]), np.arange(16.0))
