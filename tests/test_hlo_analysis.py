"""Multiplicity-aware HLO analyzer: scan trip counts, slice accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_exact():
    D, L, B = 64, 8, 32

    def layer(x, w):
        return jnp.tanh(x @ w), None

    def net(x, ws):
        y, _ = jax.lax.scan(layer, x, ws)
        return y

    comp = _compile(
        net,
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    cost = H.analyze(comp.as_text())
    assert cost.flops == pytest.approx(L * 2 * B * D * D, rel=0.01)
    assert L in cost.while_trip_counts
    # XLA's own analysis counts the body once — ours must exceed it
    xla_flops = H.xla_cost_analysis(comp)["flops"]
    assert cost.flops > 2 * xla_flops


def test_unrolled_matches_scan_totals():
    D, L, B = 32, 4, 16

    def layer(x, w):
        return jnp.tanh(x @ w), None

    def net_scan(x, ws):
        return jax.lax.scan(layer, x, ws)[0]

    def net_unroll(x, ws):
        for i in range(L):
            x, _ = layer(x, ws[i])
        return x

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    fs = H.analyze(_compile(net_scan, xs, ws).as_text()).flops
    fu = H.analyze(_compile(net_unroll, xs, ws).as_text()).flops
    assert fs == pytest.approx(fu, rel=0.01)


def test_dus_accumulation_not_quadratic():
    """Scan writing one row per step into an (L, D) buffer must count
    O(L*D) bytes, not O(L^2 * D)."""
    L, D = 64, 256

    def step(buf, i):
        buf = jax.lax.dynamic_update_slice(buf, jnp.ones((1, D)), (i, 0))
        return buf, None

    def net(buf):
        buf, _ = jax.lax.scan(step, buf, jnp.arange(L))
        return buf

    comp = _compile(net, jax.ShapeDtypeStruct((L, D), jnp.float32))
    cost = H.analyze(comp.as_text())
    full_quadratic = L * (L * D * 4)
    assert cost.bytes < 0.25 * full_quadratic


def test_collective_parse_synthetic():
    sample = """
ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %ag = f32[512]{0} all-gather(%p0), dimensions={0}
  ROOT %ar = f32[128]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
    cost = H.analyze(sample)
    assert cost.collectives["all-reduce"] == 512
    assert cost.collectives["all-gather"] == 2048  # result-sized
    assert cost.collective_counts["all-reduce"] == 1


def test_collectives_inside_loops_multiplied():
    sample = """
ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %t = (f32[128]{0}) tuple(%p0)
  %w = (f32[128]{0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %o = f32[128]{0} get-tuple-element(%w), index=0
}
%body (t: (f32[128])) -> (f32[128]) {
  %t = (f32[128]{0}) parameter(0)
  %g = f32[128]{0} get-tuple-element(%t), index=0
  %ar = f32[128]{0} all-reduce(%g), to_apply=%add
  ROOT %r = (f32[128]{0}) tuple(%ar)
}
%cond (t: (f32[128])) -> pred[] {
  %t = (f32[128]{0}) parameter(0)
  ROOT %p = pred[] constant(1)
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
    cost = H.analyze(sample)
    assert cost.collectives["all-reduce"] == 12 * 512
    assert 12 in cost.while_trip_counts


def test_shape_parsing():
    assert H._shape_bytes("bf16[16,512,128]{2,1,0}") == 16 * 512 * 128 * 2
    assert H._shape_bytes("(f32[8]{0}, s32[4]{0})") == 32 + 16
    assert H._shape_elems("f32[3,5]") == 15
    assert H._shape_bytes("pred[7]") == 7
