"""Property-testing compat shim: real ``hypothesis`` when installed,
otherwise skip-only stand-ins.

The CI image does not always ship ``hypothesis``; a hard import in
conftest/test modules would abort *collection* of the whole suite.  Route
all property-test imports through this module::

    from tests._prop import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is missing, ``@given(...)`` turns the test into a
``pytest.skip`` and the ``st`` strategies namespace returns inert
placeholders, so example-based tests in the same modules still run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hypothesis-less CI
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder accepted anywhere a strategy is expected."""

        def __init__(self, name: str = "strategy") -> None:
            self._name = name

        def __call__(self, *a, **kw) -> "_Strategy":
            return self

        def __getattr__(self, attr: str) -> "_Strategy":
            return _Strategy(f"{self._name}.{attr}")

        def map(self, fn) -> "_Strategy":
            return self

        def filter(self, fn) -> "_Strategy":
            return self

        def __repr__(self) -> str:
            return f"<{self._name} (hypothesis unavailable)>"

    class _StrategiesModule:
        def __getattr__(self, attr: str) -> _Strategy:
            return _Strategy(f"st.{attr}")

    st = _StrategiesModule()

    def given(*_a, **_kw):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_kw):
        """Decorator form is a no-op; profile registration is a no-op."""

        def deco(fn):
            return fn

        return deco

    settings.register_profile = lambda *a, **kw: None
    settings.load_profile = lambda *a, **kw: None


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
