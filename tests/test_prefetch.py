"""3-phase prefetch pipeline (paper S3.2.1)."""
import numpy as np

from repro.runtime import DevicePipeline, prefetch_to_device


def test_prefetch_preserves_order_and_values():
    batches = [{"x": np.full((4,), i, np.float32)} for i in range(10)]
    out = list(prefetch_to_device(iter(batches), depth=3))
    assert len(out) == 10
    for i, b in enumerate(out):
        assert float(b["x"][0]) == i


def test_device_pipeline_overlap_window():
    import jax

    fn = jax.jit(lambda b: {"y": b["x"] * 2})
    pipe = DevicePipeline(fn, window=3)
    batches = [{"x": np.full((8,), i, np.float32)} for i in range(7)]
    outs = list(pipe.map(iter(batches)))
    assert len(outs) == 7
    assert all(float(o["y"][0]) == 2 * i for i, o in enumerate(outs))
    assert pipe.stats == {"uploaded": 7, "computed": 7, "downloaded": 7}


def test_device_pipeline_map_tagged_pairs_metadata_with_results():
    import jax

    # tags are non-device-puttable objects (tuples of strings); they must
    # bypass the upload and come back paired with their own batch's result
    fn = jax.jit(lambda b: b + 1)
    pipe = DevicePipeline(fn, window=2)
    tagged = ((("tag", i), np.full((4,), i, np.float32)) for i in range(5))
    outs = list(pipe.map_tagged(tagged))
    assert [t for t, _ in outs] == [("tag", i) for i in range(5)]
    for (_, i), arr in outs:
        assert isinstance(arr, np.ndarray)
        assert float(arr[0]) == i + 1
    assert pipe.stats == {"uploaded": 5, "computed": 5, "downloaded": 5}
