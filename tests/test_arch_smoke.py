"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, asserting shapes + finite outputs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import encdec as ED, registry, spec, transformer as T
from repro.train import AdamW, AdamWConfig, init_state, make_train_step

B, S = 2, 16


def _batch(cfg, rng):
    tok_len = S - cfg.frontend_len if cfg.frontend else S
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, tok_len)), jnp.int32)
    batch = {"tokens": toks,
             "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-1)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.1
        )
    if cfg.frontend:
        batch["prefix"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).scaled_down()
    rng = np.random.default_rng(0)
    params = spec.materialize(jax.random.key(0), registry.abstract_params(cfg))
    batch = _batch(cfg, rng)

    if cfg.family == "encdec":
        logits, aux = ED.forward(params, batch["frames"], batch["tokens"], cfg)
    elif cfg.frontend:
        logits, aux = T.forward(params, batch["tokens"], cfg, prefix_embeds=batch["prefix"])
    else:
        logits, aux = T.forward(params, batch["tokens"], cfg)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    optim = AdamW(AdamWConfig(lr=1e-3))
    state = init_state(jax.random.key(1), cfg, optim)
    step = jax.jit(make_train_step(cfg, optim))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(state2["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(state2["params"])[0]
    assert not np.array_equal(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "hymba-1.5b", "qwen3-0.6b",
                                  "deepseek-v2-lite-16b", "seamless-m4t-large-v2"])
def test_arch_smoke_serve_step(arch):
    """One prefill + one decode step on the reduced config."""
    cfg = get_config(arch).scaled_down()
    rng = np.random.default_rng(0)
    params = spec.materialize(jax.random.key(0), registry.abstract_params(cfg))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)).astype(np.float32))
        cache = ED.init_cache(cfg, B, 16, 8)
        logits, cache = ED.prefill(params, frames, toks, cfg, cache)
        logits2, _ = ED.decode_step(params, toks[:, :1], cfg, cache, jnp.asarray(8))
    else:
        cache = T.init_cache(cfg, B, 16)
        logits, cache = T.prefill(params, toks, cfg, cache)
        logits2, _ = T.decode_step(params, toks[:, :1], cfg, cache, jnp.asarray(8))
    assert logits.shape == (B, 1, cfg.vocab)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
