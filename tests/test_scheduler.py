"""WRM scheduling: PATS/FCFS/DL policies + both execution engines."""
import threading

import pytest
from tests._prop import given, st

from repro.runtime import (
    DeviceKind,
    ReadyQueue,
    SchedulerConfig,
    SimulatedWRM,
    Task,
    TaskCost,
    ThreadedWRM,
    make_devices,
)


def _tasks(speedups, cpu_s=1.0):
    return [Task(f"t{i}", cost=TaskCost(cpu_s=cpu_s, speedup=s)) for i, s in enumerate(speedups)]


@given(st.lists(st.floats(0.5, 50.0), min_size=2, max_size=20))
def test_pats_queue_ordering(speedups):
    """Accelerator always gets max speedup, CPU min (paper Fig. 5)."""
    q = ReadyQueue("PATS")
    ts = _tasks(speedups)
    for t in ts:
        q.push(t)
    gpu_pick = q.peek_for(DeviceKind.ACCEL)
    cpu_pick = q.peek_for(DeviceKind.CPU)
    assert gpu_pick.speedup == max(speedups)
    assert cpu_pick.speedup == min(speedups)


def test_fcfs_queue_ordering():
    q = ReadyQueue("FCFS")
    ts = _tasks([5.0, 1.0, 9.0])
    for t in ts:
        q.push(t)
    assert q.peek_for(DeviceKind.ACCEL) is ts[0]
    assert q.peek_for(DeviceKind.CPU) is ts[0]


def test_dl_rule_paper_inequality():
    """DL picks the reuse task iff S_d >= S_q * (1 - TransferImpact)."""
    cfg = SchedulerConfig(policy="PATS", data_locality=True, transfer_impact=0.3)
    parent = Task("parent", cost=TaskCost(speedup=10.0))
    reuse_ok = Task("reuse_ok", deps=[parent], cost=TaskCost(speedup=8.0))
    best = Task("best", cost=TaskCost(speedup=10.0))
    from repro.runtime.dag import TaskState

    parent.state = TaskState.DONE
    q = ReadyQueue("PATS")
    q.push(reuse_ok)
    q.push(best)
    # S_d=8 >= 10*(1-0.3)=7  -> reuse wins on the accelerator
    assert q.select(DeviceKind.ACCEL, cfg, parent) is reuse_ok

    q2 = ReadyQueue("PATS")
    reuse_bad = Task("reuse_bad", deps=[parent], cost=TaskCost(speedup=5.0))
    parent.children = [reuse_bad]
    best2 = Task("best2", cost=TaskCost(speedup=10.0))
    q2.push(reuse_bad)
    q2.push(best2)
    # S_d=5 < 7 -> the higher-speedup task wins despite no reuse
    assert q2.select(DeviceKind.ACCEL, cfg, parent) is best2


def test_simulated_pats_beats_fcfs_on_heterogeneous_mix():
    def mk():
        return _tasks([1.2, 20.0] * 20)

    devs = make_devices(4, 1)
    fc = SimulatedWRM(devs, SchedulerConfig(policy="FCFS")).run(mk())
    pa = SimulatedWRM(devs, SchedulerConfig(policy="PATS")).run(mk())
    assert pa.makespan < fc.makespan


def test_simulated_respects_dependencies():
    a = Task("a", cost=TaskCost(cpu_s=1.0))
    b = Task("b", deps=[a], cost=TaskCost(cpu_s=1.0))
    c = Task("c", deps=[b], cost=TaskCost(cpu_s=1.0))
    res = SimulatedWRM(make_devices(4, 0)).run([c, b, a])
    order = {name: (s, e) for s, e, name, _ in res.task_log}
    assert order["a"][1] <= order["b"][0] and order["b"][1] <= order["c"][0]
    assert res.makespan == pytest.approx(3.0)


def test_simulated_prefetch_hides_transfers():
    def mk():
        return [
            Task(f"t{i}", cost=TaskCost(cpu_s=1.0, speedup=10.0, input_bytes=8_000_000_00))
            for i in range(8)
        ]

    devs = make_devices(0, 1)
    base = SimulatedWRM(devs, SchedulerConfig(policy="FCFS", prefetch=False)).run(mk())
    pref = SimulatedWRM(devs, SchedulerConfig(policy="FCFS", prefetch=True)).run(mk())
    assert pref.makespan < base.makespan


def test_simulated_dl_avoids_transfers():
    def mk():
        parents = [Task(f"p{i}", cost=TaskCost(cpu_s=1.0, speedup=10.0,
                                               input_bytes=10**9, output_bytes=10**9))
                   for i in range(6)]
        children = [Task(f"c{i}", deps=[p], cost=TaskCost(cpu_s=1.0, speedup=9.0,
                                                          input_bytes=10**9))
                    for i, p in enumerate(parents)]
        return parents + children

    devs = make_devices(1, 1)
    off = SimulatedWRM(devs, SchedulerConfig(policy="PATS", data_locality=False)).run(mk())
    on = SimulatedWRM(devs, SchedulerConfig(policy="PATS", data_locality=True,
                                            transfer_impact=0.3)).run(mk())
    assert on.makespan <= off.makespan


def test_threaded_wrm_executes_with_deps_and_variants():
    devs = make_devices(2, 1)
    wrm = ThreadedWRM(devs, SchedulerConfig(policy="PATS"))
    log = []
    lock = threading.Lock()

    def work(name):
        with lock:
            log.append(name)

    a = Task("a", cpu_fn=lambda: work("a"), accel_fn=lambda: work("a"))
    b = Task("b", cpu_fn=lambda: work("b"), deps=[a])
    wrm.submit(a)
    wrm.submit(b)
    wrm.wait_all()
    wrm.shutdown()
    assert log.index("a") < log.index("b")
    assert a.ran_on is not None


def test_threaded_wrm_failure_surfaces():
    wrm = ThreadedWRM(make_devices(1, 0))

    def boom():
        raise RuntimeError("kaput")

    wrm.submit(Task("bad", cpu_fn=boom))
    with pytest.raises(RuntimeError):
        wrm.wait_all()
    wrm.shutdown()


def test_measured_speedup_profile():
    import time

    wrm = ThreadedWRM(make_devices(1, 1))
    wrm.submit(Task("op", cpu_fn=lambda: time.sleep(0.02), accel_fn=lambda: time.sleep(0.002)))
    wrm.submit(Task("op", cpu_fn=lambda: time.sleep(0.02), accel_fn=lambda: time.sleep(0.002)))
    wrm.wait_all()
    wrm.shutdown()
    # with one CPU and one ACCEL thread both variants usually run; if both
    # landed on the same device kind, the estimate is undefined -> skip
    s = wrm.measured_speedup("op")
    if s is not None:
        assert s > 1.0
