"""Training substrate: optimizers, schedules, microbatching, ZeRO-1 specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.train import (
    Adafactor,
    AdamW,
    AdamWConfig,
    cosine_lr,
    cross_entropy,
    init_state,
    make_train_step,
    state_pspecs,
)

CFG = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
)


def _batch(rng, b=4, s=16, vocab=128):
    toks = jnp.asarray(rng.integers(0, vocab, (b, s)), jnp.int32)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-1)}


def test_adamw_converges():
    optim = AdamW(AdamWConfig(lr=1e-2))
    state = init_state(jax.random.key(0), CFG, optim)
    step = jax.jit(make_train_step(CFG, optim))
    batch = _batch(np.random.default_rng(0))
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0]


def test_adafactor_reduces_loss():
    optim = Adafactor()
    state = init_state(jax.random.key(0), CFG, optim)
    step = jax.jit(make_train_step(CFG, optim))
    batch = _batch(np.random.default_rng(0))
    losses = []
    for _ in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatching_matches_full_batch_grads():
    """Gradient accumulation must equal the single-shot gradient."""
    optim = AdamW(AdamWConfig(lr=0.0, weight_decay=0.0))  # lr=0: params frozen
    state = init_state(jax.random.key(0), CFG, optim)
    batch = _batch(np.random.default_rng(1), b=8)
    s1 = jax.jit(make_train_step(CFG, optim, microbatches=1))
    s2 = jax.jit(make_train_step(CFG, optim, microbatches=4))
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=1e-4)


def test_cross_entropy_ignores_masked_labels():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, -1, -1]])
    total, ce = cross_entropy(logits, labels, z_loss=0.0)
    assert float(ce) == pytest.approx(np.log(8), rel=1e-5)


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.asarray(0), base=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(jnp.asarray(10), base=1.0, warmup=10, total=100)) == pytest.approx(1.0)
    end = float(cosine_lr(jnp.asarray(100), base=1.0, warmup=10, total=100))
    assert end == pytest.approx(0.1, rel=1e-3)


def test_zero1_pspecs_add_dp_axis():
    """ZeRO-1 shards optimizer state over the data axis on top of the
    param's model-axis sharding."""
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices() * 1).reshape(1, 1), ("data", "model")
    )
    optim = AdamW()
    cfg = ModelConfig(
        name="z", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab=128,
    )
    base = state_pspecs(cfg, mesh, optim, zero1=False)
    z1 = state_pspecs(cfg, mesh, optim, zero1=True)
    base_leaves = jax.tree.leaves(
        base["opt"]["m"], is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    z1_leaves = jax.tree.leaves(
        z1["opt"]["m"], is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    # params keep their sharding; some opt leaves must gain a 'data' axis
    gained = sum(
        ("data" in jax.tree.leaves(tuple(s)) or any("data" in (p or ()) for p in s))
        and not ("data" in jax.tree.leaves(tuple(b)) or any("data" in (p or ()) for p in b))
        for b, s in zip(base_leaves, z1_leaves)
    )
    assert gained > 0
    assert base["params"] == z1["params"]


def test_nan_labels_do_not_poison_loss():
    optim = AdamW()
    state = init_state(jax.random.key(0), CFG, optim)
    step = jax.jit(make_train_step(CFG, optim))
    batch = _batch(np.random.default_rng(0))
    batch["labels"] = jnp.full_like(batch["labels"], -1)  # everything masked
    _, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
