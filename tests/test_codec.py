"""Unit coverage for the data-plane building blocks: block codecs
(`storage/codec.py`) and the shared-memory arena/window pair
(`storage/shm.py`).  Everything here is single-process — the live-fleet
integration (negotiation, mixed fleets, at-rest servers) lives in
tests/test_net.py under the `net` marker."""
import numpy as np
import pytest

from repro.storage.codec import (
    WIRE_CODECS,
    Encoded,
    check_codec,
    decode_array,
    decode_block,
    encode_array,
    encode_block,
    is_lossless,
    raw_nbytes,
)
from repro.storage.shm import ShmArena, ShmWindow


def _reregister(arena):
    """ShmWindow.attach unregisters the segment from the caller's
    resource tracker (correct cross-process, where the SERVER owns the
    registration).  These unit tests attach in the creating process, so
    re-register to keep the arena's unlink paired and the tracker
    quiet."""
    from multiprocessing import resource_tracker

    try:
        resource_tracker.register(arena._shm._name, "shared_memory")
    except Exception:
        pass


def _bf16():
    import ml_dtypes

    return ml_dtypes.bfloat16


def _arrays():
    rng = np.random.default_rng(0)
    return {
        "f32": rng.random((16, 16)).astype(np.float32),
        "f64": rng.standard_normal((8, 8)) * 100.0,
        "f16": rng.random((8, 8)).astype(np.float16),
        "bf16": np.arange(24, dtype=np.float32).astype(_bf16()).reshape(4, 6),
        "u8_labels": np.repeat(rng.integers(0, 8, (4, 64)), 16, axis=0).astype(np.uint8),
        "i64": rng.integers(-5, 5, (6, 7)).astype(np.int64),
        "bool": rng.random((9, 9)) > 0.5,
        "empty": np.zeros((0, 5), np.float32),
        "noncontig": rng.random((8, 8, 8)).astype(np.float32)[:, ::2, :],
    }


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", list(WIRE_CODECS) + [None])
@pytest.mark.parametrize("name", list(_arrays().keys()))
def test_block_roundtrip_every_codec_every_dtype(codec, name):
    """The full matrix: dtype and shape always survive; lossless codecs
    (and lossy codecs on non-float payloads, which degrade to zlib) are
    bit-exact; lossy codecs on f32/f64 land within quantization error."""
    arr = _arrays()[name]
    meta, buf = encode_block(arr, codec)
    back = decode_block(meta, bytes(buf))
    assert back.dtype == arr.dtype
    assert back.shape == arr.shape
    lossy = (
        arr.size > 0
        and codec in ("bf16", "int8")
        and arr.dtype.type in (np.float32, np.float64)
    )
    if not lossy:
        np.testing.assert_array_equal(back, arr)
        assert is_lossless(meta)
    elif codec == "bf16":
        # bf16 keeps 8 mantissa bits: relative error <= 2^-8
        np.testing.assert_allclose(
            back.astype(np.float64), arr.astype(np.float64),
            rtol=2 ** -7, atol=1e-12,
        )
    else:  # int8: absolute error <= scale/2 = absmax/254
        atol = float(np.abs(arr).max()) / 127.0 + 1e-12
        np.testing.assert_allclose(
            back.astype(np.float64), arr.astype(np.float64), atol=atol
        )


def test_raw_codec_is_legacy_wire_format():
    """codec=None and codec='raw' emit the untagged legacy frame —
    byte-identical meta and payload to encode_array."""
    arr = np.arange(64, dtype=np.float32).reshape(8, 8)
    legacy_meta, legacy_buf = encode_array(arr)
    for codec in (None, "raw"):
        meta, buf = encode_block(arr, codec)
        assert meta == legacy_meta  # no codec tag added
        assert bytes(buf) == bytes(legacy_buf)
    np.testing.assert_array_equal(decode_block(legacy_meta, bytes(legacy_buf)), arr)


def test_zlib_tags_and_shrinks_compressible_blocks():
    tile = np.zeros((64, 64), np.uint8)
    tile[::8] = 3
    meta, buf = encode_block(tile, "zlib")
    assert meta["codec"] == "zlib"
    assert buf.nbytes < tile.nbytes // 3
    np.testing.assert_array_equal(decode_block(meta, bytes(buf)), tile)


def test_zlib_incompressible_falls_back_to_untagged_raw():
    """Random bytes don't compress: the encoder must emit the raw frame
    (no tag, no size penalty) instead of a bigger zlib blob."""
    noise = np.random.default_rng(1).integers(0, 256, 4096).astype(np.uint8)
    meta, buf = encode_block(noise, "zlib")
    assert "codec" not in meta
    assert buf.nbytes == noise.nbytes
    np.testing.assert_array_equal(decode_block(meta, bytes(buf)), noise)


def test_empty_blocks_always_raw():
    for codec in WIRE_CODECS:
        meta, buf = encode_block(np.zeros((0, 3), np.float64), codec)
        assert "codec" not in meta and buf.nbytes == 0


def test_lossy_modes_never_touch_discrete_dtypes():
    """Labels/masks/ints under bf16/int8 degrade to lossless zlib (or
    raw) — never quantized."""
    labels = np.repeat(np.arange(8, dtype=np.uint8), 512).reshape(64, 64)
    for codec in ("bf16", "int8"):
        meta, buf = encode_block(labels, codec)
        assert meta.get("codec") in (None, "zlib")
        np.testing.assert_array_equal(decode_block(meta, bytes(buf)), labels)


def test_int8_all_zeros_block_decodes_exact():
    """absmax=0 must not divide by zero; zeros round-trip exactly."""
    z = np.zeros((16, 16), np.float32)
    meta, buf = encode_block(z, "int8")
    assert meta["codec"] == "int8" and buf.nbytes == z.size
    np.testing.assert_array_equal(decode_block(meta, bytes(buf)), z)


def test_bf16_halves_wire_bytes_and_preserves_dtype():
    arr = np.random.default_rng(2).standard_normal((32, 32)).astype(np.float32)
    meta, buf = encode_block(arr, "bf16")
    assert meta["codec"] == "bf16"
    assert buf.nbytes == arr.nbytes // 2
    assert not is_lossless(meta)
    back = decode_block(meta, bytes(buf))
    assert back.dtype == np.float32


def test_decode_block_rejects_unknown_tag():
    meta, buf = encode_array(np.ones(4, np.float32))
    with pytest.raises(ValueError, match="unknown codec"):
        decode_block(dict(meta, codec="lzma"), bytes(buf))


def test_check_codec_normalizes_and_validates():
    assert check_codec(None) is None
    assert check_codec("raw") is None
    assert check_codec("zlib") == "zlib"
    assert check_codec("bf16") == "bf16"
    with pytest.raises(ValueError, match="unknown wire codec"):
        check_codec("gzip")


def test_raw_nbytes_matches_decoded_size():
    for arr in _arrays().values():
        meta, _ = encode_array(arr)
        assert raw_nbytes(meta) == np.ascontiguousarray(arr).nbytes


def test_encoded_at_rest_block_accounting_and_decode():
    tile = np.repeat(np.arange(16, dtype=np.uint8), 1024).reshape(128, 128)
    meta, buf = encode_block(tile, "zlib")
    assert is_lossless(meta)
    enc = Encoded(dict(meta), bytes(buf))
    assert enc.nbytes == len(bytes(buf)) < tile.nbytes  # resident size
    assert enc.raw_nbytes == tile.nbytes
    np.testing.assert_array_equal(enc.decode(), tile)


def test_legacy_meta_without_codec_tag_decodes_as_raw():
    """Frames from an old peer (no codec key at all) decode unchanged —
    the mixed-fleet invariant at the codec layer."""
    arr = np.arange(12, dtype=np.int32).reshape(3, 4)
    meta = {"shape": [3, 4], "dtype": "int32"}  # exactly what old peers send
    assert is_lossless(meta)
    np.testing.assert_array_equal(
        decode_block(meta, arr.tobytes()), arr
    )
    np.testing.assert_array_equal(decode_array(meta, arr.tobytes()), arr)


# ---------------------------------------------------------------------------
# shm arena + window
# ---------------------------------------------------------------------------
def test_arena_place_locate_window_read_roundtrip():
    arena = ShmArena(1 << 16)
    try:
        arr = np.random.default_rng(3).random((32, 32)).astype(np.float32)
        view = arena.place("h1", arr)
        assert view is not None and not view.flags.writeable
        np.testing.assert_array_equal(view, arr)
        off, nbytes = arena.locate("h1")
        assert nbytes == arr.nbytes
        assert arena.used_bytes == arr.nbytes

        win = ShmWindow.attach(arena.describe())
        _reregister(arena)
        assert win is not None
        meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        copied = win.read(off, meta)
        np.testing.assert_array_equal(copied, arr)
        copied[0, 0] = -1.0  # private copy: arena unaffected
        zc = win.read(off, meta, zero_copy=True)
        np.testing.assert_array_equal(zc, arr)
        assert not zc.flags.writeable
        del zc
        win.close()
    finally:
        arena.close()


def test_arena_replace_release_and_pressure_reclaim():
    """A handle re-place frees the old slot; released slots sit in
    quarantine but are force-reclaimed under allocation pressure, with
    neighbour coalescing making the full capacity reusable as one
    block."""
    arena = ShmArena(4096)
    try:
        blocks = {f"b{i}": np.full(1024, i, np.uint8) for i in range(4)}
        for h, a in blocks.items():
            assert arena.place(h, a) is not None
        assert arena.used_bytes == 4096
        # full: a fifth block evicts the least-recently-fetched resident
        # (b0 — never touched since placement) to the heap ledger
        assert arena.place("b4", np.ones(1024, np.uint8)) is not None
        assert arena.evictions == 1
        assert arena.locate("b0") is None
        saved = arena.claim_or_touch("b0")  # shard reclaims the bytes
        assert saved is not None
        np.testing.assert_array_equal(
            np.frombuffer(saved, np.uint8), blocks["b0"]
        )
        assert arena.claim_or_touch("b0") is None  # ledger entry consumed
        # replacing an existing handle succeeds (its own slot frees);
        # it re-enters full, so the now-coldest resident (b1) is demoted
        assert arena.place("b0", np.full(1024, 9, np.uint8)) is not None
        assert arena.locate("b1") is None
        # release everything, then place one arena-sized block: only
        # works if quarantine is drained early AND the slots coalesce
        for h in blocks:
            arena.release(h)  # b1's release drops its ledger copy too
        arena.release("b4")
        assert arena.used_bytes == 0
        big = np.arange(4096, dtype=np.uint8)
        view = arena.place("big", big)
        assert view is not None
        np.testing.assert_array_equal(view, big)
    finally:
        arena.close()


def test_arena_pressure_drain_recycles_oldest_slot_first():
    """Pressure reclaim frees only as many quarantined slots as the
    allocation needs, oldest deadline first — the newer slot keeps its
    grace window for in-flight shm readers instead of being recycled by
    a blanket drain."""
    arena = ShmArena(4096)
    try:
        for h in ("a", "b", "c", "d"):
            assert arena.place(h, np.full(1024, ord(h), np.uint8)) is not None
        arena.release("b")
        arena.release("c")  # both slots sit in quarantine, b's is older
        assert arena.place("e", np.zeros(1024, np.uint8)) is not None
        assert arena.evictions == 0
        # only b's slot was recycled; c's is still in grace
        assert len(arena._quarantine) == 1
        assert arena.locate("e") is not None
    finally:
        arena.close()


def test_arena_rejects_oversized_and_empty_blocks():
    arena = ShmArena(1024)
    try:
        assert arena.place("big", np.zeros(4096, np.uint8)) is None
        assert arena.place("empty", np.zeros(0, np.float32)) is None
        assert arena.locate("big") is None
    finally:
        arena.close()


def test_window_attach_rejects_wrong_token_and_missing_segment():
    arena = ShmArena(1 << 12)
    try:
        desc = arena.describe()
        assert set(desc) == {"name", "size", "token"}
        bad = dict(desc, token="00" * 16)
        assert ShmWindow.attach(bad) is None  # co-location disproved
        _reregister(arena)
    finally:
        arena.close()
    assert ShmWindow.attach({"name": "repro_no_such_seg", "token": "00"}) is None


# ---------------------------------------------------------------------------
# per-key codec override maps
# ---------------------------------------------------------------------------
class _Key:
    def __init__(self, namespace, name):
        self.namespace = namespace
        self.name = name


def test_check_codec_normalizes_and_validates_mappings():
    spec = check_codec({"labels/*": "zlib", "feat/*": "bf16", "tmp/*": "raw"})
    assert spec == {"labels/*": "zlib", "feat/*": "bf16", "tmp/*": None}
    with pytest.raises(ValueError, match="unknown wire codec"):
        check_codec({"a/*": "gzip"})
    with pytest.raises(ValueError, match="non-empty str"):
        check_codec({"": "zlib"})
    with pytest.raises(ValueError, match="nested"):
        check_codec({"a/*": {"b": "zlib"}})


def test_codec_names_lists_distinct_non_raw_codecs():
    from repro.storage.codec import codec_names

    assert codec_names(None) == []
    assert codec_names("raw") == []
    assert codec_names("zlib") == ["zlib"]
    assert codec_names({"a/*": "zlib", "b/*": "bf16", "c/*": "zlib", "d": None}) == [
        "bf16",
        "zlib",
    ]


def test_resolve_codec_matches_first_hit_in_insertion_order():
    from repro.storage.codec import resolve_codec

    spec = {"labels/*": "zlib", "*mask*": "int8", "feat": "bf16"}
    assert resolve_codec(spec, _Key("labels", "nuclei")) == "zlib"
    # bare name then bare namespace also match the glob
    assert resolve_codec(spec, _Key("x", "tumor_mask_v2")) == "int8"
    assert resolve_codec(spec, _Key("feat", "embedding")) == "bf16"
    # insertion order wins: labels/mask hits the labels/* rule first
    assert resolve_codec(spec, _Key("labels", "mask")) == "zlib"
    # no hit -> raw (None); plain strings and single-codec specs pass through
    assert resolve_codec(spec, _Key("rgb", "tile")) is None
    assert resolve_codec(spec, "labels/other") == "zlib"
    assert resolve_codec("zlib", _Key("any", "thing")) == "zlib"
    assert resolve_codec(None, _Key("any", "thing")) is None


# ---------------------------------------------------------------------------
# arena LRU eviction order (by FETCH recency, not placement order)
# ---------------------------------------------------------------------------
def test_arena_evicts_least_recently_fetched_first():
    arena = ShmArena(4096)
    try:
        for h in ("a", "b", "c", "d"):
            assert arena.place(h, np.full(1024, ord(h), np.uint8)) is not None
        # touch 'a' (the oldest placement): a read bumps its recency
        assert arena.claim_or_touch("a") is None
        assert arena.place("e", np.zeros(1024, np.uint8)) is not None
        # 'b' was coldest -> demoted to the ledger; 'a' stayed resident
        assert arena.locate("b") is None and arena.locate("a") is not None
        assert arena.evictions == 1
        raw = arena.claim_or_touch("b")
        np.testing.assert_array_equal(
            np.frombuffer(raw, np.uint8), np.full(1024, ord("b"), np.uint8)
        )
        # a block too big to ever fit still refuses without evicting all
        before = arena.evictions
        assert arena.place("huge", np.zeros(8192, np.uint8)) is None
        assert arena.evictions == before
    finally:
        arena.close()
