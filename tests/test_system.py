"""End-to-end behaviour tests for the whole system.

1. The paper's two-stage WSI dataflow over a partitioned slide through the
   full Manager-Worker runtime with DMS exchange + DISK persistence.
2. The LM training driver: loss goes down, checkpoints restore, restart
   resumes.
"""
import numpy as np

from repro.configs.wsi import WSIConfig
from repro.core import BoundingBox, Intent, RegionTemplate, StorageRegistry
from repro.pipeline import FeatureStage, SegmentationStage, make_slide
from repro.runtime import SchedulerConfig, SysEnv
from repro.storage import DiskStorage, DistributedMemoryStorage


def test_partitioned_wsi_dataflow_end_to_end(tmp_path):
    """4-partition slide -> Segmentation -> Features, PATS + DL enabled,
    masks staged to DISK (persistence) and exchanged via DMS."""
    tile = 64
    rgb, _ = make_slide(2, 2, tile, seed=1)  # (3, 128, 128)
    h, w = rgb.shape[1:]
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=16)

    reg = StorageRegistry()
    dom3 = BoundingBox((0, 0, 0), (3, h, w))
    dom2 = BoundingBox((0, 0), (h, w))
    dms3 = reg.register(DistributedMemoryStorage(dom3, (3, tile, tile), 2, name="DMS3"))
    dms2 = reg.register(DistributedMemoryStorage(dom2, (tile, tile), 2, name="DMS2"))
    disk = reg.register(DiskStorage(str(tmp_path), transport="aggregated",
                                    queue_threshold=2, name="DISK"))

    rt = RegionTemplate("Patient")
    rgb_region = rt.new_region("RGB", dom3, np.float32, input_storage="DMS3", lazy=True)
    dms3.put(rgb_region.key, dom3, rgb)

    env = SysEnv(
        num_workers=2, cpus_per_worker=2, accels_per_worker=1,
        sched=SchedulerConfig(policy="PATS", data_locality=True),
        registry=reg,
    )
    stages = []
    for part2 in dom2.tiles((tile, tile)):
        part3 = BoundingBox((0,) + part2.lo, (3,) + part2.hi)
        seg = SegmentationStage(cfg, impl="xla")
        seg.add_region_template(rt, "RGB", part3, Intent.INPUT, read_storage="DMS3")
        seg.add_region_template(rt, "Mask", part2, Intent.OUTPUT, storage="DMS2")
        seg.add_region_template(rt, "Hema", part2, Intent.OUTPUT, storage="DMS2")
        feat = FeatureStage(cfg, impl="xla")
        feat.add_region_template(rt, "Mask", part2, Intent.INPUT, read_storage="DMS2")
        feat.add_region_template(rt, "Hema", part2, Intent.INPUT, read_storage="DMS2")
        feat.add_dependency(seg)
        env.execute_component(seg)
        env.execute_component(feat)
        stages.append((seg, feat))
    env.startup_execution()
    env.finalize_system()

    # every partition produced a mask region covering its bounding box
    mask_key = stages[0][0].templates["Patient"].get("Mask").key
    full_mask = dms2.get(mask_key, dom2)
    assert full_mask.shape == (h, w)
    assert (full_mask >= -1).all()
    # feature stages produced object sets
    total_objects = 0
    for _, feat in stages:
        fr = feat.templates["Patient"].get("Features")
        total_objects += fr.num_objects
        assert fr.data["features"].shape[1] == 9
    assert total_objects > 4

    # persistence: stage the mask to DISK and reopen
    disk.put(mask_key, dom2, full_mask)
    disk.flush()
    reopened = DiskStorage(str(tmp_path))
    assert np.array_equal(reopened.get(mask_key, dom2), full_mask)


def test_train_driver_end_to_end_with_restart(tmp_path):
    from repro.launch.train import main

    out1 = main([
        "--arch", "qwen3-0.6b", "--smoke", "--steps", "8", "--batch", "2",
        "--seq", "32", "--ckpt-every", "4", "--ckpt-dir", str(tmp_path),
        "--vocab", "128", "--log-every", "100",
    ])
    assert len(out1["losses"]) == 8
    assert np.isfinite(out1["losses"]).all()
    ck = out1["ckpt"]
    assert ck.latest_step() == 8

    # restart resumes from the checkpoint and continues
    out2 = main([
        "--arch", "qwen3-0.6b", "--smoke", "--steps", "12", "--batch", "2",
        "--seq", "32", "--ckpt-every", "100", "--ckpt-dir", str(tmp_path),
        "--vocab", "128", "--restore", "--log-every", "100",
    ])
    assert len(out2["losses"]) == 4  # 8 -> 12
    assert int(np.asarray(out2["state"]["step"])) == 12


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    out = main([
        "--arch", "qwen3-0.6b", "--smoke", "--requests", "3", "--batch", "2",
        "--prompt-len", "8", "--max-new", "4",
    ])
    assert sum(o.shape[0] for o in out["outputs"]) == 3
    assert all(o.shape[1] == 12 for o in out["outputs"])
