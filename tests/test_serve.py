"""Serving: cache sharding specs, generation, determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, registry, spec
from repro.serve import abstract_cache, cache_pspecs, generate, make_cache

CFG = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
)


def test_greedy_generation_deterministic():
    params = spec.materialize(jax.random.key(0), registry.abstract_params(CFG))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    a = generate(params, CFG, prompt, max_new=6)
    b = generate(params, CFG, prompt, max_new=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (1, 10)


def test_generation_continuation_consistency():
    """Generating 6 tokens equals generating 3 then continuing with 3."""
    params = spec.materialize(jax.random.key(0), registry.abstract_params(CFG))
    prompt = jnp.asarray([[5, 6, 7]], jnp.int32)
    full = np.asarray(generate(params, CFG, prompt, max_new=6, max_len=16))
    half = np.asarray(generate(params, CFG, prompt, max_new=3, max_len=16))
    cont = np.asarray(generate(params, CFG, jnp.asarray(full[:, :6]), max_new=3, max_len=16))
    np.testing.assert_array_equal(full[:, :6], np.concatenate([prompt, half[:, 3:]], 1))
    np.testing.assert_array_equal(full, cont)


def test_cache_pspecs_cover_every_leaf():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for fam_cfg in (
        CFG,
        CFG.replace(family="ssm", ssm_state=8, ssm_headdim=16),
        CFG.replace(family="hybrid", window=8, num_global_layers=1,
                    ssm_state=8, ssm_headdim=16, num_layers=3),
        CFG.replace(attn_kind="mla", kv_lora_rank=32, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16),
    ):
        cache = abstract_cache(fam_cfg, 4, 32)
        specs = cache_pspecs(fam_cfg, cache, mesh)
        n_cache = len(jax.tree.leaves(cache))
        n_spec = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        ))
        assert n_cache == n_spec


def test_make_cache_shapes():
    cache = make_cache(CFG, batch=3, max_len=20)
    assert cache["layers"]["k"].shape == (2, 3, 2, 20, 16)
    ssm_cfg = CFG.replace(family="ssm", ssm_state=8, ssm_headdim=16)
    c2 = make_cache(ssm_cfg, batch=3, max_len=20)
    assert c2["ssm"]["ssm"].shape == (2, 3, ssm_cfg.ssm_heads, 8, 16)
