"""Gradient-compression collectives. Multi-device psum semantics need >1
device, so the core check runs in a subprocess with a forced 8-device host
platform; the quantization math is also validated in-process."""
import subprocess
import sys

import numpy as np

from repro.train.compression import compression_ratio

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.train.compression import compressed_psum

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pre-0.6 jax only ships the experimental spelling
    from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)

for mode, tol in [("fp32", 1e-6), ("bf16", 2e-2), ("int8", 3e-2)]:
    f = jax.jit(
        shard_map(
            lambda v: compressed_psum(v, "pod", mode),
            mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
        )
    )
    out = np.asarray(f(x))
    want = np.asarray(x).reshape(2, 4, 16)
    want = want.sum(axis=0, keepdims=True).repeat(2, 0).reshape(8, 16)
    err = np.abs(out - want).max() / (np.abs(want).max() + 1e-9)
    assert err < tol, (mode, err)
    print(f"{mode} ok rel_err={err:.2e}")
print("SUBPROC_OK")
"""


def test_compressed_psum_multi_device_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=300, cwd=".",
    )
    assert "SUBPROC_OK" in res.stdout, res.stdout + res.stderr


def test_int8_quantization_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(1000).astype(np.float32)
    scale = np.abs(x).max() / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    err = np.abs(q.astype(np.float32) * scale - x).max()
    assert err <= scale / 2 + 1e-7


def test_compression_ratios():
    assert compression_ratio("fp32") == 1.0
    assert compression_ratio("bf16") == 2.0
    assert compression_ratio("int8") == 4.0
