"""Near-data compute: chain registry validation (typed fail-fast errors),
ref-vs-Pallas closeness for every standard chain, gateway compute()
exactness vs local fetch + chain, compute-ROI coalescing, the
generation-validated derived cache, and make_wsi_storage(compute=True)."""
import threading

import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.kernels import ref
from repro.kernels.chains import (
    STANDARD_CHAINS,
    ChainParamError,
    UnknownChainError,
    list_stages,
    resolve_chain,
)
from repro.serve import ComputeRequest, RegionGateway
from repro.serve.gateway import GatewayConfig
from repro.storage import DistributedMemoryStorage, Tier, TieredStore

H = W = 128
DOM3 = BoundingBox((0, 0, 0), (3, H, W))
TILE3 = (3, 32, 32)


def _key(name="RGB"):
    return RegionKey("nd", name, ElementType.FLOAT32)


def _stain_rgb(h, w, seed=0) -> np.ndarray:
    """Synthetic H&E-like tile via the *forward* Ruifrok model: blobby
    hematoxylin density in {0.15, 0.85} so the deconvolved plane is
    bimodal and thresholding is far from any decision boundary (chains
    stay bit-stable across impls)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    blobs = np.zeros((h, w), bool)
    for _ in range(6):
        cy, cx = rng.integers(0, h), rng.integers(0, w)
        r = rng.integers(6, 14)
        blobs |= (yy - cy) ** 2 + (xx - cx) ** 2 < r * r
    density = np.where(blobs, 0.85, 0.15).astype(np.float32)
    stains = np.stack([density, np.full_like(density, 0.05), np.full_like(density, 0.02)])
    m = ref.RUIFROK_HED / np.linalg.norm(ref.RUIFROK_HED, axis=1, keepdims=True)
    od = np.einsum("shw,sc->chw", stains, m)
    return (10.0 ** -od).astype(np.float32)


def _store() -> tuple[TieredStore, np.ndarray]:
    dms = DistributedMemoryStorage(DOM3, TILE3, 4)
    store = TieredStore([Tier("DMS", dms)], name="NDC")
    slide = _stain_rgb(H, W)
    for tile in DOM3.tiles(TILE3):
        store.put(_key(), tile, slide[tile.slices()])
    return store, slide


# ---------------------------------------------------------------------------
# chain registry + ref-vs-Pallas
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", STANDARD_CHAINS)
def test_standard_chain_ref_vs_pallas_bit_close(name):
    """Every registered standard chain: the Pallas path (interpret=True on
    CPU) must be bit-close to the pure-jnp reference composition."""
    chain = resolve_chain(name)
    x = _stain_rgb(64, 64, seed=3)
    if 3 not in chain.in_ranks:
        x = x[0]  # rank-2 chains take a single plane
    want = chain(x, impl="xla")
    got = chain(x, impl="pallas")
    assert got.shape == want.shape and got.dtype == want.dtype
    if np.issubdtype(want.dtype, np.floating):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    else:
        np.testing.assert_array_equal(got, want)


def test_chain_digest_canonicalizes_params():
    base = resolve_chain("deconv|threshold")
    defaulted = resolve_chain("deconv|threshold", {"thr": 0.5, "norm": True})
    assert base.digest() == defaulted.digest()
    assert base.digest() != resolve_chain("deconv|threshold", {"thr": 0.4}).digest()
    assert set(list_stages()) >= {"deconv", "threshold", "fill", "ccl", "count", "glcm"}


def test_typed_errors_fail_fast():
    with pytest.raises(UnknownChainError, match="nope"):
        resolve_chain("deconv|nope")
    with pytest.raises(UnknownChainError):
        resolve_chain("")
    with pytest.raises(ChainParamError, match="thr"):
        resolve_chain("deconv|threshold", {"thr": 1.5})
    with pytest.raises(ChainParamError, match="unknown param"):
        resolve_chain("deconv", {"bogus": 1})
    with pytest.raises(ChainParamError, match="host reduction"):
        resolve_chain("deconv|threshold|ccl|count|threshold")  # host stage mid-chain
    with pytest.raises(ChainParamError):
        resolve_chain("deconv|threshold", {"stain": -1})  # rank-3 out feeds rank-2 stage


# ---------------------------------------------------------------------------
# gateway compute(): exactness, fail-fast, coalescing
# ---------------------------------------------------------------------------
def test_gateway_compute_matches_local_fetch_plus_chain_exactly():
    store, slide = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    rois = [
        BoundingBox((0, 0, 0), (3, 64, 64)),
        BoundingBox((0, 32, 48), (3, 96, 128)),
    ]
    for name in ("deconv|threshold", "deconv|threshold|ccl", "deconv|threshold|ccl|count"):
        chain = resolve_chain(name)
        for roi in rois:
            got = gw.compute(_key(), roi, name)
            want = chain(store.get(_key(), roi), impl=gw.config.compute_impl)
            np.testing.assert_array_equal(got, want)  # bit-exact, same impl
            np.testing.assert_array_equal(
                got, chain(slide[roi.slices()], impl=gw.config.compute_impl)
            )
    stats = gw.stats.as_dict()
    assert stats["compute_served"] == stats["compute_requests"] > 0
    assert stats["raw_fetch_bytes"] > stats["derived_reply_bytes"]
    gw.close()


def test_submit_compute_typed_errors_raise_before_queueing():
    store, _ = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((0, 0, 0), (3, 32, 32))
    with pytest.raises(UnknownChainError):
        gw.submit_compute(_key(), roi, "no_such_chain")
    with pytest.raises(ChainParamError):
        gw.submit_compute(_key(), roi, "deconv|threshold", {"thr": 7.0})
    with pytest.raises(ChainParamError, match="rank"):
        gw.submit_compute(_key(), BoundingBox((0, 0), (32, 32)), "deconv")
    with pytest.raises(TypeError):
        gw.submit_compute(_key(), roi)  # chain missing
    assert gw.stats.compute_requests == 0  # nothing was admitted
    assert gw.queue_depth() == 0
    gw.close()


def test_compute_coalesces_overlapping_rois_one_window_fetch():
    """Overlapping compute ROIs merge into ONE store window fetch (fewer
    transport frames than naive per-ROI reads) while each member's chain
    still runs on its own slice — results stay bit-exact."""
    store, slide = _store()
    transport = store.tiers[0].backend.transport
    chain = resolve_chain("deconv|threshold")
    rois = [BoundingBox((0, 0, x), (3, 32, x + 32)) for x in range(0, 65, 16)]

    transport.reset()
    for roi in rois:
        store.get(_key(), roi)
    naive_frames = transport.stats.gets + transport.stats.meta_msgs

    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, batch_window=16, compute_cache_bytes=0)
    )
    gw.pause()  # queue the burst so one drain coalesces it
    tickets = [gw.submit_compute(_key(), roi, "deconv|threshold") for roi in rois]
    transport.reset()
    gw.resume()
    outs = [t.result(60.0) for t in tickets]
    gw_frames = transport.stats.gets + transport.stats.meta_msgs

    for roi, out in zip(rois, outs):
        np.testing.assert_array_equal(
            out, chain(slide[roi.slices()], impl=gw.config.compute_impl)
        )
    assert gw_frames < naive_frames, (gw_frames, naive_frames)
    assert gw.stats.compute_windows < len(rois)
    assert gw.stats.compute_coalesced >= len(rois)
    assert gw.stats.compute_window_fallbacks == 0
    gw.close()


def test_mixed_reads_and_computes_drain_into_separate_batches():
    """A read and a compute on the same key must not batch together (a
    window fetch answers reads; a kernel chain answers computes)."""
    store, slide = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((0, 0, 0), (3, 48, 48))
    chain = resolve_chain("deconv|threshold")
    gw.pause()
    t_read = gw.submit(_key(), roi)
    t_comp = gw.submit_compute(ComputeRequest(_key(), roi, "deconv|threshold"))
    gw.resume()
    np.testing.assert_array_equal(t_read.result(30.0), slide[roi.slices()])
    np.testing.assert_array_equal(
        t_comp.result(60.0), chain(slide[roi.slices()], impl=gw.config.compute_impl)
    )
    assert gw.stats.batches == 2
    assert gw.stats.served == 1 and gw.stats.compute_served == 1
    gw.close()


def test_reduced_chain_returns_feature_vector_not_region():
    store, slide = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((0, 0, 0), (3, H, W))
    count = gw.compute(_key(), roi, "deconv|threshold|ccl|count")
    assert count.shape == (1,) and count.dtype == np.int32
    chain = resolve_chain("deconv|threshold|ccl|count")
    np.testing.assert_array_equal(
        count, chain(slide, impl=gw.config.compute_impl)
    )
    assert count[0] > 0  # the blobs are there
    s = gw.stats.as_dict()
    assert s["raw_fetch_bytes"] >= 100 * s["derived_reply_bytes"]  # 4 B back
    gw.close()


# ---------------------------------------------------------------------------
# derived-product cache: hits, put-generation invalidation
# ---------------------------------------------------------------------------
def test_derived_cache_hits_and_invalidation_paths():
    store, slide = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((0, 0, 0), (3, 64, 64))
    chain = resolve_chain("deconv|threshold")

    first = gw.compute(_key(), roi, "deconv|threshold")
    again = gw.compute(_key(), roi, "deconv|threshold")
    np.testing.assert_array_equal(first, again)
    assert not np.shares_memory(first, again)  # callers never alias the cache
    assert gw.stats.compute_cache_hits == 1

    # a put THROUGH the gateway invalidates
    slide2 = slide.copy()
    slide2[:, :64, :64] = _stain_rgb(64, 64, seed=9)
    gw.put(_key(), BoundingBox((0, 0, 0), (3, 64, 64)), slide2[:, :64, :64])
    got = gw.compute(_key(), roi, "deconv|threshold")
    np.testing.assert_array_equal(
        got, chain(slide2[roi.slices()], impl=gw.config.compute_impl)
    )
    assert gw.stats.compute_cache_hits == 1  # miss: recomputed

    # a put BYPASSING the gateway is caught by TieredStore.generation
    gw.compute(_key(), roi, "deconv|threshold")  # re-warm (hit #2)
    assert gw.stats.compute_cache_hits == 2
    store.put(_key(), BoundingBox((0, 0, 0), (3, 64, 64)), slide[:, :64, :64])
    got = gw.compute(_key(), roi, "deconv|threshold")
    np.testing.assert_array_equal(
        got, chain(slide[roi.slices()], impl=gw.config.compute_impl)
    )
    assert gw.stats.compute_cache_hits == 2  # stale entry was a miss

    # different params -> different digest -> no false sharing
    gw.compute(_key(), roi, "deconv|threshold", {"thr": 0.4})
    assert gw.stats.compute_cache_hits == 2
    cache = gw.storage_stats()["compute"]["cache"]
    assert cache["entries"] >= 2 and cache["hits"] == 2
    gw.close()


def test_delete_invalidates_derived_products():
    store, _ = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((0, 0, 0), (3, 32, 32))
    gw.compute(_key(), roi, "deconv")
    gw.delete(_key())
    assert gw.storage_stats()["compute"]["cache"]["entries"] == 0
    with pytest.raises(KeyError):
        gw.compute(_key(), roi, "deconv")  # no ghost answers from the cache
    gw.close()


def test_cache_disabled_with_zero_budget():
    store, _ = _store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, compute_cache_bytes=0)
    )
    roi = BoundingBox((0, 0, 0), (3, 32, 32))
    gw.compute(_key(), roi, "deconv")
    gw.compute(_key(), roi, "deconv")
    assert gw.stats.compute_cache_hits == 0
    gw.close()


def test_concurrent_computes_and_writes_never_serve_stale(  # hammer
):
    """Writers flip the region between two versions while readers
    compute(); every answer must match ONE of the two versions' local
    chain output — never a mix and never a stale post-write hit that
    predates both."""
    store, slide = _store()
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    roi = BoundingBox((0, 0, 0), (3, 32, 32))
    chain = resolve_chain("deconv|threshold")
    v0 = slide[:, :32, :32].copy()
    v1 = _stain_rgb(32, 32, seed=7)
    want0 = chain(v0, impl=gw.config.compute_impl)
    want1 = chain(v1, impl=gw.config.compute_impl)
    box = BoundingBox((0, 0, 0), (3, 32, 32))
    errors = []
    stop = threading.Event()

    def writer():
        flip = False
        while not stop.is_set():
            gw.put(_key(), box, v1 if flip else v0)
            flip = not flip

    def reader():
        try:
            for _ in range(30):
                got = gw.compute(_key(), roi, "deconv|threshold")
                if not (np.array_equal(got, want0) or np.array_equal(got, want1)):
                    raise AssertionError("served a torn/stale derived product")
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    w = threading.Thread(target=writer)
    readers = [threading.Thread(target=reader) for _ in range(3)]
    w.start()
    for t in readers:
        t.start()
    for t in readers:
        t.join(timeout=120)
    stop.set()
    w.join(timeout=10)
    assert not errors, errors
    gw.close()


# ---------------------------------------------------------------------------
# wiring
# ---------------------------------------------------------------------------
def test_make_wsi_storage_compute_implies_serving_gateways():
    from repro.pipeline import make_wsi_storage

    reg = make_wsi_storage(64, 64, mode="tiered", compute=True, tile=32)
    gw3 = reg.get("DMS3")
    assert isinstance(gw3, RegionGateway)
    key = RegionKey("t", "RGB", ElementType.FLOAT32)
    dom3 = BoundingBox((0, 0, 0), (3, 64, 64))
    rgb = _stain_rgb(64, 64, seed=5)
    gw3.put(key, dom3, rgb)
    chain = resolve_chain("deconv|threshold")
    got = gw3.compute(key, dom3, "deconv|threshold")
    np.testing.assert_array_equal(got, chain(rgb, impl=gw3.config.compute_impl))
    assert "compute" in gw3.storage_stats()
    for name in ("DMS3", "DMS2"):
        reg.get(name).close()
