"""Manager-Worker runtime: demand-driven dispatch, fault tolerance."""
import threading
import time

import numpy as np
import pytest

from repro.core import BoundingBox, Intent, RegionTemplate, StorageRegistry
from repro.runtime import Stage, SysEnv, Task
from repro.storage import DistributedMemoryStorage

DOM = BoundingBox((0, 0), (64, 64))


class AddOne(Stage):
    def run(self, ctx):
        reg = ctx.region("P", "X")
        rt = self.get_region_template("P")
        out = rt.new_region("Y", reg.roi, np.float32)

        def work():
            out.set_data(np.asarray(reg.data) + 1)

        ctx.submit(Task("addone", cpu_fn=work))


def _env(**kw):
    reg = StorageRegistry()
    dms = reg.register(DistributedMemoryStorage(DOM, (32, 32), 2, name="DMS"))
    env = SysEnv(num_workers=2, cpus_per_worker=2, accels_per_worker=0,
                 registry=reg, **kw)
    return env, dms


def _wire(env, dms, n_parts=4, stage_cls=AddOne):
    rt = RegionTemplate("P")
    x = rt.new_region("X", DOM, np.float32, input_storage="DMS", lazy=True)
    data = np.random.default_rng(0).random((64, 64), dtype=np.float32)
    dms.put(x.key, DOM, data)
    stages = []
    for part in list(DOM.tiles((32, 32)))[:n_parts]:
        s = stage_cls()
        s.add_region_template(rt, "X", part, Intent.INPUT, read_storage="DMS")
        s.add_region_template(rt, "Y", part, Intent.OUTPUT, storage="DMS")
        env.execute_component(s)
        stages.append(s)
    return rt, data, stages


def test_e2e_pipeline_two_workers():
    env, dms = _env()
    rt, data, stages = _wire(env, dms)
    env.startup_execution()
    env.finalize_system()
    key = stages[0].templates["P"].get("Y").key
    assert np.allclose(dms.get(key, DOM), data + 1)
    # demand-driven: both workers should have gotten work
    dispatched = {pay[1] for ev, pay in env.manager.events if ev == "dispatch"}
    assert len(dispatched) >= 1


def test_stage_failure_retried_then_succeeds():
    attempts = []

    class Flaky(AddOne):
        def run(self, ctx):
            attempts.append(self.sid)
            if len(attempts) == 1:
                raise RuntimeError("transient")
            return super().run(ctx)

    env, dms = _env()
    _wire(env, dms, n_parts=1, stage_cls=Flaky)
    env.startup_execution()
    env.finalize_system()
    assert len(attempts) == 2  # failed once, re-ran elsewhere


def test_stage_failure_exhausts_retries():
    class AlwaysBad(Stage):
        def run(self, ctx):
            raise RuntimeError("permanent")

    env, dms = _env()
    rt = RegionTemplate("P")
    s = AlwaysBad()
    s.templates["P"] = rt
    env.execute_component(s)
    with pytest.raises(RuntimeError, match="failed after"):
        env.startup_execution()
    env.finalize_system()


def test_worker_death_requeues_inflight():
    """Node-failure fault tolerance: stages of a dead worker re-run."""
    release = []

    class Slow(AddOne):
        def run(self, ctx):
            while not release:
                time.sleep(0.01)
            return super().run(ctx)

    env, dms = _env(heartbeat_timeout=10.0)
    rt, data, stages = _wire(env, dms, n_parts=2, stage_cls=Slow)

    import threading

    def killer():
        time.sleep(0.3)
        env.workers[0].kill()  # node dies mid-stage
        time.sleep(0.1)
        release.append(1)

    threading.Thread(target=killer, daemon=True).start()
    env.startup_execution()
    env.finalize_system()
    key = stages[0].templates["P"].get("Y").key
    covered = BoundingBox((0, 0), (32, 64))  # the two dispatched partitions
    assert np.allclose(dms.get(key, covered), data[:32] + 1)
    events = [ev for ev, _ in env.manager.events]
    assert "requeue" in events


def test_incremental_dag_spawn():
    spawned = []

    class Parent(Stage):
        def run(self, ctx):
            child = AddOne()
            rt = self.get_region_template("P")
            child.add_region_template(rt, "X", self.bindings[0].roi, Intent.INPUT,
                                      read_storage="DMS")
            child.add_region_template(rt, "Y", self.bindings[0].roi, Intent.OUTPUT,
                                      storage="DMS")
            spawned.append(ctx.spawn_stage(child, deps=[self]))

    env, dms = _env()
    rt = RegionTemplate("P")
    x = rt.new_region("X", DOM, np.float32, input_storage="DMS", lazy=True)
    data = np.ones((64, 64), np.float32)
    dms.put(x.key, DOM, data)
    p = Parent()
    p.add_region_template(rt, "X", DOM, Intent.INPUT, read_storage="DMS")
    env.execute_component(p)
    env.startup_execution()
    env.finalize_system()
    assert spawned and spawned[0].state.name == "DONE"


def test_zombie_execution_does_not_poison_retry():
    """A stage killed AFTER it created its output region must retry
    cleanly: the zombie's mutated template copy must never leak into the
    retry (regression test for the thread-local template binding)."""
    entered = []
    release = []

    class CreatesThenBlocks(Stage):
        def run(self, ctx):
            rt = self.get_region_template("P")
            out = rt.new_region("Y", self.bindings[0].roi, np.float32)
            entered.append(threading.get_ident())
            if len(entered) == 1:  # first (to-be-killed) execution blocks
                while not release:
                    time.sleep(0.01)

            def work():
                out.set_data(np.ones(self.bindings[0].roi.shape, np.float32))

            ctx.submit(Task("mk", cpu_fn=work))

    env, dms = _env(heartbeat_timeout=10.0)
    rt = RegionTemplate("P")
    x = rt.new_region("X", DOM, np.float32, input_storage="DMS", lazy=True)
    dms.put(x.key, DOM, np.zeros((64, 64), np.float32))
    s = CreatesThenBlocks()
    part = BoundingBox((0, 0), (32, 32))
    s.add_region_template(rt, "X", part, Intent.INPUT, read_storage="DMS")
    s.add_region_template(rt, "Y", part, Intent.OUTPUT, storage="DMS")
    env.execute_component(s)

    def killer():
        while not entered:
            time.sleep(0.01)
        wid = s.worker
        env.workers[wid].kill()  # dies after new_region, before finishing
        time.sleep(0.05)
        release.append(1)

    threading.Thread(target=killer, daemon=True).start()
    env.startup_execution()  # must NOT raise duplicate-region failures
    env.finalize_system()
    key = s.templates["P"].get("Y").key
    assert (dms.get(key, part) == 1).all()
    # the shared manager-side template was never polluted
    assert "Y" not in rt.region_names() or rt.get("Y").empty()
