"""The paper's use-case pipeline on synthetic tiles: correctness + RT parity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.wsi import WSIConfig
from repro.core import BoundingBox, Intent, RegionTemplate, StorageRegistry
from repro.pipeline import (
    FeatureStage,
    SegmentationStage,
    analyze_tile,
    extract_object_rois,
    make_tile,
    segment_tile,
)
from repro.runtime import SysEnv
from repro.storage import DistributedMemoryStorage


@pytest.fixture(scope="module")
def tile():
    return make_tile(128, num_nuclei=8, seed=3)


def _iou(a, b):
    inter = np.logical_and(a, b).sum()
    union = np.logical_or(a, b).sum()
    return inter / max(union, 1)


def test_segmentation_recovers_nuclei(tile):
    rgb, gt = tile
    cfg = WSIConfig(seg_threshold=0.5)
    seg = segment_tile(jnp.asarray(rgb), cfg, impl="xla")
    mask = np.asarray(seg["mask"]) > 0
    assert _iou(mask, gt > 0) > 0.5
    labels = np.asarray(seg["labels"])
    n_objects = len(np.unique(labels[labels >= 0]))
    assert 3 <= n_objects <= 24  # ballpark of 8 seeded nuclei (some merge)


def test_full_tile_analysis_features(tile):
    rgb, _ = tile
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=32)
    out = analyze_tile(jnp.asarray(rgb), cfg, impl="xla")
    k = out["features"].shape[0]
    assert k == out["boxes"].shape[0] == out["rois"].shape[0]
    assert out["features"].shape[1] == 9
    assert np.isfinite(out["features"]).all()


def test_object_roi_extraction_fixed_size():
    labels = np.full((64, 64), -1, np.int32)
    labels[10:20, 10:20] = 0
    labels[40:50, 30:44] = 1
    intensity = np.random.default_rng(0).random((64, 64)).astype(np.float32)
    cfg = WSIConfig(nucleus_roi=16)
    rois, boxes = extract_object_rois(labels, intensity, cfg)
    assert rois.shape == (2, 16, 16)
    assert boxes.shape == (2, 4)
    assert (boxes[:, 2] <= 64).all() and (boxes[:, 3] <= 64).all()


def test_rt_two_stage_pipeline_matches_plain(tile):
    """RT-based Segmentation->Features == plain function pipeline (the
    precondition for the Fig. 11 overhead comparison)."""
    rgb, _ = tile
    h, w = rgb.shape[1:]
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=32)
    plain = analyze_tile(jnp.asarray(rgb), cfg, impl="xla")

    reg = StorageRegistry()
    dom3 = BoundingBox((0, 0, 0), (3, h, w))
    dom2 = BoundingBox((0, 0), (h, w))
    dms3 = reg.register(DistributedMemoryStorage(dom3, (3, h, w), 1, name="DMS3"))
    dms2 = reg.register(DistributedMemoryStorage(dom2, (h, w), 1, name="DMS2"))

    rt = RegionTemplate("Patient")
    rgb_region = rt.new_region("RGB", dom3, np.float32, input_storage="DMS3", lazy=True)
    dms3.put(rgb_region.key, dom3, np.asarray(rgb))

    env = SysEnv(num_workers=1, cpus_per_worker=2, accels_per_worker=1, registry=reg)
    seg = SegmentationStage(cfg, impl="xla")
    seg.add_region_template(rt, "RGB", dom3, Intent.INPUT, read_storage="DMS3")
    seg.add_region_template(rt, "Mask", dom2, Intent.OUTPUT, storage="DMS2")
    seg.add_region_template(rt, "Hema", dom2, Intent.OUTPUT, storage="DMS2")
    feat = FeatureStage(cfg, impl="xla")
    feat.add_region_template(rt, "Mask", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_region_template(rt, "Hema", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_dependency(seg)
    env.execute_component(seg)
    env.execute_component(feat)
    env.startup_execution()
    env.finalize_system()

    mask_key = seg.templates["Patient"].get("Mask").key
    got_mask = dms2.get(mask_key, dom2)
    np.testing.assert_array_equal(got_mask, np.asarray(plain["labels"]))

    feats_region = feat.templates["Patient"].get("Features")
    got = feats_region.data
    np.testing.assert_allclose(got["features"], plain["features"], rtol=1e-4, atol=1e-4)
    assert feats_region.num_objects == plain["features"].shape[0]
