"""Multi-host DMS transport: wire codec, Transport conformance, live
ServerProcess round-trips, shm zero-copy data plane, R-way replication +
failover chaos, tiered staging over sockets, WSI on sockets."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import (
    DistributedMemoryStorage,
    InProcTransport,
    MemoryTier,
    ShmTransport,
    SocketTransport,
    Tier,
    TieredStore,
    Transport,
    TransportError,
    decode_homes,
    spawn_servers,
)
from repro.storage.net import ServerProcess, decode_array, encode_array

# every test here spawns (or attaches to) real server processes — the
# fast unit CI leg deselects the whole module via `-m "not net"`
pytestmark = pytest.mark.net

# nightly chaos runs scale the kill/restart/hammer loops up without
# code changes (see .github/workflows/ci.yml chaos-nightly)
CHAOS_ITERS = max(1, int(os.environ.get("REPRO_CHAOS_ITERS", "1")))

DOM = BoundingBox((0, 0), (64, 64))


def _key(name="R", ts=0):
    return RegionKey("t", name, ElementType.FLOAT32, ts)


# ---------------------------------------------------------------------------
# shared fleet: 4 shards across 2 real server processes
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def group():
    g = spawn_servers(4, processes=2)
    assert len(g.procs) == 2 and g.num_servers == 4
    yield g
    g.close()


@pytest.fixture(params=["inproc", "socket", "shm"])
def transport(request, group):
    if request.param == "inproc":
        tr = InProcTransport(4)
        yield tr
    else:
        # "shm" runs the identical conformance suite over the negotiated
        # shared-memory data plane (fetches map the server arena instead
        # of riding the socket payload)
        tr = group.transport() if request.param == "socket" else group.transport(shm="require")
        # module-scoped servers: isolate tests by dropping our keys
        yield tr
        for sid in range(tr.num_servers):
            for key in tr.keys(sid):
                tr.drop(sid, key)
        tr.close()


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "arr",
    [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.arange(12, dtype=np.float16).reshape(3, 4),
        np.zeros((0, 5), np.float64),
        np.arange(24, dtype=np.int64).reshape(2, 3, 4)[:, :, ::2],  # non-contiguous
        np.asarray(np.random.default_rng(0).random((4, 4)) > 0.5),  # bool
        np.arange(6, dtype=np.uint8).reshape(6, 1, 1),  # trailing dims
    ],
    ids=["f32", "f16", "empty", "noncontig", "bool", "trailing"],
)
def test_array_codec_roundtrip(arr):
    meta, buf = encode_array(arr)
    back = decode_array(meta, bytearray(buf))
    assert back.dtype == arr.dtype
    assert back.shape == arr.shape
    np.testing.assert_array_equal(back, arr)


def test_array_codec_bfloat16():
    import jax.numpy as jnp

    arr = np.asarray(jnp.arange(8, dtype=jnp.bfloat16).reshape(2, 4))
    meta, buf = encode_array(arr)
    back = decode_array(meta, bytearray(buf))
    assert back.dtype == arr.dtype
    np.testing.assert_array_equal(back.astype(np.float32), arr.astype(np.float32))


# ---------------------------------------------------------------------------
# Transport conformance: InProcTransport and SocketTransport obey the same
# message API (the drop-in-swap guarantee under DistributedMemoryStorage)
# ---------------------------------------------------------------------------
def test_transport_protocol_conformance(transport):
    assert isinstance(transport, Transport)
    assert transport.num_servers == 4
    key = _key("conf")
    box = BoundingBox((0, 0), (8, 8))
    payload = np.random.default_rng(1).random((8, 8)).astype(np.float32)

    # store/fetch round-trip on every server
    for sid in range(transport.num_servers):
        transport.store(sid, key, (sid, 0), box, payload)
        got = transport.fetch(sid, key, (sid, 0))
        assert got.dtype == payload.dtype and got.shape == payload.shape
        np.testing.assert_array_equal(got, payload)

    # fetch of an absent block raises KeyError (not a transport failure)
    with pytest.raises(KeyError):
        transport.fetch(0, _key("absent"), (9, 9))

    # metadata: propagate to all, any directory answers, home preserved
    for sid in range(transport.num_servers):
        transport.put_meta(sid, key, (1, 2), box, home=3)
    looked = transport.lookup(2, key)
    assert looked[(1, 2)] == (box, 3)
    assert key in transport.keys(2)

    # batched metadata (what DMS.put sends): same directory semantics
    box2 = BoundingBox((8, 8), (16, 16))
    for sid in range(transport.num_servers):
        had = transport.put_meta_batch(
            sid, [(key, (3, 4), box, 1), (key, (5, 6), box2, 2)]
        )
        assert had == []  # fresh coords: empty pre-image
    looked = transport.lookup(0, key)
    assert looked[(3, 4)] == (box, 1) and looked[(5, 6)] == (box2, 2)
    # re-sending reports the coords that already existed (the pre-image
    # a failed put's rollback consults before dropping anything)
    assert transport.put_meta_batch(0, [(key, (3, 4), box, 1)]) == [(3, 4)]

    # byte accounting is real on every transport: the *_raw fields count
    # decoded array bytes regardless of data plane (shm fetches and
    # codec'd frames move fewer wire bytes, never fewer raw bytes)
    assert transport.stats.puts == 4
    assert transport.stats.gets >= 4
    assert transport.stats.bytes_put_raw >= 4 * payload.nbytes
    assert transport.stats.bytes_get_raw >= 4 * payload.nbytes
    assert transport.stats.bytes_put > 0 and transport.stats.bytes_get > 0
    assert transport.stats.meta_msgs >= 3
    assert transport.payload_bytes(0) >= payload.nbytes

    # drop removes payload + metadata
    for sid in range(transport.num_servers):
        transport.drop(sid, key)
    assert key not in transport.keys(2)
    with pytest.raises(KeyError):
        transport.fetch(0, key, (0, 0))


def test_fetch_many_conformance(transport):
    """Scatter-gather fetch: N blocks, ONE round-trip, bit-exact — the
    same contract over both transports (mixed dtypes/shapes in one frame
    exercise the concatenated-payload offsets)."""
    key = _key("fm")
    box = BoundingBox((0, 0), (8, 8))
    blocks = [
        np.random.default_rng(7).random((8, 8)).astype(np.float32),
        np.arange(12, dtype=np.float16).reshape(3, 4),
        np.zeros((0, 5), np.float64),  # empty payload mid-frame
        np.asarray(np.random.default_rng(8).random((4, 4)) > 0.5),  # bool
    ]
    for i, payload in enumerate(blocks):
        transport.store(1, key, (i, 0), box, payload)
    transport.reset()
    got = transport.fetch_many(1, [(key, (i, 0)) for i in range(len(blocks))])
    assert len(got) == len(blocks)
    for want, back in zip(blocks, got):
        assert back.dtype == want.dtype and back.shape == want.shape
        np.testing.assert_array_equal(back, want)
    # one round-trip for the whole gather, every payload byte accounted
    # (raw bytes: over the shm plane the wire only carries block refs)
    assert transport.stats.gets == 1
    assert transport.stats.bytes_get_raw >= sum(b.nbytes for b in blocks)
    # empty request list short-circuits (no wire traffic)
    transport.reset()
    assert transport.fetch_many(1, []) == []
    assert transport.stats.gets == 0
    # a missing block surfaces as KeyError, same as plain fetch
    with pytest.raises(KeyError):
        transport.fetch_many(1, [(key, (0, 0)), (_key("absent"), (9, 9))])
    for sid in range(transport.num_servers):
        transport.drop(sid, key)


def test_transport_mutation_safety(transport):
    """Resident blocks never alias client buffers: mutating the array a
    caller put (or the one it fetched back) must not corrupt the store —
    on BOTH transports (the socket copies bytes on the wire; the in-proc
    shards copy on store and hand out read-only views)."""
    key = _key("mut")
    box = BoundingBox((0, 0), (4, 4))
    original = np.arange(16, dtype=np.float32).reshape(4, 4)
    buf = original.copy()
    transport.store(0, key, (0, 0), box, buf)
    buf[:] = -1.0  # caller scribbles on its buffer after the put
    got = transport.fetch(0, key, (0, 0))
    np.testing.assert_array_equal(got, original)
    # scribbling on the fetched array either raises (read-only view) or
    # lands in a private copy — never in the store
    try:
        got[0, 0] = 99.0
    except ValueError:
        pass
    np.testing.assert_array_equal(transport.fetch(0, key, (0, 0)), original)
    # same guarantee through the scatter-gather path
    transport.store(0, key, (1, 0), box, original.copy())
    many = transport.fetch_many(0, [(key, (0, 0)), (key, (1, 0))])
    for blk in many:
        try:
            blk[0, 0] = 77.0
        except ValueError:
            pass
    for blk in transport.fetch_many(0, [(key, (0, 0)), (key, (1, 0))]):
        np.testing.assert_array_equal(blk, original)
    transport.drop(0, key)


def test_drop_block_conformance(transport):
    """drop_block removes ONE block's payload + directory entry and
    leaves siblings intact — the put-rollback primitive (a whole-key
    drop would destroy sibling blocks), same over both transports."""
    key = _key("db")
    box = BoundingBox((0, 0), (8, 8))
    a = np.ones((8, 8), np.float32)
    transport.store(0, key, (0, 0), box, a)
    transport.store(0, key, (1, 0), box, a)
    transport.put_meta_batch(0, [(key, (0, 0), box, 0), (key, (1, 0), box, 0)])
    transport.drop_block(0, key, (0, 0))
    with pytest.raises(KeyError):
        transport.fetch(0, key, (0, 0))
    np.testing.assert_array_equal(transport.fetch(0, key, (1, 0)), a)
    looked = transport.lookup(0, key)
    assert (0, 0) not in looked and (1, 0) in looked
    transport.drop_block(0, key, (9, 9))  # idempotent on absent blocks
    transport.drop_block(0, _key("nope"), (0, 0))  # and on absent keys
    transport.drop(0, key)
    assert key not in transport.keys(0)


def test_homes_metadata_roundtrip(transport):
    """Directory entries carry a single home (legacy int, preserved
    as-is) or a replica list; both transports round-trip both forms and
    ``decode_homes`` normalizes them."""
    key = _key("homes")
    box = BoundingBox((0, 0), (8, 8))
    box2 = BoundingBox((8, 8), (16, 16))
    transport.put_meta(0, key, (1, 2), box, 3)          # legacy single home
    transport.put_meta(0, key, (3, 4), box, [1, 3])     # replica set
    transport.put_meta_batch(
        0, [(key, (5, 6), box, 2), (key, (7, 8), box2, [0, 2])]
    )
    looked = transport.lookup(0, key)
    bb, h = looked[(1, 2)]
    assert bb == box and isinstance(h, int) and decode_homes(h) == (3,)
    assert decode_homes(looked[(3, 4)][1]) == (1, 3)
    assert decode_homes(looked[(5, 6)][1]) == (2,)
    assert looked[(7, 8)][0] == box2
    assert decode_homes(looked[(7, 8)][1]) == (0, 2)
    transport.drop(0, key)


def test_replication_wire_format_preserved_at_r1(group):
    """replication=1 must keep today's directory format byte-for-byte
    (bare-int homes); replication=2 records the full replica ring — over
    both transports."""
    arr = np.random.default_rng(11).random((64, 64)).astype(np.float32)
    for make_tr in (lambda: InProcTransport(4), group.transport):
        dms1 = DistributedMemoryStorage(DOM, (16, 16), transport=make_tr())
        dms1.put(_key("r1"), DOM, arr)
        for _, (_, h) in dms1.transport.lookup(1, _key("r1")).items():
            assert isinstance(h, int)  # legacy format, not a 1-list
        assert sum(dms1.server_load()) == arr.nbytes
        dms1.delete(_key("r1"))
        dms1.close()

        dms2 = DistributedMemoryStorage(
            DOM, (16, 16), transport=make_tr(), replication=2
        )
        dms2.put(_key("r2"), DOM, arr)
        eps = getattr(dms2.transport, "endpoints", None)
        for bc, (_, h) in dms2.transport.lookup(3, _key("r2")).items():
            homes = decode_homes(h)
            assert homes == dms2.replica_servers(bc)
            assert len(homes) == 2
            assert homes[0] == dms2.home_server(bc)
            if eps is None:
                assert homes[1] == (homes[0] + 1) % 4  # SFC-ring neighbor
            else:
                # the fleet packs 2 shards per process: the ring walk
                # must skip the co-located neighbor — replicas live in
                # distinct failure domains (processes)
                assert eps[homes[0]] != eps[homes[1]]
        # write amplification: every block resident on both replicas
        assert sum(dms2.server_load()) == 2 * arr.nbytes
        np.testing.assert_array_equal(dms2.get(_key("r2"), DOM), arr)
        dms2.delete(_key("r2"))
        dms2.close()


def test_dms_get_uses_scatter_gather_round_trips(group):
    """A multi-block DMS read costs one fetch_many per touched server,
    not one fetch per block — over both transports."""
    arr = np.random.default_rng(9).random((64, 64)).astype(np.float32)
    for tr in (InProcTransport(4), group.transport()):
        dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr)
        dms.put(_key("sg"), DOM, arr)  # 16 blocks over 4 servers
        tr.reset()
        np.testing.assert_array_equal(dms.get(_key("sg"), DOM), arr)
        # 1 lookup + at most one gather per server (16 blocks without
        # scatter-gather would be 16 gets)
        assert tr.stats.gets <= 4
        assert tr.stats.bytes_get >= arr.nbytes
        dms.delete(_key("sg"))
        dms.close()


def test_dms_identical_results_over_both_transports(group):
    arr = np.random.default_rng(2).random((64, 64)).astype(np.float32)
    rois = [DOM, BoundingBox((3, 7), (41, 64)), BoundingBox((17, 0), (18, 53))]
    results = []
    for tr in (InProcTransport(4), group.transport()):
        dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr)
        dms.put(_key(), DOM, arr)
        results.append([dms.get(_key(), roi) for roi in rois])
        dms.delete(_key())
        dms.close()
    for a, b in zip(*results):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# DMS over live server processes
# ---------------------------------------------------------------------------
def test_dms_put_get_bit_exact_across_processes(group):
    tr = group.transport()
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr, name="NETDMS")
    arr = np.random.default_rng(3).random((64, 64)).astype(np.float32)
    dms.put(_key("net"), DOM, arr)
    np.testing.assert_array_equal(dms.get(_key("net"), DOM), arr)
    roi = BoundingBox((9, 21), (40, 60))
    np.testing.assert_array_equal(dms.get(_key("net"), roi), arr[roi.slices()])
    # payload landed on real remote shards, balanced by the SFC partition
    load = dms.server_load()
    assert sum(load) == arr.nbytes
    assert min(load) > 0
    # every server process hosts two shards of the fleet
    assert sorted(tr.ping(0)) == [0, 1]
    assert sorted(tr.ping(2)) == [2, 3]
    dms.delete(_key("net"))
    dms.close()


def test_scoped_transports_isolate_stores_on_shared_fleet(group):
    """Two stores sharing one server fleet must not see each other's keys
    (the isolation separate InProcTransports give for free)."""
    a = DistributedMemoryStorage(DOM, (16, 16), transport=group.transport(scope="A"))
    b = DistributedMemoryStorage(DOM, (16, 16), transport=group.transport(scope="B"))
    arr = np.ones((64, 64), np.float32)
    a.put(_key("shared"), DOM, arr)
    assert b.query("t", "shared") == []  # b cannot see a's regions
    with pytest.raises(KeyError):
        b.get(_key("shared"), DOM)
    b.put(_key("shared"), DOM, 2 * arr)
    b.delete(_key("shared"))  # must not destroy a's copy
    np.testing.assert_array_equal(a.get(_key("shared"), DOM), arr)
    a.delete(_key("shared"))
    a.close()
    b.close()


def test_dms_query_and_versioning_over_socket(group):
    tr = group.transport()
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr)
    dms.put(_key("v", ts=0), DOM, np.zeros((64, 64), np.float32))
    dms.put(_key("v", ts=1), DOM, np.ones((64, 64), np.float32))
    found = dms.query("t", "v")
    assert [k.timestamp for k, _ in found] == [0, 1]
    assert (dms.get(_key("v", ts=1), DOM) == 1).all()
    dms.delete(_key("v", ts=0))
    assert len(dms.query("t", "v")) == 1
    dms.delete(_key("v", ts=1))
    dms.close()


def test_concurrent_put_get_hammer(group):
    """Many threads sharing one SocketTransport against live servers."""
    tr = group.transport()
    dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr)
    tiles = list(DOM.tiles((16, 16)))
    rng = np.random.default_rng(4)
    payloads = {i: rng.random((16, 16)).astype(np.float32) for i in range(len(tiles))}
    errors = []

    def worker(wid: int):
        try:
            key = _key(f"hammer{wid}")
            for rep in range(3 * CHAOS_ITERS):
                for i, bb in enumerate(tiles):
                    dms.put(key.at(i), bb, payloads[i])
                for i, bb in enumerate(tiles):
                    np.testing.assert_array_equal(dms.get(key.at(i), bb), payloads[i])
        except Exception as e:  # noqa: BLE001
            errors.append((wid, e))

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    wall = time.perf_counter() - t0
    assert not errors, errors
    # virtual_time is the union of on-the-wire intervals: it can never
    # exceed elapsed wall time, no matter how many threads overlap
    assert tr.virtual_time() <= wall * 1.05
    assert dms.aggregate_throughput() > 0
    for w in range(8):
        for i in range(len(tiles)):
            dms.delete(_key(f"hammer{w}").at(i))
    dms.close()


def test_server_restart_error_surfacing():
    """A killed server surfaces as TransportError; a fresh server on a new
    port is reachable through a fresh transport."""
    proc = ServerProcess([0]).start()
    tr = SocketTransport([proc.address], connect_timeout=5.0, op_timeout=10.0)
    box = BoundingBox((0, 0), (4, 4))
    payload = np.ones((4, 4), np.float32)
    tr.store(0, _key("crash"), (0, 0), box, payload)
    np.testing.assert_array_equal(tr.fetch(0, _key("crash"), (0, 0)), payload)

    proc.kill()
    assert not proc.alive()
    with pytest.raises((TransportError, ConnectionError)):
        tr.fetch(0, _key("crash"), (0, 0))
    # still down: reconnect attempt also surfaces, doesn't hang
    with pytest.raises((TransportError, ConnectionError)):
        tr.store(0, _key("crash"), (0, 0), box, payload)
    tr.close()

    fresh = ServerProcess([0]).start()
    try:
        tr2 = SocketTransport([fresh.address])
        # restarted server is empty: data did not silently survive
        with pytest.raises(KeyError):
            tr2.fetch(0, _key("crash"), (0, 0))
        tr2.store(0, _key("crash"), (0, 0), box, payload)
        np.testing.assert_array_equal(tr2.fetch(0, _key("crash"), (0, 0)), payload)
        tr2.close()
    finally:
        fresh.stop()


def test_server_process_kill_restart_reconnect():
    """stop()/kill() reset the handle, so the SAME ServerProcess restarts
    on its known port and the SAME transport reconnects once the liveness
    backoff expires — the crash-simulation primitive behind the failover
    tests."""
    proc = ServerProcess([0]).start()
    tr = SocketTransport(
        [proc.address], connect_timeout=5.0, op_timeout=10.0, dead_backoff=0.2
    )
    box = BoundingBox((0, 0), (4, 4))
    payload = np.ones((4, 4), np.float32)
    try:
        tr.store(0, _key("cycle"), (0, 0), box, payload)
        proc.kill()
        with pytest.raises(TransportError):
            tr.fetch(0, _key("cycle"), (0, 0))
        assert not tr.alive(0)  # liveness cache armed by the failure

        proc.start()  # restart on the same port: stop/kill reset the handle
        assert proc.alive()
        deadline = time.monotonic() + 15.0
        while True:  # backoff expiry + ping probe re-admit the host
            try:
                tr.store(0, _key("cycle"), (0, 0), box, payload)
                break
            except TransportError:
                assert time.monotonic() < deadline, "never reconnected"
                time.sleep(0.1)
        assert tr.alive(0)
        np.testing.assert_array_equal(tr.fetch(0, _key("cycle"), (0, 0)), payload)
    finally:
        tr.close()
        proc.stop()
    # a second start() after stop() must not raise "already started"
    proc.start()
    proc.stop()


def test_server_process_failed_start_is_retryable():
    """A child that dies before the LISTENING banner (e.g. port already
    bound) must leave the handle restartable, same as stop()/kill()."""
    import socket as pysock

    blocker = pysock.socket()
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    port = blocker.getsockname()[1]
    proc = ServerProcess([0], port=port)
    with pytest.raises(TransportError, match="failed to start"):
        proc.start()
    assert not proc.alive()
    blocker.close()
    proc.start()  # retry on the same handle, port now free
    try:
        tr = SocketTransport([proc.address])
        assert tr.ping(0) == [0]
        tr.close()
    finally:
        proc.stop()


def test_liveness_probe_recovers_within_backoff_window():
    """A transient failure must cost one probe, not dead_backoff seconds:
    the first request after a failure pings the host and proceeds if it
    answers — otherwise a blip on a block's LAST live replica would fail
    reads for the whole window."""
    proc = ServerProcess([0]).start()
    tr = SocketTransport(
        [proc.address], connect_timeout=5.0, op_timeout=10.0,
        dead_backoff=60.0, probe_timeout=2.0,
    )
    box = BoundingBox((0, 0), (4, 4))
    payload = np.ones((4, 4), np.float32)
    try:
        tr.store(0, _key("blip"), (0, 0), box, payload)
        proc.kill()
        with pytest.raises(TransportError):
            tr.fetch(0, _key("blip"), (0, 0))
        assert not tr.alive(0)
        proc.start()  # back on the same port well inside the 60s backoff
        # the very next request probes and succeeds — no 60s outage
        tr.store(0, _key("blip"), (0, 0), box, payload)
        np.testing.assert_array_equal(tr.fetch(0, _key("blip"), (0, 0)), payload)
        assert tr.alive(0)
        # a host that fails its probe DOES fail fast until the window ends
        proc.kill()
        with pytest.raises(TransportError):
            tr.fetch(0, _key("blip"), (0, 0))
        t0 = time.perf_counter()
        with pytest.raises(TransportError):  # probe fails: re-armed
            tr.fetch(0, _key("blip"), (0, 0))
        with pytest.raises(TransportError, match="backoff"):  # fail-fast now
            tr.fetch(0, _key("blip"), (0, 0))
        assert time.perf_counter() - t0 < 5.0  # never a full op_timeout
    finally:
        tr.close()
        proc.stop()


def test_socket_close_refuses_new_requests(group):
    tr = group.transport()
    tr.ping(0)
    tr.close()
    with pytest.raises(TransportError, match="closed"):
        tr.fetch(0, _key("closed"), (0, 0))
    with pytest.raises(TransportError, match="closed"):
        tr.store(
            0, _key("closed"), (0, 0), BoundingBox((0, 0), (2, 2)),
            np.zeros((2, 2), np.float32),
        )
    tr.close()  # idempotent


def test_socket_close_while_requests_in_flight(group):
    """close() takes the per-connection locks: concurrent requests either
    complete normally or surface as TransportError — never an arbitrary
    mid-frame OSError."""
    tr = group.transport()
    key = _key("inflight")
    box = BoundingBox((0, 0), (64, 64))
    payload = np.random.default_rng(12).random((64, 64)).astype(np.float32)
    tr.store(1, key, (0, 0), box, payload)
    stop = threading.Event()
    bad: list[BaseException] = []

    def reader():
        while not stop.is_set():
            try:
                tr.fetch(1, key, (0, 0))
            except TransportError:
                return  # expected once closed
            except BaseException as e:  # noqa: BLE001
                bad.append(e)
                return

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    tr.close()
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not bad, bad
    cleanup = group.transport()
    cleanup.drop(1, key)
    cleanup.close()


# ---------------------------------------------------------------------------
# chaos: R-way replication + failover reads on a real fleet
# ---------------------------------------------------------------------------
def test_chaos_replicated_reads_survive_server_kills():
    """The headline availability demo: a 4-process fleet (one shard per
    process so kills are independent) with replication=2 serves every
    read bit-exact after killing a non-zero host AND the host serving
    shard 0 (the old hardcoded directory pin), with the failovers visible
    in DMSStats."""
    fleet = spawn_servers(4)
    assert len(fleet.procs) == 4
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=60.0)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        keys = [_key("chaos", ts=t) for t in range(2)]
        rng = np.random.default_rng(13)
        arrays = [rng.random((64, 64)).astype(np.float32) for _ in keys]
        for k, a in zip(keys, arrays):
            dms.put(k, DOM, a)
        dms.put(_key("doomed"), DOM, arrays[0])  # read after the 3rd kill below
        rois = [DOM, BoundingBox((3, 7), (41, 64)), BoundingBox((17, 0), (18, 53))]
        for k, a in zip(keys, arrays):
            for roi in rois:
                np.testing.assert_array_equal(dms.get(k, roi), a[roi.slices()])

        # kill a non-zero host: its blocks regroup onto ring neighbors
        fleet.procs[2].kill()
        for _ in range(CHAOS_ITERS):
            for k, a in zip(keys, arrays):
                for roi in rois:
                    np.testing.assert_array_equal(dms.get(k, roi), a[roi.slices()])
        assert dms.stats.failover_fetches > 0
        # the dead host was discovered either by a fetch error or by a
        # directory lookup that failed over (both arm the liveness cache)
        assert dms.stats.failed_servers + dms.stats.directory_retries >= 1

        # a put whose replica pair avoids the dead host still works end
        # to end: the metadata broadcast skips the unreachable directory
        # instead of failing the put
        bc = next(
            tuple(c) for c in np.ndindex(4, 4)
            if 2 not in dms.replica_servers(tuple(c))
        )
        patch = BoundingBox(
            tuple(16 * x for x in bc), tuple(16 * x + 16 for x in bc)
        )
        extra = rng.random((16, 16)).astype(np.float32)
        dms.put(_key("late"), patch, extra)
        np.testing.assert_array_equal(dms.get(_key("late"), patch), extra)
        assert dms.stats.meta_broadcast_skips > 0
        dms.delete(_key("late"))

        # kill the host serving shard 0 as well (0 and 2 are not ring
        # neighbors, so every block still has one live replica) — the
        # directory rotation must also route around it
        fleet.procs[0].kill()
        for _ in range(CHAOS_ITERS):
            for k, a in zip(keys, arrays):
                for roi in rois:
                    np.testing.assert_array_equal(dms.get(k, roi), a[roi.slices()])
        found = dms.query("t", "chaos")  # tolerates the dead servers
        assert [k.timestamp for k, _ in found] == [0, 1]

        for k in keys:  # best-effort delete skips the dead hosts
            dms.delete(k)
        assert dms.stats.delete_skips > 0
        assert dms.query("t", "chaos") == []

        # a third kill leaves some blocks with no live replica at all:
        # the failure is explicit and names the replicas, not a hang
        fleet.procs[1].kill()
        with pytest.raises(TransportError, match="replica"):
            dms.get(_key("doomed"), DOM)
        dms.close()
    finally:
        fleet.close()


def test_chaos_reads_survive_process_kill_with_colocated_shards():
    """The default deployment packs several shards per process
    (spawn_servers(4, processes=2)); replica placement must put the two
    copies in DIFFERENT processes, or one process crash silently takes
    both.  Killing either process must leave every block readable."""
    fleet = spawn_servers(4, processes=2)
    assert len(fleet.procs) == 2  # shards {0,1} and {2,3} share a process
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=60.0)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        arr = np.random.default_rng(16).random((64, 64)).astype(np.float32)
        dms.put(_key("coloc"), DOM, arr)
        for bc, (_, h) in tr.lookup(0, _key("coloc")).items():
            a, b = decode_homes(h)
            assert tr.endpoints[a] != tr.endpoints[b], (bc, a, b)
        fleet.procs[0].kill()  # shards 0 AND 1 die together
        np.testing.assert_array_equal(dms.get(_key("coloc"), DOM), arr)
        assert dms.stats.failover_fetches > 0
        dms.close()
    finally:
        fleet.close()


def test_chaos_reads_survive_server_rejoining_empty():
    """A crashed server restarted on the same port rejoins REACHABLE but
    empty: its remote KeyErrors and empty directory answers must fail
    over to the healthy replicas, not leak to the caller."""
    fleet = spawn_servers(4)
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=0.2)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        arr = np.random.default_rng(14).random((64, 64)).astype(np.float32)
        dms.put(_key("rejoin"), DOM, arr)

        fleet.procs[2].kill()
        np.testing.assert_array_equal(dms.get(_key("rejoin"), DOM), arr)
        fleet.procs[2].start()  # same port, empty shard
        deadline = time.monotonic() + 15.0
        while not tr.alive(2) and time.monotonic() < deadline:
            try:
                tr.ping(2)
            except TransportError:
                time.sleep(0.1)
        # enough reads to cycle the directory rotor over every server
        # (including the empty one) and to route fetches at its shard
        for _ in range(8):
            np.testing.assert_array_equal(dms.get(_key("rejoin"), DOM), arr)
        assert dms.stats.empty_reroutes > 0  # the rejoined shard was rerouted past
        found = dms.query("t", "rejoin")  # empty directory answer not trusted
        assert len(found) == 1

        # a LATER sub-ROI re-put of the same key gives the rejoined
        # server a non-empty but PARTIAL directory (only the patch
        # block); it must not shadow the healthy servers' full ones —
        # the cross-directory union repairs the coverage hole — and the
        # blocks the rejoined server received post-rejoin must still
        # serve from it
        patch = BoundingBox((0, 0), (16, 16))
        arr[:16, :16] = 7.0
        dms.put(_key("rejoin"), patch, arr[:16, :16])
        other = np.random.default_rng(15).random((64, 64)).astype(np.float32)
        dms.put(_key("rejoin", ts=1), DOM, other)
        # consecutive same-key reads so the lookup rotation start sweeps
        # every server (interleaving two keys would advance the rotor by
        # 2 per key and could skip the stale directory forever)
        for _ in range(8):
            np.testing.assert_array_equal(dms.get(_key("rejoin"), DOM), arr)
        for _ in range(8):
            np.testing.assert_array_equal(dms.get(_key("rejoin", ts=1), DOM), other)
        assert dms.stats.directory_repairs > 0
        # the stale server can neither hide a timestamp (keys union) nor
        # shrink the reported extents (per-key lookup union) — callers
        # like TieredStore size cross-tier reads off these boxes
        for _ in range(4):  # sweep the rotor across the stale directory
            found = dms.query("t", "rejoin")
            assert [k.timestamp for k, _ in found] == [0, 1]
            assert all(bb == DOM for _, bb in found)
        dms.delete(_key("rejoin"))
        dms.delete(_key("rejoin", ts=1))
        dms.close()
    finally:
        fleet.close()


def test_chaos_writes_survive_server_kill_and_repair_heals_rejoin():
    """The write-path acceptance demo: a 4-process fleet with R=2 runs a
    mixed put/get workload while a server is killed mid-workload — ZERO
    failed puts, ZERO failed gets, bit-exact reads (puts re-home blocks
    past the dead server along the ring) — then the server restarts
    EMPTY on the same port and repair() converges the fleet back to two
    live, directory-confirmed copies of every block."""
    fleet = spawn_servers(4)
    assert len(fleet.procs) == 4
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=0.5)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        rng = np.random.default_rng(30)
        arrays: dict = {}

        def step(i: int) -> None:
            k = _key("wchaos", ts=i)
            a = rng.random((64, 64)).astype(np.float32)
            dms.put(k, DOM, a)  # a failed put would raise here
            arrays[k] = a
            for k2, a2 in arrays.items():  # and a failed get here
                np.testing.assert_array_equal(dms.get(k2, DOM), a2)

        for i in range(3):
            step(i)
        fleet.procs[1].kill()  # mid-workload: half the replica pairs touch it
        for i in range(3, 3 + 5 * CHAOS_ITERS):
            step(i)
        assert dms.stats.put_failovers > 0  # writes re-homed, none failed
        # every post-kill placement avoids the dead server
        directory = tr.lookup(0, _key("wchaos", ts=5))
        assert len(directory) == 16
        for _, (_, h) in directory.items():
            assert 1 not in decode_homes(h)

        # restart empty on the same port, wait for the liveness cache
        fleet.procs[1].start()
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            try:
                tr.ping(1)
                break
            except TransportError:
                time.sleep(0.1)
        report = dms.repair()
        assert report["lost"] == 0
        assert report["repaired"] > 0  # pre-kill blocks homed on 1 re-filled
        # convergence proof: every directory entry of every key names two
        # replicas whose own shards serve the block
        for k in arrays:
            assert len(tr.lookup(1, k)) == 16  # rejoined directory complete
            for bc, (_, h) in tr.lookup(2, k).items():
                homes = decode_homes(h)
                assert len(homes) == 2
                for sid in homes:
                    assert tr.fetch(sid, k, bc) is not None
        assert dms.repair()["repaired"] == 0  # second sweep: nothing left
        # the workload (including reads of pre-kill data) continues green
        last = 3 + 5 * CHAOS_ITERS
        for i in range(last, last + 2):
            step(i)
        dms.close()
    finally:
        fleet.close()


def test_replication_one_dead_server_still_fails():
    """replication=1 preserves today's behavior: a dead home server means
    the read fails (that is exactly what R buys you)."""
    proc = ServerProcess([0]).start()
    tr = SocketTransport(
        [proc.address], connect_timeout=5.0, op_timeout=10.0, dead_backoff=0.1
    )
    dms = DistributedMemoryStorage(
        BoundingBox((0, 0), (16, 16)), (16, 16), transport=tr
    )
    arr = np.ones((16, 16), np.float32)
    dms.put(_key("r1dead"), BoundingBox((0, 0), (16, 16)), arr)
    proc.kill()
    with pytest.raises(TransportError):
        dms.get(_key("r1dead"), BoundingBox((0, 0), (16, 16)))
    dms.close()


# ---------------------------------------------------------------------------
# tiered staging over the socket tier
# ---------------------------------------------------------------------------
def test_tiered_store_demotes_and_flushes_through_socket_tier(group, tmp_path):
    tile_bytes = 16 * 16 * 4
    store = TieredStore(
        [
            Tier("MEM", MemoryTier(name="MEM"), 2 * tile_bytes),
            Tier(
                "DMS",
                DistributedMemoryStorage(
                    DOM, (16, 16), 4, name="NET-DMS", transport=group.transport()
                ),
            ),
        ],
        name="NETTIER",
        write_policy="write_back",
    )
    tiles = list(DOM.tiles((16, 16)))
    rng = np.random.default_rng(5)
    payloads = [rng.random((16, 16)).astype(np.float32) for _ in tiles]
    keys = [_key("spill").at(i) for i in range(len(tiles))]
    for k, bb, a in zip(keys, tiles, payloads):
        store.put(k, bb, a)
    store.flush()  # write-backs reach the socket tier
    # capacity 2 tiles -> most keys were demoted over the wire
    stats = store.tier_stats()
    assert stats["MEM"].demotions > 0
    assert store.used_bytes("MEM") <= 2 * tile_bytes
    # every key still reads back bit-exact (MEM hit or socket fetch)
    for k, bb, a in zip(keys, tiles, payloads):
        np.testing.assert_array_equal(store.get(k, bb), a)
    # the cold ones are DMS-resident and the network tier answers locality
    locs = {store.locality(k) for k in keys}
    assert "DMS" in locs
    store.drain()  # push-down: bottom tier holds everything
    for k in keys:
        assert not store.dirty(k)
    dms = store.tiers[1].backend
    assert sum(dms.server_load()) >= len(tiles) * tile_bytes
    for k in keys:
        store.delete(k)
    store.close()  # closes the socket transport too


def test_make_wsi_storage_socket_tiered(group):
    """The opt-in pipeline wiring: make_wsi_storage(mode='tiered',
    transport='socket') against an already-running fleet."""
    from repro.pipeline import make_wsi_storage

    reg = make_wsi_storage(
        64, 64, mode="tiered", transport="socket", endpoints=group.endpoints, tile=32
    )
    store3 = reg.get("DMS3")
    dms3 = store3.tiers[2].backend
    assert type(dms3.transport).__name__ == "SocketTransport"
    key = RegionKey("t", "RGB", ElementType.FLOAT32)
    dom3 = BoundingBox((0, 0, 0), (3, 64, 64))
    rgb = np.random.default_rng(6).random((3, 64, 64)).astype(np.float32)
    store3.put(key, dom3, rgb)
    np.testing.assert_array_equal(store3.get(key, dom3), rgb)
    store3.drain()  # reaches the socket-backed DMS tier
    assert not store3.dirty(key)
    store3.delete(key)
    for name in ("DMS3", "DMS2"):
        reg.get(name).close()

    # endpoints without transport="socket" is a deployment mistake, not a
    # silent fallback to in-process shards
    with pytest.raises(ValueError, match="transport='socket'"):
        make_wsi_storage(64, 64, mode="tiered", endpoints=group.endpoints)


def test_wsi_pipeline_green_on_socket_transport(group):
    """End-to-end: the RT two-stage pipeline over socket-backed storage
    matches the plain-function pipeline."""
    import jax.numpy as jnp

    from repro.configs.wsi import WSIConfig
    from repro.core import Intent, RegionTemplate
    from repro.pipeline import FeatureStage, SegmentationStage, analyze_tile, make_tile
    from repro.pipeline import make_wsi_storage
    from repro.runtime import SysEnv

    rgb, _ = make_tile(64, num_nuclei=4, seed=7)
    h, w = rgb.shape[1:]
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=16)
    plain = analyze_tile(jnp.asarray(rgb), cfg, impl="xla")

    reg = make_wsi_storage(
        h, w, mode="tiered", transport="socket", endpoints=group.endpoints
    )
    dom3 = BoundingBox((0, 0, 0), (3, h, w))
    dom2 = BoundingBox((0, 0), (h, w))
    rt = RegionTemplate("Patient")
    rgb_region = rt.new_region("RGB", dom3, np.float32, input_storage="DMS3", lazy=True)
    reg.get("DMS3").put(rgb_region.key, dom3, np.asarray(rgb))

    env = SysEnv(num_workers=1, cpus_per_worker=2, accels_per_worker=1, registry=reg)
    seg = SegmentationStage(cfg, impl="xla")
    seg.add_region_template(rt, "RGB", dom3, Intent.INPUT, read_storage="DMS3")
    seg.add_region_template(rt, "Mask", dom2, Intent.OUTPUT, storage="DMS2")
    seg.add_region_template(rt, "Hema", dom2, Intent.OUTPUT, storage="DMS2")
    feat = FeatureStage(cfg, impl="xla")
    feat.add_region_template(rt, "Mask", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_region_template(rt, "Hema", dom2, Intent.INPUT, read_storage="DMS2")
    feat.add_dependency(seg)
    env.execute_component(seg)
    env.execute_component(feat)
    env.startup_execution()
    env.finalize_system()

    mask_key = seg.templates["Patient"].get("Mask").key
    got_mask = reg.get("DMS2").get(mask_key, dom2)
    np.testing.assert_array_equal(got_mask, np.asarray(plain["labels"]))
    feats_region = feat.templates["Patient"].get("Features")
    np.testing.assert_allclose(
        feats_region.data["features"], plain["features"], rtol=1e-4, atol=1e-4
    )
    for name in ("DMS3", "DMS2"):
        reg.get(name).close()


# ---------------------------------------------------------------------------
# regression: overlapping re-put chunks must not double-count coverage
# (ROADMAP open item: per-chunk volume counters -> mask-based _assemble)
# ---------------------------------------------------------------------------
def test_disk_overlap_coverage_is_mask_based(tmp_path):
    """Two overlapping puts whose volumes sum to the ROI volume but leave
    a hole: the old per-chunk counters accepted this and served zeros."""
    from repro.storage import DiskStorage

    disk = DiskStorage(str(tmp_path), name="DISK")
    a = np.ones((32, 64), np.float32)
    disk.put(_key("hole"), BoundingBox((0, 0), (32, 64)), a)
    disk.put(_key("hole"), BoundingBox((16, 0), (48, 64)), a)
    # chunk volumes sum to 64*64 == DOM volume, but rows 48..64 are a hole
    with pytest.raises(KeyError):
        disk.get(_key("hole"), DOM)
    got = disk.get(_key("hole"), BoundingBox((0, 0), (48, 64)))
    assert (got == 1).all()


def test_dms_partial_coverage_still_raises(group):
    """Same contract over both transports: holes surface as KeyError."""
    for tr in (InProcTransport(4), group.transport()):
        dms = DistributedMemoryStorage(DOM, (16, 16), 4, transport=tr)
        a = np.ones((32, 64), np.float32)
        dms.put(_key("hole"), BoundingBox((0, 0), (32, 64)), a)
        dms.put(_key("hole"), BoundingBox((16, 0), (48, 64)), a)
        # covered rows: 0..48 of 64 -> full-domain read must fail
        with pytest.raises(KeyError):
            dms.get(_key("hole"), DOM)
        got = dms.get(_key("hole"), BoundingBox((0, 0), (48, 64)))
        assert (got == 1).all()
        dms.delete(_key("hole"))
        dms.close()


# ---------------------------------------------------------------------------
# shared-memory data plane: negotiation, zero-copy views, promotion,
# exhaustion fallback
# ---------------------------------------------------------------------------
def test_shm_negotiation_moves_payloads_off_the_wire(group):
    """A co-located client that negotiates shm fetches blocks out of the
    server arena: stats count the fetch raw bytes in full while the wire
    carries only the block ref (order-of-magnitude smaller)."""
    tr = group.transport(shm="require")
    key = _key("shmneg")
    box = BoundingBox((0, 0), (64, 64))
    payload = np.random.default_rng(21).random((64, 64)).astype(np.float32)
    try:
        tr.store(0, key, (0, 0), box, payload)
        tr.reset()
        got = tr.fetch(0, key, (0, 0))
        np.testing.assert_array_equal(got, payload)
        assert tr.stats.shm_gets == 1
        assert tr.stats.bytes_get_raw >= payload.nbytes
        assert tr.stats.bytes_get < payload.nbytes // 4  # ref, not payload
        # default mode hands out private copies: scribbling is safe
        got[0, 0] = -99.0
        np.testing.assert_array_equal(tr.fetch(0, key, (0, 0)), payload)
    finally:
        tr.drop(0, key)
        tr.close()


def test_shm_zero_copy_views_are_read_only(group):
    """zero_copy=True maps the arena block directly: the view is
    read-only (the store stays uncorruptible) and still bit-exact."""
    tr = group.transport(shm="require", zero_copy=True)
    key = _key("shmzc")
    box = BoundingBox((0, 0), (64, 64))
    payload = np.random.default_rng(22).random((64, 64)).astype(np.float32)
    try:
        tr.store(1, key, (0, 0), box, payload)
        got = tr.fetch(1, key, (0, 0))
        np.testing.assert_array_equal(got, payload)
        with pytest.raises(ValueError):
            got[0, 0] = 1.0
        # scatter-gather rides the same plane
        tr.store(1, key, (1, 0), box, 2 * payload)
        many = tr.fetch_many(1, [(key, (0, 0)), (key, (1, 0))])
        np.testing.assert_array_equal(many[0], payload)
        np.testing.assert_array_equal(many[1], 2 * payload)
        assert tr.stats.shm_gets >= 3
    finally:
        tr.drop(1, key)
        tr.close()


def test_shm_promotion_on_fetch_from_plain_store(group):
    """Blocks stored by a plain client are promoted into the arena when
    an shm client fetches them — the data plane is per-reader, not
    per-writer."""
    plain = group.transport()
    shm = group.transport(shm="require")
    key = _key("promote")
    box = BoundingBox((0, 0), (32, 32))
    payload = np.random.default_rng(23).random((32, 32)).astype(np.float32)
    try:
        plain.store(2, key, (0, 0), box, payload)
        got = shm.fetch(2, key, (0, 0))
        np.testing.assert_array_equal(got, payload)
        assert shm.stats.shm_gets == 1
    finally:
        plain.drop(2, key)
        plain.close()
        shm.close()


def test_shm_arena_exhaustion_falls_back_to_socket():
    """A block that does not fit the arena still serves bit-exact over
    the socket payload path — shm is an optimization, never a capacity
    limit."""
    proc = ServerProcess([0], arena_bytes=1 << 20).start()  # 1 MB arena
    try:
        tr = ShmTransport([proc.address])
        box = BoundingBox((0, 0), (1024, 1024))
        big = np.random.default_rng(24).random((1024, 1024)).astype(np.float32)  # 4 MB
        small = np.ones((64, 64), np.float32)  # 16 KB: fits
        tr.store(0, _key("big"), (0, 0), box, big)
        tr.store(0, _key("small"), (0, 0), BoundingBox((0, 0), (64, 64)), small)
        np.testing.assert_array_equal(tr.fetch(0, _key("big"), (0, 0)), big)
        np.testing.assert_array_equal(tr.fetch(0, _key("small"), (0, 0)), small)
        assert tr.stats.shm_gets >= 1  # the small block rode the arena
        tr.close()
    finally:
        proc.stop()


def test_shm_require_fails_against_compat_server():
    """shm='require' against a server that cannot negotiate (pre-codec
    wire protocol) surfaces as TransportError, not a silent downgrade."""
    proc = ServerProcess([0], extra_env={"REPRO_NET_COMPAT": "1"}).start()
    try:
        tr = ShmTransport([proc.address], connect_timeout=5.0, op_timeout=10.0)
        with pytest.raises(TransportError):
            tr.ping(0)
        tr.close()
    finally:
        proc.stop()


def test_dms_bit_exact_over_shm_transport(group):
    """Full DMS put/get over the shm data plane matches the array."""
    dms = DistributedMemoryStorage(
        DOM, (16, 16), 4, transport=group.transport(shm="require")
    )
    arr = np.random.default_rng(25).random((64, 64)).astype(np.float32)
    dms.put(_key("shmdms"), DOM, arr)
    np.testing.assert_array_equal(dms.get(_key("shmdms"), DOM), arr)
    roi = BoundingBox((5, 9), (61, 47))
    np.testing.assert_array_equal(dms.get(_key("shmdms"), roi), arr[roi.slices()])
    assert dms.transport.stats.shm_gets > 0
    dms.delete(_key("shmdms"))
    dms.close()


# ---------------------------------------------------------------------------
# wire codecs over live servers + mixed codec-vs-plain fleets
# ---------------------------------------------------------------------------
def _codec_arrays(rng):
    import jax.numpy as jnp

    return {
        "f32": rng.random((32, 32)).astype(np.float32),
        "f16": rng.random((16, 16)).astype(np.float16),
        "bf16": np.asarray(jnp.arange(64, dtype=jnp.bfloat16).reshape(8, 8)),
        "u8_labels": rng.integers(0, 8, (64, 64)).astype(np.uint8),
        "empty": np.zeros((0, 5), np.float32),
        "noncontig": rng.random((8, 8, 8)).astype(np.float64)[:, ::2, :],
        "bool": rng.random((16, 16)) > 0.5,
    }


@pytest.mark.parametrize("codec", ["zlib", "bf16", "int8"])
def test_wire_codec_roundtrip_over_socket(group, codec):
    """Every codec round-trips every dtype over a live fleet: lossless
    codecs bit-exact, lossy ones within tolerance on f32/f64 and
    bit-exact on everything else (they degrade to zlib off-dtype)."""
    tr = group.transport(wire_codec=codec)
    box = BoundingBox((0, 0), (64, 64))
    arrays = _codec_arrays(np.random.default_rng(26))
    key = _key(f"codec_{codec}")
    try:
        for i, (name, arr) in enumerate(arrays.items()):
            tr.store(0, key, (i, 0), box, arr)
            got = tr.fetch(0, key, (i, 0))
            assert got.dtype == arr.dtype, name
            assert got.shape == arr.shape, name
            lossy = (
                arr.size > 0
                and codec in ("bf16", "int8")
                and arr.dtype in (np.float32, np.float64)
            )
            if lossy:
                atol = 0.02 if codec == "bf16" else float(np.abs(arr).max()) / 127 + 1e-6
                np.testing.assert_allclose(
                    got.astype(np.float64), arr.astype(np.float64), atol=atol
                )
            else:
                np.testing.assert_array_equal(got, arr)
        # the whole matrix again through scatter-gather
        many = tr.fetch_many(0, [(key, (i, 0)) for i in range(len(arrays))])
        for got, arr in zip(many, arrays.values()):
            assert got.dtype == arr.dtype and got.shape == arr.shape
    finally:
        tr.drop(0, key)
        tr.close()


def test_zlib_codec_reduces_wire_bytes_on_label_tiles(group):
    """The acceptance claim at test scale: compressible uint8 label
    tiles move >=30% fewer wire bytes than raw under the zlib codec,
    bit-exact."""
    tr = group.transport(wire_codec="zlib")
    key = _key("labels")
    box = BoundingBox((0, 0), (64, 64))
    tile = np.kron(
        np.random.default_rng(27).integers(0, 8, (8, 8)).astype(np.uint8),
        np.ones((8, 8), np.uint8),
    )
    try:
        tr.store(3, key, (0, 0), box, tile)
        tr.reset()
        got = tr.fetch(3, key, (0, 0))
        np.testing.assert_array_equal(got, tile)
        assert tr.stats.bytes_get_raw >= tile.nbytes
        assert tr.stats.bytes_get < 0.7 * tr.stats.bytes_get_raw
    finally:
        tr.drop(3, key)
        tr.close()


def test_mixed_fleet_old_server_new_client_degrades_to_plain():
    """A codec/shm client against a pre-codec server: the failed hello
    downgrades the connection to the legacy wire format — round-trips
    stay bit-exact, nothing is compressed."""
    proc = ServerProcess([0], extra_env={"REPRO_NET_COMPAT": "1"}).start()
    try:
        tr = SocketTransport(
            [proc.address], wire_codec="zlib", shm="auto",
            connect_timeout=5.0, op_timeout=10.0,
        )
        box = BoundingBox((0, 0), (64, 64))
        payload = np.random.default_rng(28).integers(0, 8, (64, 64)).astype(np.uint8)
        tr.store(0, _key("compat"), (0, 0), box, payload)
        np.testing.assert_array_equal(tr.fetch(0, _key("compat"), (0, 0)), payload)
        assert tr.stats.shm_gets == 0
        # no codec on the wire: wire bytes >= raw bytes both directions
        assert tr.stats.bytes_put >= tr.stats.bytes_put_raw
        assert tr.stats.bytes_get >= tr.stats.bytes_get_raw
        many = tr.fetch_many(0, [(_key("compat"), (0, 0))])
        np.testing.assert_array_equal(many[0], payload)
        tr.close()
    finally:
        proc.stop()


def test_mixed_fleet_new_server_old_client_stays_legacy():
    """A plain client (no codec, no shm — i.e. yesterday's build) against
    a new server: no hello is sent, frames are the legacy format, blocks
    round-trip bit-exact — including blocks STORED by a codec client."""
    proc = ServerProcess([0]).start()
    try:
        old = SocketTransport([proc.address])
        new = SocketTransport([proc.address], wire_codec="zlib")
        box = BoundingBox((0, 0), (64, 64))
        payload = np.random.default_rng(29).integers(0, 8, (64, 64)).astype(np.uint8)
        # codec client writes, plain client reads
        new.store(0, _key("x"), (0, 0), box, payload)
        np.testing.assert_array_equal(old.fetch(0, _key("x"), (0, 0)), payload)
        # plain client writes, codec client reads
        old.store(0, _key("y"), (0, 0), box, 2 * payload)
        np.testing.assert_array_equal(new.fetch(0, _key("y"), (0, 0)), 2 * payload)
        old.close()
        new.close()
    finally:
        proc.stop()


def test_at_rest_compression_keeps_blocks_small_and_readable():
    """at_rest=True keeps losslessly-codec'd puts resident in compressed
    form: shard payload bytes shrink, and a PLAIN client still reads the
    block bit-exact (the server re-encodes per reader)."""
    proc = ServerProcess([0], at_rest=True).start()
    try:
        zl = SocketTransport([proc.address], wire_codec="zlib")
        box = BoundingBox((0, 0), (128, 128))
        tile = np.kron(
            np.random.default_rng(31).integers(0, 8, (16, 16)).astype(np.uint8),
            np.ones((8, 8), np.uint8),
        )
        zl.store(0, _key("rest"), (0, 0), box, tile)
        assert zl.payload_bytes(0) < tile.nbytes // 2  # resident compressed
        np.testing.assert_array_equal(zl.fetch(0, _key("rest"), (0, 0)), tile)
        plain = SocketTransport([proc.address])
        np.testing.assert_array_equal(plain.fetch(0, _key("rest"), (0, 0)), tile)
        # lossy-codec and plain puts stay raw-resident (lossy at rest
        # would corrupt the only copy)
        plain.store(0, _key("rawres"), (0, 0), box, tile)
        assert plain.payload_bytes(0) >= tile.nbytes
        zl.close()
        plain.close()
    finally:
        proc.stop()


# ---------------------------------------------------------------------------
# elastic fleet: live join/leave over real sockets
# ---------------------------------------------------------------------------
def test_elastic_chaos_join_and_leave_mid_workload():
    """The elastic acceptance demo: a 4-process fleet with replication=2
    grows by one server and then drains a founding member WHILE reader
    threads hammer the store — zero failed ops, every read bit-exact,
    and both paced sweeps report zero lost blocks and agreeing
    directories."""
    fleet = spawn_servers(4)
    assert len(fleet.procs) == 4
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=60.0)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        rng = np.random.default_rng(23)
        keys = [_key("elastic", ts=t) for t in range(3)]
        arrays = [rng.random((64, 64)).astype(np.float32) for _ in keys]
        for k, a in zip(keys, arrays):
            dms.put(k, DOM, a)

        rois = [DOM, BoundingBox((5, 3), (37, 61)), BoundingBox((16, 16), (48, 48))]
        failures: list = []
        done = threading.Event()

        def hammer():
            i = 0
            while not done.is_set():
                j = i % len(keys)
                k, a, roi = keys[j], arrays[j], rois[i % len(rois)]
                try:
                    got = dms.get(k, roi)
                    if not np.array_equal(got, a[roi.slices()]):
                        failures.append((k, roi, "bit mismatch"))
                except Exception as exc:  # noqa: BLE001 - chaos: count every failure
                    failures.append((k, roi, repr(exc)))
                i += 1

        threads = [threading.Thread(target=hammer) for _ in range(2)]
        for t in threads:
            t.start()
        try:
            for _ in range(CHAOS_ITERS):
                # grow: a fresh server process joins the live ring
                sid, addr = fleet.add_server()
                dms.add_server(addr, sid=sid)
                rep = dms.rebalance()
                assert rep["lost"] == 0 and rep["unreachable"] == 0
                assert rep["directories_agree"]
                # shrink: drain the oldest member (paced) and purge it
                victim = min(dms.membership.servers)
                rep = dms.remove_server(victim)
                assert rep["lost"] == 0
                assert rep["directories_agree"]
                assert victim not in dms.membership.servers
        finally:
            done.set()
            for t in threads:
                t.join(timeout=60.0)
        assert not failures, failures[:5]
        # the steady state after churn: still bit-exact, still minimal
        for k, a in zip(keys, arrays):
            np.testing.assert_array_equal(dms.get(k, DOM), a)
        assert dms.rebalance()["migrated"] == 0  # idempotent at rest
        dms.close()
    finally:
        fleet.close()


def test_elastic_rejoin_same_port_resets_stale_dead_verdict():
    """Satellite: a server that leaves and rejoins on the SAME address
    within the liveness backoff window must be probed again, not served
    a cached dead answer — add_server clears the verdict and drops the
    old connection so the link renegotiates."""
    fleet = spawn_servers(3)
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=20.0, dead_backoff=600.0)
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
        arr = np.random.default_rng(31).random((64, 64)).astype(np.float32)
        dms.put(_key("bounce"), DOM, arr)

        victim = 2
        addr = tr.endpoints[victim]
        # crash it: failover reads arm the 600s dead verdict for the addr
        fleet.proc_for(victim).kill()
        np.testing.assert_array_equal(dms.get(_key("bounce"), DOM), arr)
        assert dms.stats.failover_fetches > 0
        # drain it out of the ring (it is unreachable; nothing is lost)
        rep = dms.remove_server(victim)
        assert rep["lost"] == 0
        assert not tr.alive(victim)

        # restart on the same port, rejoin within the backoff window
        fleet.proc_for(victim).start()
        assert fleet.proc_for(victim).address == addr
        dms.add_server(addr, sid=victim)
        assert tr.alive(victim)  # stale-dead verdict cleared
        rep = dms.rebalance()
        assert rep["unreachable"] == 0 and rep["lost"] == 0
        assert rep["directories_agree"]
        # the rejoined (empty) server holds its ideal share again and
        # serves it: reads stay bit-exact with the other replica stopped
        assert len(tr.lookup(victim, _key("bounce"))) > 0
        np.testing.assert_array_equal(dms.get(_key("bounce"), DOM), arr)
        dms.close()
    finally:
        fleet.close()


def test_epoch_gossip_bootstraps_fresh_clients(group):
    """Membership changes announced to the fleet are served back to any
    client that asks (epoch op + adopt-newer), so a fresh client joins
    the current epoch without an out-of-band config push."""
    tr = group.transport(scope="gossip", connect_timeout=5.0, op_timeout=20.0)
    dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr, replication=2)
    assert dms.epoch == 0
    view = dms.membership.leave(3)
    dms._ring = view  # simulate an admin change on THIS client...
    dms._announce("leave", 3, view.to_json())
    late = DistributedMemoryStorage(
        DOM, (16, 16),
        transport=group.transport(scope="gossip", connect_timeout=5.0),
        replication=2,
    )
    assert late.epoch == 0
    late.sync_membership()  # ...which the late client learns by gossip
    assert late.epoch == 1
    assert late.membership == view
    late.close()
    dms.close()


# ---------------------------------------------------------------------------
# per-key wire codecs (glob map negotiated per connection)
# ---------------------------------------------------------------------------
def test_per_key_codec_map_over_socket():
    """wire_codec={'labels/*': 'zlib', 'feat/*': 'bf16'}: label tiles
    ride zlib (bit-exact), feature tiles ride bf16 (lossy-close), and
    unmatched keys ride raw — all over one negotiated connection,
    including the batched fetch_many path (multi-block gets)."""
    fleet = spawn_servers(2)
    try:
        tr = fleet.transport(
            wire_codec={"labels/*": "zlib", "feat/*": "bf16"},
            connect_timeout=5.0, op_timeout=20.0,
        )
        dms = DistributedMemoryStorage(DOM, (16, 16), transport=tr)
        klab = RegionKey("labels", "L", ElementType.UINT8, 0)
        kfeat = RegionKey("feat", "F", ElementType.FLOAT32, 0)
        kraw = RegionKey("other", "O", ElementType.FLOAT32, 0)
        rng = np.random.default_rng(3)
        lab = (np.arange(64 * 64).reshape(64, 64) % 7).astype(np.uint8)
        feat = rng.normal(size=(64, 64)).astype(np.float32)
        other = rng.normal(size=(64, 64)).astype(np.float32)
        dms.put(klab, DOM, lab)
        dms.put(kfeat, DOM, feat)
        dms.put(kraw, DOM, other)
        # DOM spans 16 blocks -> these gets ride fetch_many with per-req
        # codec tags (the server advertises pkc in its hello)
        np.testing.assert_array_equal(dms.get(klab, DOM), lab)
        got = dms.get(kfeat, DOM)
        assert not np.array_equal(got, feat)  # bf16 IS lossy
        np.testing.assert_allclose(got, feat, rtol=2e-2, atol=2e-2)
        np.testing.assert_array_equal(dms.get(kraw, DOM), other)
        # zlib moved fewer wire bytes than the raw payload for labels
        assert tr.stats.bytes_get < tr.stats.bytes_get_raw
        dms.close()
    finally:
        fleet.close()


def test_per_key_codec_map_degrades_to_raw_on_compat_server():
    """A per-key-codec client against a pre-codec server: the failed
    hello downgrades everything to the legacy raw wire — even the bf16
    pattern round-trips bit-exact because no codec is applied."""
    proc = ServerProcess([0], extra_env={"REPRO_NET_COMPAT": "1"}).start()
    try:
        tr = SocketTransport(
            [proc.address],
            wire_codec={"feat/*": "bf16", "labels/*": "zlib"},
            connect_timeout=5.0, op_timeout=10.0,
        )
        box = BoundingBox((0, 0), (32, 32))
        kfeat = RegionKey("feat", "F", ElementType.FLOAT32, 0)
        feat = np.random.default_rng(5).normal(size=(32, 32)).astype(np.float32)
        tr.store(0, kfeat, (0, 0), box, feat)
        np.testing.assert_array_equal(tr.fetch(0, kfeat, (0, 0)), feat)
        got = tr.fetch_many(0, [(kfeat, (0, 0))])
        np.testing.assert_array_equal(got[0], feat)
        tr.close()
    finally:
        proc.stop()


def test_compat_server_rejects_membership_ops():
    """join/leave/epoch are post-compat wire ops: a REPRO_NET_COMPAT
    server answers them with the same unknown-op error every legacy
    frame gets, so mixed fleets fail loudly instead of desyncing."""
    proc = ServerProcess([0], extra_env={"REPRO_NET_COMPAT": "1"}).start()
    try:
        tr = SocketTransport([proc.address], connect_timeout=5.0, op_timeout=10.0)
        with pytest.raises(TransportError, match="unknown op"):
            tr.epoch(0)
        with pytest.raises(TransportError, match="unknown op"):
            tr.gen(0, bump=["k"])
        tr.close()
    finally:
        proc.stop()


def test_gen_gossip_over_sockets_increments_and_reads():
    """The ``gen`` wire op: server-authoritative per-token increments
    (``bump``) and reads (``want``) — the write-generation gossip that
    backs cross-gateway response-cache invalidation."""
    proc = ServerProcess([0]).start()
    try:
        tr = SocketTransport([proc.address], connect_timeout=5.0, op_timeout=10.0)
        assert tr.gen(0, want=["a"]) == {"a": 0}
        assert tr.gen(0, bump=["a"]) == {"a": 1}
        assert tr.gen(0, bump=["a"], want=["b"]) == {"a": 2, "b": 0}
        # a second client sees the same authoritative counters
        tr2 = SocketTransport([proc.address], connect_timeout=5.0, op_timeout=10.0)
        assert tr2.gen(0, want=["a", "b"]) == {"a": 2, "b": 0}
        tr2.close()
        tr.close()
    finally:
        proc.stop()
