"""Staged serving tier: response-cache semantics (generation-validated,
bit-exact), fairness scheduling, per-client pacing, write coalescing,
prefetch, the merged stats namespace, and fleet mode (N gateways over
one DMS fleet with cross-gateway invalidation)."""
import threading
import time

import numpy as np
import pytest

from repro.core import BoundingBox, ElementType, RegionKey
from repro.serve.fair import ClientPacer, FairScheduler
from repro.serve.gateway import (
    GatewayConfig,
    Overloaded,
    ReadTicket,
    RegionGateway,
)
from repro.serve.rcache import GenerationTracker, ResponseCache
from repro.storage import DistributedMemoryStorage, Tier, TieredStore
from repro.storage.dms import InProcTransport

DOM = BoundingBox((0, 0), (128, 128))
TILE = 32


def _key(name="Slide", ts=0):
    return RegionKey("g", name, ElementType.FLOAT32, ts)


def _dms_store(transport=None) -> tuple[TieredStore, np.ndarray]:
    dms = DistributedMemoryStorage(DOM, (TILE, TILE), transport=transport)
    store = TieredStore([Tier("DMS", dms)], name="SRV")
    slide = np.random.default_rng(7).random((128, 128)).astype(np.float32)
    for tile in DOM.tiles((TILE, TILE)):
        store.put(_key(), tile, slide[tile.slices()])
    return store, slide


# -- response cache ---------------------------------------------------------------


def test_hot_read_repeats_served_from_response_cache_without_tier_fetch():
    store, slide = _dms_store()
    transport = store.tiers[0].backend.transport
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    roi = BoundingBox((16, 16), (64, 64))
    first = gw.get(_key(), roi)
    np.testing.assert_array_equal(first, slide[roi.slices()])
    transport.reset()
    for _ in range(5):
        repeat = gw.get(_key(), roi)
        np.testing.assert_array_equal(repeat, slide[roi.slices()])
    # the repeats cost slices of the cached window, not tier fetches
    assert transport.stats.gets == 0
    assert gw.stats.response_cache_hits == 5
    assert gw.storage_stats()["gateway"]["response_cache"]["hits"] == 5
    gw.close()


def test_sub_roi_served_from_containing_cached_window():
    store, slide = _dms_store()
    transport = store.tiers[0].backend.transport
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    window = BoundingBox((0, 0), (64, 64))
    gw.get(_key(), window)
    transport.reset()
    sub = BoundingBox((8, 8), (40, 40))
    got = gw.get(_key(), sub)
    np.testing.assert_array_equal(got, slide[sub.slices()])
    assert transport.stats.gets == 0
    assert gw.stats.response_cache_hits == 1
    gw.close()


def test_cached_reads_stay_bit_exact_across_gateway_put_invalidation():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    roi = BoundingBox((0, 0), (32, 32))
    np.testing.assert_array_equal(gw.get(_key(), roi), slide[roi.slices()])
    fresh = np.full((32, 32), 9.5, np.float32)
    gw.put(_key(), roi, fresh)  # facade write: invalidates + bumps gen
    got = gw.get(_key(), roi)
    np.testing.assert_array_equal(got, fresh)
    np.testing.assert_array_equal(got, store.get(_key(), roi))
    gw.close()


def test_direct_store_put_bypassing_gateway_still_invalidates():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=2))
    roi = BoundingBox((32, 0), (64, 32))
    gw.get(_key(), roi)  # fills the response cache
    fresh = np.full((32, 32), -3.0, np.float32)
    store.put(_key(), roi, fresh)  # bypasses the gateway entirely
    # TieredStore.generation moved, so the cached window is a stale miss
    got = gw.get(_key(), roi)
    np.testing.assert_array_equal(got, fresh)
    gw.close()


def test_put_then_read_generation_race_is_a_spurious_miss_never_stale():
    """An entry recorded under a pre-write generation must not be
    served after the write, even if it lands in the cache afterwards
    (the fetch raced the put)."""
    store, _ = _dms_store()
    roi = BoundingBox((0, 0), (32, 32))
    cache = ResponseCache(1 << 20)
    gens = GenerationTracker(store)
    gen_before = gens.current(_key())
    stale_payload = store.get(_key(), roi)
    store.put(_key(), roi, np.zeros((32, 32), np.float32))  # racing write
    # the racing fetch completes and fills the cache under the old gen
    cache.put((_key(), roi), gen_before, stale_payload)
    assert cache.lookup_window(_key(), roi, gens.current(_key())) is None
    assert cache.misses == 1 and cache.hits == 0


def test_response_cache_client_mutation_cannot_corrupt_future_hits():
    store, slide = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((64, 64), (96, 96))
    first = gw.get(_key(), roi)
    first[:] = -1.0  # hostile client scribbles on its result
    np.testing.assert_array_equal(gw.get(_key(), roi), slide[roi.slices()])
    gw.close()


def test_response_cache_disabled_with_zero_budget():
    store, _ = _dms_store()
    transport = store.tiers[0].backend.transport
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, response_cache_bytes=0)
    )
    roi = BoundingBox((0, 0), (32, 32))
    gw.get(_key(), roi)
    transport.reset()
    gw.get(_key(), roi)
    assert transport.stats.gets > 0  # every read pays the tier
    assert gw.stats.response_cache_hits == 0
    assert "response_cache" not in gw.storage_stats()["gateway"]
    gw.close()


# -- fairness + pacing ------------------------------------------------------------


def _ticket(priority, name="Slide"):
    t = ReadTicket(_key(name), BoundingBox((0, 0), (8, 8)))
    t.priority = priority
    return t


def test_fair_scheduler_serves_classes_in_weight_proportion():
    sched = FairScheduler((("hi", 3), ("lo", 1)))
    for i in range(12):
        sched.push(_ticket("hi", f"H{i}"))
        sched.push(_ticket("lo", f"L{i}"))
    first8 = [sched.pop_head().priority for _ in range(8)]
    # DRR with weights 3:1 -> each full round serves 3 hi then 1 lo
    assert first8 == ["hi", "hi", "hi", "lo"] * 2
    assert len(sched) == 16


def test_fair_scheduler_unknown_class_degrades_to_default():
    sched = FairScheduler((("interactive", 4), ("default", 2)))
    assert sched.resolve("no-such-class") == "default"
    assert sched.resolve(None) == "default"
    assert sched.resolve("interactive") == "interactive"


def test_drain_matching_stays_within_the_heads_class():
    sched = FairScheduler((("hi", 2), ("lo", 1)))
    for i in range(3):
        sched.push(_ticket("hi"))
        sched.push(_ticket("lo"))
    head = sched.pop_head()
    assert head.priority == "hi"
    batch = sched.drain_matching(head, limit=16, coalesce=True)
    # same key, same group, but only hi's own queue drains
    assert [t.priority for t in batch] == ["hi", "hi", "hi"]
    assert len(sched) == 3  # the lo backlog is untouched


def test_low_priority_hog_cannot_starve_interactive_requests():
    store, _ = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1, max_queue=256))
    gw.pause()
    hog = [
        gw.submit(_key(), tile, priority="batch")
        for tile in DOM.tiles((TILE, TILE))
        for _ in range(4)
    ]
    vip = gw.submit(_key(), BoundingBox((0, 0), (16, 16)), priority="interactive")
    gw.resume()
    vip.result(30.0)  # resolves long before the hog's 64-deep backlog
    done_hogs = sum(1 for t in hog if t.done())
    assert done_hogs < len(hog), "interactive request waited out the whole backlog"
    for t in hog:
        t.result(30.0)
    classes = gw.storage_stats()["gateway"]["classes"]
    assert classes["interactive"]["served"] >= 1
    assert classes["batch"]["served"] >= 1
    gw.close()


def test_client_pacer_throttles_only_the_hog():
    now = [0.0]
    waited = []

    def clock():
        return now[0]

    def sleep(dt):
        waited.append(dt)
        now[0] += dt

    pacer = ClientPacer(1.0, 1.0, clock=clock, sleep=sleep)
    assert pacer.take("hog") == 0.0  # burst token
    assert pacer.take("hog") > 0.0  # over rate: waits on its OWN bucket
    assert pacer.take("polite") == 0.0  # other client untouched
    assert pacer.clients() == 2
    assert waited and all(w > 0 for w in waited)


def test_gateway_counts_throttled_submissions():
    store, _ = _dms_store()
    gw = RegionGateway(
        store,
        config=GatewayConfig(workers=1, client_rate=1000.0, client_burst=1.0),
    )
    roi = BoundingBox((0, 0), (16, 16))
    for _ in range(3):
        gw.get(_key(), roi)
    assert gw.stats.throttled >= 1  # burst=1 -> the repeats paid the bucket
    gw.close()


def test_shed_mode_rejects_immediately_with_class_attribution():
    store, _ = _dms_store()
    gw = RegionGateway(
        store,
        config=GatewayConfig(workers=1, max_queue=8, admit_timeout=30.0),
        pressure_fn=lambda: 0.99,  # RAM tier past the highwater
    )
    gw.pause()
    with pytest.raises(Overloaded):
        for _ in range(8):  # shed limit = max(1, 8 * 0.25) = 2
            gw.submit(_key(), BoundingBox((0, 0), (8, 8)), priority="batch")
    assert gw.stats.rejected >= 1
    assert gw.storage_stats()["gateway"]["classes"]["batch"]["shed"] >= 1
    gw.resume()
    gw.close()


# -- write coalescing -------------------------------------------------------------


def test_put_coalescing_last_writer_wins_one_store_put():
    store, _ = _dms_store()
    transport = store.tiers[0].backend.transport
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, coalesce_puts=True)
    )
    roi = BoundingBox((0, 0), (32, 32))
    versions = [np.full((32, 32), float(i), np.float32) for i in range(4)]
    gw.pause()
    tickets = [gw.submit_put(_key(), roi, v) for v in versions]
    transport.reset()
    gw.resume()
    for t in tickets:
        assert t.result(30.0) is None  # superseded writes still resolve
    np.testing.assert_array_equal(store.get(_key(), roi), versions[-1])
    assert gw.stats.writes == 4
    assert gw.stats.writes_applied == 1  # last-writer-wins: one flush
    assert gw.stats.write_coalesced == 3
    assert transport.stats.puts <= 1
    gw.close()


def test_put_coalescing_distinct_rois_all_flush():
    store, _ = _dms_store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, coalesce_puts=True)
    )
    rois = [BoundingBox((0, x), (32, x + 32)) for x in (0, 32, 64)]
    payloads = [np.full((32, 32), float(i) + 0.5, np.float32) for i in range(3)]
    gw.pause()
    tickets = [gw.submit_put(_key(), r, p) for r, p in zip(rois, payloads)]
    gw.resume()
    for t in tickets:
        t.result(30.0)
    for r, p in zip(rois, payloads):
        np.testing.assert_array_equal(store.get(_key(), r), p)
    assert gw.stats.writes_applied == 3 and gw.stats.write_coalesced == 0
    gw.close()


def test_facade_put_blocks_until_applied_when_coalescing():
    store, _ = _dms_store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=2, coalesce_puts=True)
    )
    roi = BoundingBox((32, 32), (64, 64))
    fresh = np.full((32, 32), 4.25, np.float32)
    gw.put(_key(), roi, fresh)  # returns only after the flush
    np.testing.assert_array_equal(store.get(_key(), roi), fresh)
    np.testing.assert_array_equal(gw.get(_key(), roi), fresh)
    gw.close()


# -- prefetch ---------------------------------------------------------------------


def test_sequential_scan_feeds_the_window_prefetcher():
    store, slide = _dms_store()
    gw = RegionGateway(
        store, config=GatewayConfig(workers=1, prefetch=True, prefetch_depth=2)
    )
    windows = [BoundingBox((0, x), (32, x + 32)) for x in range(0, 97, 32)]
    gw.get(_key(), windows[0])
    gw.get(_key(), windows[1])  # stride observed -> windows[2] predicted
    deadline = time.monotonic() + 10.0
    while gw.stats.prefetch_issued < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert gw.stats.prefetch_issued >= 1
    # give the pipeline a beat to land the payload in the cache
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        got = gw.get(_key(), windows[2])
        np.testing.assert_array_equal(got, slide[windows[2].slices()])
        if gw.stats.prefetch_hits >= 1:
            break
        time.sleep(0.01)
    assert gw.stats.prefetch_hits >= 1
    gw.close()


# -- stats namespace --------------------------------------------------------------


def test_gateway_stats_namespace_merges_compute_with_alias():
    store, _ = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    out = gw.storage_stats()
    assert "compute" not in out["gateway"]  # engine not built yet
    gw.compute(_key(), BoundingBox((0, 0), (32, 32)), "threshold")
    out = gw.storage_stats()
    assert out["gateway"]["compute"]["chains"]["threshold"]["served"] == 1
    # deprecated top-level alias, kept for one release
    assert out["compute"] == out["gateway"]["compute"]
    assert out["gateway"]["served"] == 0 and out["gateway"]["compute_served"] == 1
    for row in out["gateway"]["classes"].values():
        assert set(row) == {"requests", "admitted", "shed", "served", "cache_hits"}
    gw.close()


def test_unknown_stats_counters_still_raise():
    store, _ = _dms_store()
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    with pytest.raises(AttributeError):
        gw.stats.add(no_such_counter=1)
    with pytest.raises(AttributeError):
        gw.stats.class_add("default", no_such_counter=1)
    gw.close()


# -- fleet mode -------------------------------------------------------------------


def _fleet_pair():
    """Two gateways over one DMS fleet (one shared transport)."""
    transport = InProcTransport(4)
    store_a, slide = _dms_store(transport)
    dms_b = DistributedMemoryStorage(DOM, (TILE, TILE), transport=transport)
    store_b = TieredStore([Tier("DMS", dms_b)], name="SRVB")
    cfg = GatewayConfig(workers=2, fleet_generations=True)
    gw_a = RegionGateway(store_a, name="GWA", config=cfg)
    gw_b = RegionGateway(store_b, name="GWB", config=cfg)
    return gw_a, gw_b, slide


def test_cross_gateway_put_invalidates_sibling_response_cache():
    gw_a, gw_b, slide = _fleet_pair()
    roi = BoundingBox((0, 0), (32, 32))
    # both gateways cache the hot window
    np.testing.assert_array_equal(gw_a.get(_key(), roi), slide[roi.slices()])
    np.testing.assert_array_equal(gw_b.get(_key(), roi), slide[roi.slices()])
    fresh = np.full((32, 32), 11.0, np.float32)
    gw_b.put(_key(), roi, fresh)  # gossips the generation bump
    # A's cached window is stale the moment B's put returns: the very
    # next read through A must see B's bytes, not A's cache
    got = gw_a.get(_key(), roi)
    np.testing.assert_array_equal(got, fresh)
    np.testing.assert_array_equal(got, gw_a.store.get(_key(), roi))
    # and the new payload re-caches under the advanced generation
    transport = gw_a.store.tiers[0].backend.transport
    gets_before = transport.stats.gets
    np.testing.assert_array_equal(gw_a.get(_key(), roi), fresh)
    assert transport.stats.gets == gets_before
    gw_a.close(close_store=False)
    gw_b.close()


class _FakeFleetStore:
    """A backend with gossip hooks but no generation() of its own."""

    def __init__(self):
        self.val = 0

    def pull_generation(self, key):
        return self.val

    def push_generation(self, key):
        self.val += 1
        return self.val


def test_generation_tracker_floors_fleet_pull_regressions():
    """A pull that regresses (the member holding the max is unreachable)
    must never resurrect a stale cache entry: the observed fleet value
    is floored per key."""
    backend = _FakeFleetStore()
    gens = GenerationTracker(backend, fleet=True)
    assert gens.fleet_enabled
    k = _key()
    assert gens.current(k) == 0
    backend.val = 5  # remote writes observed
    assert gens.current(k) == 5
    backend.val = 2  # regression: the max-holder dropped out of the pull
    assert gens.current(k) == 5  # floored — monotone, no stale revival
    gens.note_write(k)  # local write: base line +1, fleet push -> 3 < floor
    assert gens.current(k) == 6


def test_manual_generation_bump_drops_cached_responses():
    """TieredStore.bump_generation: out-of-band invalidation without a
    write — the next gateway read pays the tier again."""
    store, slide = _dms_store()
    transport = store.tiers[0].backend.transport
    gw = RegionGateway(store, config=GatewayConfig(workers=1))
    roi = BoundingBox((96, 96), (128, 128))
    gw.get(_key(), roi)
    transport.reset()
    np.testing.assert_array_equal(gw.get(_key(), roi), slide[roi.slices()])
    assert transport.stats.gets == 0  # cached
    store.bump_generation(_key())
    np.testing.assert_array_equal(gw.get(_key(), roi), slide[roi.slices()])
    assert transport.stats.gets > 0  # cache dropped, tier re-fetched
    gw.close()


def test_fleet_reads_stay_bit_exact_under_concurrent_cross_writes():
    gw_a, gw_b, _ = _fleet_pair()
    roi = BoundingBox((64, 64), (96, 96))
    stop = threading.Event()
    errors = []
    # replace the staged random tile with version 0 so every read is a
    # uniform plane and the version ordering below is well-defined
    gw_b.put(_key(), roi, np.zeros((32, 32), np.float32))

    def writer():
        try:
            i = 1
            while not stop.is_set():
                gw_b.put(_key(), roi, np.full((32, 32), float(i), np.float32))
                i += 1
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                got = gw_a.get(_key(), roi)
                direct = gw_a.store.get(_key(), roi)
                # every read is SOME written version, uniform per-plane
                assert got.min() == got.max()
                assert direct.min() == direct.max()
                assert got.max() <= direct.max()  # never newer than now...
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer), threading.Thread(target=reader)]
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not errors, errors
    gw_a.close(close_store=False)
    gw_b.close()
