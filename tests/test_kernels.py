"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests on invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._prop import given, settings, st

from repro.kernels import ref
from repro.kernels.ccl import ccl_pallas
from repro.kernels.color_deconv import color_deconv_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.glcm import glcm_pallas
from repro.kernels.morph_recon import morph_recon_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# color deconvolution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,bh,bw", [(32, 128, 16, 128), (64, 256, 64, 128), (48, 96, 32, 96)])
def test_color_deconv_sweep(h, w, bh, bw):
    rgb = jnp.asarray(RNG.random((3, h, w), dtype=np.float32))
    minv = jnp.asarray(ref.stain_inverse())
    out = color_deconv_pallas(rgb, minv, block_h=bh, block_w=bw, interpret=True)
    np.testing.assert_allclose(out, ref.color_deconv_ref(rgb, minv), rtol=2e-5, atol=2e-5)


def test_color_deconv_white_is_zero_density():
    rgb = jnp.ones((3, 8, 128), jnp.float32)
    out = color_deconv_pallas(rgb, jnp.asarray(ref.stain_inverse()), interpret=True)
    np.testing.assert_allclose(out, np.zeros_like(out), atol=1e-5)


# ---------------------------------------------------------------------------
# morphological reconstruction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,bh,bw", [(32, 48, 16, 16), (64, 64, 32, 32)])
def test_morph_recon_matches_ref(h, w, bh, bw):
    mask = jnp.asarray((RNG.random((h, w)) > 0.35).astype(np.float32))
    marker = jnp.asarray(RNG.random((h, w)).astype(np.float32)) * mask
    out = morph_recon_pallas(marker, mask, block_h=bh, block_w=bw, interpret=True)
    np.testing.assert_allclose(out, ref.morph_recon_ref(marker, mask), atol=1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=10)
def test_morph_recon_invariants(seed):
    r = np.random.default_rng(seed)
    mask = jnp.asarray(r.random((24, 24), dtype=np.float32))
    marker = jnp.asarray(r.random((24, 24), dtype=np.float32))
    out = np.asarray(ref.morph_recon_ref(marker, mask))
    # invariants: marker^mask <= recon <= mask ; idempotent
    clipped = np.minimum(np.asarray(marker), np.asarray(mask))
    assert (out >= clipped - 1e-6).all()
    assert (out <= np.asarray(mask) + 1e-6).all()
    again = np.asarray(ref.morph_recon_ref(jnp.asarray(out), mask))
    np.testing.assert_allclose(again, out, atol=1e-6)


def test_fill_holes_closes_a_donut():
    m = np.zeros((32, 32), np.float32)
    m[8:24, 8:24] = 1.0
    m[14:18, 14:18] = 0.0  # the hole
    filled = np.asarray(ref.fill_holes_ref(jnp.asarray(m)))
    assert filled[15, 15] == 1.0
    assert filled[0, 0] == 0.0


# ---------------------------------------------------------------------------
# connected components
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,density", [(24, 32, 0.4), (48, 48, 0.6), (16, 64, 0.2)])
def test_ccl_matches_unionfind(h, w, density):
    m = RNG.random((h, w)) < density
    got = np.asarray(ccl_pallas(jnp.asarray(m), block_h=16, block_w=16, interpret=True))
    want = ref.ccl_unionfind_host(m)
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 10_000))
@settings(max_examples=10)
def test_ccl_labels_are_canonical_min_index(seed):
    r = np.random.default_rng(seed)
    m = r.random((20, 20)) < 0.5
    labels = np.asarray(ref.ccl_ref(jnp.asarray(m)))
    assert ((labels == -1) == ~m).all()
    for lab in np.unique(labels[labels >= 0]):
        ys, xs = np.nonzero(labels == lab)
        assert (ys * 20 + xs).min() == lab  # component labeled by min flat idx


# ---------------------------------------------------------------------------
# GLCM / histogram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,w,nb", [(2, 16, 16, 8), (4, 24, 32, 16), (1, 64, 64, 32)])
def test_glcm_sweep(b, h, w, nb):
    bins = jnp.asarray(RNG.integers(0, nb, (b, h, w), dtype=np.int32))
    g, hist = glcm_pallas(bins, nb, interpret=True)
    np.testing.assert_array_equal(g, ref.glcm_ref(bins, nb))
    np.testing.assert_array_equal(hist, ref.histogram_ref(bins, nb))
    # sanity: counts conserve mass
    assert float(g.sum()) == b * h * (w - 1) * 1.0 if b == 1 else True
    np.testing.assert_allclose(np.asarray(hist).sum(-1), h * w)


def test_glcm_features_known_case():
    # constant image: single GLCM cell -> energy 1, contrast 0, corr nan-safe
    bins = jnp.zeros((1, 8, 8), jnp.int32)
    g = ref.glcm_ref(bins, 4)
    f = np.asarray(ref.glcm_features_ref(g))[0]
    contrast, energy, homog, entropy, corr = f
    assert contrast == pytest.approx(0.0)
    assert energy == pytest.approx(1.0)
    assert homog == pytest.approx(1.0)
    assert entropy == pytest.approx(0.0, abs=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,hq,hkv,tq,tk,d,causal,window,qoff,bq,bk",
    [
        (2, 4, 2, 64, 64, 32, True, None, 0, 16, 16),
        (1, 8, 1, 32, 32, 16, True, 8, 0, 8, 8),
        (2, 4, 4, 1, 96, 32, True, None, 95, 1, 32),
        (1, 2, 2, 48, 48, 64, False, None, 0, 16, 24),
        (1, 4, 2, 40, 40, 24, True, None, 0, 16, 16),  # ragged blocks
    ],
)
def test_flash_attention_sweep(b, hq, hkv, tq, tk, d, causal, window, qoff, bq, bk):
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d), dtype=np.float32))
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d), dtype=np.float32))
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d), dtype=np.float32))
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=qoff,
        block_q=bq, block_k=bk, interpret=True,
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 32)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, block_q=16, block_k=16, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,t,h,p,g,n,chunk",
    [(2, 64, 4, 16, 2, 8, 16), (1, 32, 2, 8, 1, 4, 8), (1, 128, 8, 32, 1, 16, 32)],
)
def test_ssd_scan_sweep(b, t, h, p, g, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, t, h, p), dtype=np.float32))
    dt = jnp.asarray(RNG.random((b, t, h), dtype=np.float32) * 0.1)
    a = jnp.asarray(-np.exp(RNG.standard_normal(h)).astype(np.float32))
    bm = jnp.asarray(RNG.standard_normal((b, t, g, n), dtype=np.float32))
    cm = jnp.asarray(RNG.standard_normal((b, t, g, n), dtype=np.float32))
    d = jnp.asarray(RNG.standard_normal(h).astype(np.float32))
    y, hf = ssd_scan_pallas(x, dt, a, bm, cm, d, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(y, yr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hf, hr, rtol=3e-4, atol=3e-4)


def test_ssd_chunked_equals_chunkless():
    """Chunk size must not change the math (state handoff exactness)."""
    b, t, h, p, g, n = 1, 64, 2, 8, 1, 4
    x = jnp.asarray(RNG.standard_normal((b, t, h, p), dtype=np.float32))
    dt = jnp.asarray(RNG.random((b, t, h), dtype=np.float32) * 0.1)
    a = jnp.asarray(-np.ones(h, np.float32))
    bm = jnp.asarray(RNG.standard_normal((b, t, g, n), dtype=np.float32))
    cm = jnp.asarray(RNG.standard_normal((b, t, g, n), dtype=np.float32))
    y1, h1 = ssd_scan_pallas(x, dt, a, bm, cm, chunk=8, interpret=True)
    y2, h2 = ssd_scan_pallas(x, dt, a, bm, cm, chunk=64, interpret=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# chunked (flash-structured) XLA attention — the lowerable memory-term fix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,hq,hkv,tq,tk,causal,window,qoff,chunk",
    [
        (2, 4, 2, 64, 64, True, None, 0, 16),
        (1, 8, 1, 40, 40, True, 8, 0, 16),
        (2, 4, 4, 1, 96, True, None, 95, 32),
        (1, 2, 2, 48, 48, False, None, 0, 13),
    ],
)
def test_chunked_attention_matches_ref(b, hq, hkv, tq, tk, causal, window, qoff, chunk):
    d = 32
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d), dtype=np.float32))
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d), dtype=np.float32))
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d), dtype=np.float32))
    got = ref.attention_chunked_ref(
        q, k, v, causal=causal, window=window, q_offset=qoff, chunk=chunk
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_xla_matches_sequential(chunk):
    """The lowerable chunked SSD (§Perf memory fix) == step-by-step scan."""
    B, T, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = jnp.asarray(RNG.standard_normal((B, T, H, P), dtype=np.float32))
    dt = jnp.asarray(RNG.random((B, T, H), dtype=np.float32) * 0.1)
    a = jnp.asarray(-np.exp(RNG.standard_normal(H)).astype(np.float32))
    bm = jnp.asarray(RNG.standard_normal((B, T, G, N), dtype=np.float32))
    cm = jnp.asarray(RNG.standard_normal((B, T, G, N), dtype=np.float32))
    d = jnp.asarray(RNG.standard_normal(H).astype(np.float32))
    yr, hr = ref.ssd_scan_ref(x, dt, a, bm, cm, d)
    yc, hc = ref.ssd_scan_chunked_ref(x, dt, a, bm, cm, d, chunk=chunk)
    np.testing.assert_allclose(yc, yr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hc, hr, rtol=3e-4, atol=3e-4)
