"""relint fixture corpus: per-rule firing and non-firing snippets, the
repo self-check, pragma semantics, and the runtime lock witness."""
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

import pytest

from tools.relint import rules as R
from tools.relint.core import SourceFile, run
from tools.relint.witness import LockWitness

REPO = Path(__file__).resolve().parent.parent


def lint(src: str, rule: str):
    """Run one rule over a source snippet, honoring pragmas."""
    f = SourceFile("<snippet>", textwrap.dedent(src))
    return [v for v in R.ALL_RULES[rule]([f]) if not f.allowed(v.rule, v.line)]


# ---------------------------------------------------------------------------
# rule 1: guarded-attribute
# ---------------------------------------------------------------------------
GUARDED_FIRING = """
    import threading

    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def read(self):
            return self.n
"""


def test_guarded_attribute_fires_on_unlocked_read():
    vs = lint(GUARDED_FIRING, "guarded-attribute")
    assert len(vs) == 1 and "self.n is read" in vs[0].message


def test_guarded_attribute_clean_when_read_under_lock():
    src = GUARDED_FIRING.replace(
        "return self.n", "with self._lock:\n                return self.n"
    )
    assert lint(src, "guarded-attribute") == []


def test_guarded_attribute_pragma_suppresses():
    src = GUARDED_FIRING.replace(
        "return self.n",
        "return self.n  # relint: allow(guarded-attribute) — test escape",
    )
    assert lint(src, "guarded-attribute") == []


def test_guarded_attribute_condition_aliases_its_lock():
    # holding a Condition built over self._lock IS holding self._lock
    src = """
        import threading

        class Q:
            def __init__(self):
                self._lock = threading.Lock()
                self._ready = threading.Condition(self._lock)
                self.depth = 0

            def push(self):
                with self._lock:
                    self.depth += 1

            def pop(self):
                with self._ready:
                    self.depth -= 1
    """
    assert lint(src, "guarded-attribute") == []


def test_guarded_attribute_locked_suffix_convention():
    # *_locked methods are analyzed as if the class locks were held
    src = """
        import threading

        class Cache:
            def __init__(self):
                self._lock = threading.Lock()
                self.size = 0

            def insert(self):
                with self._lock:
                    self.size += 1
                    self._evict_locked()

            def _evict_locked(self):
                self.size -= 1
    """
    assert lint(src, "guarded-attribute") == []


# ---------------------------------------------------------------------------
# rule 2: blocking-under-lock
# ---------------------------------------------------------------------------
def test_blocking_under_lock_fires_on_sleep():
    src = """
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def slow(self):
                with self._lock:
                    time.sleep(0.1)
    """
    vs = lint(src, "blocking-under-lock")
    assert len(vs) == 1 and "time.sleep" in vs[0].message


def test_blocking_under_lock_fires_on_socket_and_join_and_anonymous_lock():
    src = """
        import threading

        class C:
            def __init__(self):
                self._locks = {}
                self._lock = threading.Lock()

            def send(self, sock, addr):
                with self._locks[addr]:
                    sock.sendall(b"x")

            def stop(self, worker):
                with self._lock:
                    worker.join()
    """
    vs = lint(src, "blocking-under-lock")
    assert len(vs) == 2
    assert any("sendall" in v.message for v in vs)
    assert any(".join()" in v.message for v in vs)


def test_blocking_under_lock_clean_cases():
    # sleep outside the lock; str.join / os.path.join under the lock
    src = """
        import os
        import threading
        import time

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.parts = []

            def ok(self):
                with self._lock:
                    name = ", ".join(self.parts)
                    path = os.path.join("a", "b")
                time.sleep(0.1)
                return name, path
    """
    assert lint(src, "blocking-under-lock") == []


# ---------------------------------------------------------------------------
# rule 3: lock-order
# ---------------------------------------------------------------------------
def test_lock_order_fires_on_cycle():
    src = """
        import threading

        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._b:
                    with self._a:
                        pass
    """
    vs = lint(src, "lock-order")
    assert len(vs) == 1 and "cycle" in vs[0].message


def test_lock_order_clean_on_consistent_order():
    src = """
        import threading

        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._a:
                    with self._b:
                        pass
    """
    assert lint(src, "lock-order") == []


def test_lock_order_flags_plain_lock_reacquire_but_not_rlock():
    plain = """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def oops(self):
                with self._lock:
                    with self._lock:
                        pass
    """
    vs = lint(plain, "lock-order")
    assert len(vs) == 1 and "re-acquire" in vs[0].message
    assert lint(plain.replace("Lock()", "RLock()"), "lock-order") == []


def test_lock_order_sees_cross_class_nesting():
    # A holds its lock while calling into B, B holds its lock while
    # calling into A -> cross-class cycle through the attr type map
    src = """
        import threading

        class B:
            def __init__(self):
                self._lock = threading.Lock()

            def poke(self):
                with self._lock:
                    pass

        class A:
            def __init__(self):
                self._lock = threading.Lock()
                self.b = B()

            def use(self):
                with self._lock:
                    self.b.poke()
    """
    assert lint(src, "lock-order") == []
    cyclic = """
        import threading

        class B:
            def __init__(self):
                self._lock = threading.Lock()
                self.a = A()

            def poke(self):
                with self._lock:
                    self.a.use()

        class A:
            def __init__(self):
                self._lock = threading.Lock()
                self.b = B()

            def use(self):
                with self._lock:
                    self.b.poke()
    """
    vs = lint(cyclic, "lock-order")
    assert len(vs) == 1 and "cycle" in vs[0].message


# ---------------------------------------------------------------------------
# rule 4: transport-conformance
# ---------------------------------------------------------------------------
PROTO = """
    from typing import Protocol

    class Transport(Protocol):
        def store(self, server, key): ...
        def fetch(self, server, key): ...
        def close(self): ...
"""


def test_transport_conformance_clean_impl():
    src = PROTO + """
    class GoodTransport:
        def store(self, server, key):
            pass

        def fetch(self, server, key):
            pass

        def close(self):
            pass
    """
    assert lint(src, "transport-conformance") == []


def test_transport_conformance_fires_on_missing_and_mismatched_ops():
    src = PROTO + """
    class BadTransport:
        def store(self, server):
            pass

        def fetch(self, server, key):
            pass
    """
    vs = lint(src, "transport-conformance")
    assert len(vs) == 2
    assert any("does not implement Transport.close" in v.message for v in vs)
    assert any("does not match Transport.store" in v.message for v in vs)


def test_transport_conformance_inherited_ops_count():
    src = PROTO + """
    class BaseTransport:
        def store(self, server, key):
            pass

        def fetch(self, server, key):
            pass

        def close(self):
            pass

    class ShinyTransport(BaseTransport):
        pass
    """
    assert lint(src, "transport-conformance") == []


def test_transport_conformance_frame_tag_parity():
    src = """
    class _NetServer:
        def dispatch(self, header):
            op = header.get("op")
            if op == "ping":
                return {}
            if op == "store":
                return {}

    class WireTransport:
        def ping(self):
            self._request({"op": "ping"})

        def store(self):
            self._request({"op": "store"})
    """
    assert lint(src, "transport-conformance") == []
    drifted = src.replace('self._request({"op": "store"})', 'self._request({"op": "stash"})')
    vs = lint(drifted, "transport-conformance")
    assert len(vs) == 2  # client emits unknown 'stash'; server 'store' unused
    assert any("'stash'" in v.message for v in vs)
    assert any("'store'" in v.message for v in vs)


# ---------------------------------------------------------------------------
# rule 5: resource-lifecycle
# ---------------------------------------------------------------------------
def test_resource_lifecycle_fires_without_close():
    src = """
        import threading

        class Spawner:
            def go(self):
                threading.Thread(target=self.run, daemon=True).start()
    """
    vs = lint(src, "resource-lifecycle")
    assert len(vs) == 1 and "spawns threads" in vs[0].message


def test_resource_lifecycle_clean_with_close():
    src = """
        import threading

        class Spawner:
            def go(self):
                self._t = threading.Thread(target=self.run, daemon=True)
                self._t.start()

            def close(self):
                pass
    """
    assert lint(src, "resource-lifecycle") == []


def test_resource_lifecycle_nondaemon_needs_join():
    src = """
        import threading

        class Spawner:
            def go(self):
                self._t = threading.Thread(target=self.run)
                self._t.start()

            def close(self):
                pass
    """
    vs = lint(src, "resource-lifecycle")
    assert len(vs) == 1 and "non-daemon" in vs[0].message
    joined = src.replace("def close(self):\n                pass",
                         "def close(self):\n                self._t.join()")
    assert lint(joined, "resource-lifecycle") == []


# ---------------------------------------------------------------------------
# pragma mechanics
# ---------------------------------------------------------------------------
def test_pragma_on_line_above_suppresses():
    src = GUARDED_FIRING.replace(
        "return self.n",
        "# relint: allow(guarded-attribute) — escape above\n            return self.n",
    )
    assert lint(src, "guarded-attribute") == []


def test_pragma_does_not_suppress_other_rules():
    src = GUARDED_FIRING.replace(
        "return self.n",
        "return self.n  # relint: allow(blocking-under-lock) — wrong rule",
    )
    assert len(lint(src, "guarded-attribute")) == 1


# ---------------------------------------------------------------------------
# repo self-check: the codebase itself lints clean
# ---------------------------------------------------------------------------
def test_repo_lints_clean():
    assert run([str(REPO / "src" / "repro")]) == []


def test_cli_exits_clean_on_repo():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.relint", "src/repro"],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


# ---------------------------------------------------------------------------
# runtime lock witness
# ---------------------------------------------------------------------------
@pytest.mark.no_lock_witness
def test_witness_detects_order_cycle():
    w = LockWitness()
    w.install()
    try:
        a = threading.Lock()
        b = threading.Lock()

        def one():
            with a:
                with b:
                    pass

        def two():
            with b:
                with a:
                    pass

        # sequential threads: opposite orders, no actual deadlock — the
        # witness must still call the latent cycle
        t1 = threading.Thread(target=one)
        t1.start()
        t1.join()
        t2 = threading.Thread(target=two)
        t2.start()
        t2.join()
    finally:
        w.uninstall()
    with pytest.raises(AssertionError, match="cycle"):
        w.check()


@pytest.mark.no_lock_witness
def test_witness_accepts_consistent_order():
    w = LockWitness()
    w.install()
    try:
        a = threading.Lock()
        b = threading.Lock()
        for _ in range(3):
            with a:
                with b:
                    pass
    finally:
        w.uninstall()
    w.check()


@pytest.mark.no_lock_witness
def test_witness_flags_sleep_under_lock():
    w = LockWitness(blocking_allow=())
    w.install()
    try:
        lock = threading.Lock()
        with lock:
            time.sleep(0.001)
    finally:
        w.uninstall()
    with pytest.raises(AssertionError, match="time.sleep"):
        w.check()


@pytest.mark.no_lock_witness
def test_witness_allowlist_spares_blocking_sites():
    w = LockWitness(blocking_allow=("test_relint.py",))
    w.install()
    try:
        lock = threading.Lock()
        with lock:
            time.sleep(0.001)
    finally:
        w.uninstall()
    w.check()


@pytest.mark.no_lock_witness
def test_witness_condition_over_rlock_survives_wait():
    # Condition steals _release_save/_acquire_restore/_is_owned from a
    # wrapped RLock; held bookkeeping must survive the wait cycle
    w = LockWitness()
    w.install()
    try:
        lk = threading.RLock()
        cv = threading.Condition(lk)
        done = []

        def waiter():
            with cv:
                while not done:
                    cv.wait(timeout=2.0)

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.02)
        with cv:
            done.append(1)
            cv.notify_all()
        t.join(timeout=5.0)
        assert not t.is_alive()
    finally:
        w.uninstall()
    w.check()


@pytest.mark.no_lock_witness
def test_witness_uninstall_restores_factories():
    real_lock, real_sleep = threading.Lock, time.sleep
    w = LockWitness()
    w.install()
    assert threading.Lock is not real_lock
    w.uninstall()
    assert threading.Lock is real_lock
    assert time.sleep is real_sleep
