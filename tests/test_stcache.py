"""Spatio-temporal cache + prefetch (the paper's §7 extension)."""
import time

import numpy as np

from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage
from repro.storage.stcache import SpatioTemporalCache

DOM = BoundingBox((0, 0), (128, 128))


def _backend():
    dms = DistributedMemoryStorage(DOM, (32, 32), 2, name="DMS")
    key = RegionKey("track", "frame", ElementType.FLOAT32, timestamp=0)
    arr = np.arange(128 * 128, dtype=np.float32).reshape(128, 128)
    dms.put(key, DOM, arr)
    return dms, key, arr


def test_lru_hit_and_containment():
    dms, key, arr = _backend()
    c = SpatioTemporalCache(dms, prefetch=False, capacity_bytes=1 << 20)
    big = BoundingBox((0, 0), (64, 64))
    np.testing.assert_array_equal(c.get(key, big), arr[:64, :64])
    assert c.stats.misses == 1
    # contained ROI served from cache without touching the backend
    before = dms.transport.stats.gets
    sub = BoundingBox((16, 16), (48, 48))
    np.testing.assert_array_equal(c.get(key, sub), arr[16:48, 16:48])
    assert c.stats.hits == 1
    assert dms.transport.stats.gets == before


def test_eviction_under_capacity_pressure():
    dms, key, arr = _backend()
    c = SpatioTemporalCache(dms, prefetch=False, capacity_bytes=40_000)
    for i in range(4):
        roi = BoundingBox((0, i * 32), (64, (i + 1) * 32))  # 8KB each... 64*32*4=8KB
        c.get(key, roi)
    assert c.stats.evictions >= 0  # capacity respected
    assert c.stats.bytes_cached <= 40_000


def test_motion_prefetch_anticipates_next_roi():
    """Constant-velocity ROI stream: after two reads the third is
    prefetched (the paper's object-tracking scenario)."""
    dms, key, arr = _backend()
    c = SpatioTemporalCache(dms, prefetch=True)
    r0 = BoundingBox((0, 0), (32, 32))
    r1 = BoundingBox((0, 16), (32, 48))
    r2 = BoundingBox((0, 32), (32, 64))
    c.get(key, r0)
    c.get(key, r1)  # predicts r2 and prefetches it
    deadline = time.time() + 2.0
    while c.stats.prefetch_issued == 0 and time.time() < deadline:
        time.sleep(0.01)
    assert c.stats.prefetch_issued >= 1
    # wait for prefetch to land, then the next read is a (prefetch) hit
    time.sleep(0.1)
    before = c.stats.misses
    np.testing.assert_array_equal(c.get(key, r2), arr[0:32, 32:64])
    assert c.stats.misses == before  # no backend round-trip on the hot path


def test_temporal_prediction_follows_timestamps():
    dms, key, arr = _backend()
    key1 = key.at(1)
    dms.put(key1, DOM, arr + 1)
    key2 = key.at(2)
    dms.put(key2, DOM, arr + 2)
    c = SpatioTemporalCache(dms, prefetch=True)
    roi = BoundingBox((0, 0), (32, 32))
    c.get(key, roi)
    c.get(key1, roi)  # dt=1 -> predicts (t=2, same roi)
    time.sleep(0.2)
    before = c.stats.misses
    np.testing.assert_array_equal(c.get(key2, roi), arr[:32, :32] + 2)
    assert c.stats.misses == before


def test_write_through_invalidates():
    dms, key, arr = _backend()
    c = SpatioTemporalCache(dms, prefetch=False)
    roi = BoundingBox((0, 0), (32, 32))
    c.get(key, roi)
    c.put(key, DOM, arr * 2)  # overwrite through the cache
    np.testing.assert_array_equal(c.get(key, roi), arr[:32, :32] * 2)
