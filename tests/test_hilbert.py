"""Hilbert / Morton SFC property tests (DHT routing foundation)."""
from tests._prop import given, st

from repro.core import (
    hilbert_d2xy,
    hilbert_xy2d,
    morton_decode,
    morton_encode,
    sfc_index,
    sfc_order_for,
)


@given(st.integers(1, 6), st.data())
def test_hilbert_bijective(order, data):
    n = 1 << order
    x = data.draw(st.integers(0, n - 1))
    y = data.draw(st.integers(0, n - 1))
    d = hilbert_xy2d(order, x, y)
    assert 0 <= d < n * n
    assert hilbert_d2xy(order, d) == (x, y)


def test_hilbert_full_coverage_order3():
    order, n = 3, 8
    seen = {hilbert_xy2d(order, x, y) for x in range(n) for y in range(n)}
    assert seen == set(range(n * n))


def test_hilbert_locality_adjacent_d():
    """Consecutive curve positions are 4-neighbors (the locality property
    the paper's DHT exploits for range queries)."""
    order, n = 4, 16
    for d in range(n * n - 1):
        x1, y1 = hilbert_d2xy(order, d)
        x2, y2 = hilbert_d2xy(order, d + 1)
        assert abs(x1 - x2) + abs(y1 - y2) == 1


@given(st.integers(1, 5), st.lists(st.integers(0, 31), min_size=3, max_size=3))
def test_morton_roundtrip(order, coords):
    coords = tuple(c % (1 << order) for c in coords)
    d = morton_encode(order, coords)
    assert morton_decode(order, len(coords), d) == coords


def test_sfc_order_for():
    assert sfc_order_for(1) == 1
    assert sfc_order_for(16) == 4
    assert sfc_order_for(17) == 5


def test_sfc_index_dispatch():
    assert sfc_index(3, (1, 2)) == hilbert_xy2d(3, 1, 2)
    assert sfc_index(3, (1, 2, 3)) == morton_encode(3, (1, 2, 3))
