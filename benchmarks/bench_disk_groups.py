"""Fig. 12: disk staging — transports x I/O placement x group sizes.

Reproduces the Titan experiment shape in virtual time: many writers
staging 4Kx4K-tile masks; configurations over
  transport  in {posix, aggregated ("MPI")}
  placement  in {colocated, separated}
  group size in {1, 15, ALL}
The paper's claim: small I/O groups beat the stock single-group ADIOS
config by ~1.13x on application time.
"""
from __future__ import annotations

import shutil
import tempfile
import threading

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DiskStorage

N_WRITERS = 16
CHUNKS_PER_WRITER = 8
CHUNK = 64  # 64x64 f32 chunks stand in for 4K tiles


def _drive(store: DiskStorage) -> None:
    arr = np.ones((CHUNK, CHUNK), np.float32)

    def writer(w: int):
        for c in range(CHUNKS_PER_WRITER):
            key = RegionKey("stage", f"mask{w}", ElementType.FLOAT32, timestamp=c)
            store.put(key, BoundingBox((0, 0), (CHUNK, CHUNK)), arr)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(N_WRITERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    store.flush()


def run() -> list:
    rows = []
    results = {}
    for placement, workers in (("colocated", 0), ("separated", 8)):
        for transport in ("posix", "aggregated"):
            groups = (1,) if transport == "posix" else (1, 15, N_WRITERS)
            for g in groups:
                tmp = tempfile.mkdtemp(prefix="bench_disk_")
                store = DiskStorage(
                    tmp,
                    transport=transport,
                    io_mode=placement,
                    num_io_workers=workers,
                    io_group_size=g,
                    queue_threshold=4,
                )
                _drive(store)
                vt = store.stats.virtual_total_s
                name = f"{placement}_{transport}_g{g}"
                results[name] = vt
                rows.append(row(
                    f"fig12_{name}",
                    vt * 1e6,
                    f"files={store.stats.files_written},sync_s={store.stats.virtual_sync_s:.4f}",
                ))
                shutil.rmtree(tmp, ignore_errors=True)
    stock = results.get("colocated_aggregated_g16")
    best = min(v for k, v in results.items() if k.startswith("colocated"))
    if stock:
        rows.append(row(
            "fig12_smallgroup_speedup", best * 1e6,
            f"vs_stock_adios={stock/best:.2f}x(paper=1.13)",
        ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
