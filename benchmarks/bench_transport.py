"""Socket vs in-proc DMS transport: put/get throughput + metadata overhead.

Replays the tile-exchange pattern of Fig. 13/14 against the same
``DistributedMemoryStorage`` routing logic over both transports:

  * ``InProcTransport`` — direct calls into local shards (the upper
    bound: zero wire cost, virtual-time link model only);
  * ``SocketTransport`` — framed TCP to live ``ServerProcess`` hosts
    (2 processes x 2 shards), the real multi-host path.

Rows report per-tile put/get wall latency, wire throughput (MB/s), and
the metadata fraction of wire traffic (the paper's "metadata propagated,
payload stays home" claim means this must stay small).  Fast mode
(``REPRO_BENCH_FAST=1``) shrinks the grid for CI smoke runs.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 2 if FAST else 5
NUM_SERVERS = 4
PROCESSES = 2


def _exchange(store: DistributedMemoryStorage, dom: BoundingBox) -> dict:
    key = RegionKey("x", "Mask", ElementType.FLOAT32)
    arr = np.random.default_rng(0).random((TILE, TILE)).astype(np.float32)
    tiles = list(dom.tiles((TILE, TILE)))
    t0 = time.perf_counter()
    for box in tiles:
        store.put(key, box, arr)
    t_put = time.perf_counter() - t0
    t0 = time.perf_counter()
    for box in tiles:
        store.get(key, box)
    t_get = time.perf_counter() - t0
    n = len(tiles)
    moved = arr.nbytes * n
    stats = store.transport.stats
    meta_frac = stats.bytes_meta / max(stats.bytes_put + stats.bytes_get, 1)
    return {
        "put_us": t_put * 1e6 / n,
        "get_us": t_get * 1e6 / n,
        "put_mbs": moved / max(t_put, 1e-9) / 1e6,
        "get_mbs": moved / max(t_get, 1e-9) / 1e6,
        "meta_frac": meta_frac,
        "meta_msgs": stats.meta_msgs,
    }


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    rows = []

    inproc = DistributedMemoryStorage(dom, (TILE, TILE), NUM_SERVERS, name="DMS")
    r_in = _exchange(inproc, dom)
    rows.append(row("transport_inproc_put", r_in["put_us"],
                    f"{r_in['put_mbs']:.0f}MB/s"))
    rows.append(row("transport_inproc_get", r_in["get_us"],
                    f"{r_in['get_mbs']:.0f}MB/s"))

    with spawn_servers(NUM_SERVERS, processes=PROCESSES) as group:
        sock = DistributedMemoryStorage(
            dom, (TILE, TILE), NUM_SERVERS, name="DMS", transport=group.transport()
        )
        r_so = _exchange(sock, dom)
        sock.close()
    rows.append(row("transport_socket_put", r_so["put_us"],
                    f"{r_so['put_mbs']:.0f}MB/s,{PROCESSES}procs"))
    rows.append(row("transport_socket_get", r_so["get_us"],
                    f"{r_so['get_mbs']:.0f}MB/s"))
    rows.append(row("transport_socket_meta", 0.0,
                    f"meta_frac={r_so['meta_frac']:.4f},msgs={r_so['meta_msgs']}"))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
