"""Socket vs in-proc DMS transport: put/get throughput + metadata overhead.

Replays the tile-exchange pattern of Fig. 13/14 against the same
``DistributedMemoryStorage`` routing logic over both transports:

  * ``InProcTransport`` — direct calls into local shards (the upper
    bound: zero wire cost, virtual-time link model only);
  * ``SocketTransport`` — framed TCP to live ``ServerProcess`` hosts
    (2 processes x 2 shards), the real multi-host path.

Rows report per-tile put/get wall latency, wire throughput (MB/s), and
the metadata fraction of wire traffic (the paper's "metadata propagated,
payload stays home" claim means this must stay small).  Fast mode
(``REPRO_BENCH_FAST=1``) shrinks the grid for CI smoke runs.

Data-plane rows (both SELF-ASSERT their win, so a silent regression of
the zero-copy/compression machinery fails the benchmark, not just the
latency gate):

  * ``transport_shm_get`` — a co-located big-block fetch through
    :class:`ShmTransport` (control frame on the socket, payload by arena
    reference) vs the same fetch through the TCP stream; must be >=5x
    faster.
  * ``transport_zlib_get`` — uint8 label tiles fetched with the
    lossless ``zlib`` wire codec; wire bytes must be >=30% below raw
    bytes (``TransportStats.bytes_get`` vs ``bytes_get_raw``).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, time_call
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, ShmTransport, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 2 if FAST else 5
NUM_SERVERS = 4
PROCESSES = 2
BIG_MB = 4 if FAST else 8  # co-located zero-copy fetch payload
SHM_MIN_SPEEDUP = 5.0
ZLIB_MIN_REDUCTION = 0.30


def _label_tile(rng: np.random.Generator) -> np.ndarray:
    """A segmentation-label-shaped uint8 tile: piecewise-constant class
    regions (the compressible payload the astronomy/WSI workloads move),
    not uniform noise."""
    coarse = rng.integers(0, 8, (TILE // 16, TILE // 16), dtype=np.uint8)
    return np.kron(coarse, np.ones((16, 16), dtype=np.uint8))


def _exchange(store: DistributedMemoryStorage, dom: BoundingBox) -> dict:
    key = RegionKey("x", "Mask", ElementType.FLOAT32)
    arr = np.random.default_rng(0).random((TILE, TILE)).astype(np.float32)
    tiles = list(dom.tiles((TILE, TILE)))
    t0 = time.perf_counter()
    for box in tiles:
        store.put(key, box, arr)
    t_put = time.perf_counter() - t0
    t0 = time.perf_counter()
    for box in tiles:
        store.get(key, box)
    t_get = time.perf_counter() - t0
    n = len(tiles)
    moved = arr.nbytes * n
    stats = store.transport.stats
    meta_frac = stats.bytes_meta / max(stats.bytes_put + stats.bytes_get, 1)
    return {
        "put_us": t_put * 1e6 / n,
        "get_us": t_get * 1e6 / n,
        "put_mbs": moved / max(t_put, 1e-9) / 1e6,
        "get_mbs": moved / max(t_get, 1e-9) / 1e6,
        "meta_frac": meta_frac,
        "meta_msgs": stats.meta_msgs,
    }


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    rows = []

    inproc = DistributedMemoryStorage(dom, (TILE, TILE), NUM_SERVERS, name="DMS")
    r_in = _exchange(inproc, dom)
    rows.append(row("transport_inproc_put", r_in["put_us"],
                    f"{r_in['put_mbs']:.0f}MB/s"))
    rows.append(row("transport_inproc_get", r_in["get_us"],
                    f"{r_in['get_mbs']:.0f}MB/s"))

    with spawn_servers(NUM_SERVERS, processes=PROCESSES) as group:
        sock = DistributedMemoryStorage(
            dom, (TILE, TILE), NUM_SERVERS, name="DMS", transport=group.transport()
        )
        r_so = _exchange(sock, dom)
        sock.close()
    rows.append(row("transport_socket_put", r_so["put_us"],
                    f"{r_so['put_mbs']:.0f}MB/s,{PROCESSES}procs"))
    rows.append(row("transport_socket_get", r_so["get_us"],
                    f"{r_so['get_mbs']:.0f}MB/s"))
    rows.append(row("transport_socket_meta", 0.0,
                    f"meta_frac={r_so['meta_frac']:.4f},msgs={r_so['meta_msgs']}"))

    rows.append(_shm_row())
    rows.append(_zlib_row())
    return rows


def _shm_row():
    """Co-located big-block fetch: TCP stream vs shared-memory reference.

    Same server process, same resident block; the only difference is the
    data plane.  Self-asserts the >=5x ROADMAP target — the control
    frame costs ~50us regardless of payload size, while the stream pays
    a memcpy through the kernel socket buffers both ways.
    """
    key = RegionKey("bench", "Big", ElementType.UINT8)
    side = int((BIG_MB << 20) ** 0.5)
    box = BoundingBox((0, 0), (side, side))
    arr = np.random.default_rng(2).integers(0, 255, (side, side), dtype=np.uint8)
    with spawn_servers(1) as group:
        plain = group.transport()
        # zero_copy: fetch returns a read-only view into the mapped
        # arena — the paper's RDMA-window semantics, and the mode whose
        # cost is one ~50us control round-trip regardless of payload
        shm = ShmTransport(group.endpoints, zero_copy=True)
        plain.store(0, key, (0, 0), box, arr)
        t_sock = time_call(lambda: plain.fetch(0, key, (0, 0)), repeats=5)
        t_shm = time_call(lambda: shm.fetch(0, key, (0, 0)), repeats=5)
        got = shm.fetch(0, key, (0, 0))
        assert np.array_equal(got, arr), "shm fetch not bit-exact"
        assert shm.stats.shm_gets > 0, "fetches did not go through the arena"
        speedup = t_sock / max(t_shm, 1e-9)
        assert speedup >= SHM_MIN_SPEEDUP, (
            f"shm data plane only {speedup:.1f}x faster than the TCP stream "
            f"on a co-located {BIG_MB}MB fetch (need >={SHM_MIN_SPEEDUP}x): "
            f"socket={t_sock * 1e6:.0f}us shm={t_shm * 1e6:.0f}us"
        )
        plain.close()
        shm.close()
    return row("transport_shm_get", t_shm * 1e6,
               f"{speedup:.1f}x_vs_socket,{BIG_MB}MB")


def _zlib_row():
    """Label-tile fetches with the lossless wire codec.

    Self-asserts the >=30% wire-byte reduction on uint8 label tiles
    (stats split: ``bytes_get`` is what crossed the wire, ``bytes_get_raw``
    is what the application received)."""
    key = RegionKey("bench", "Labels", ElementType.UINT8)
    box = BoundingBox((0, 0), (TILE, TILE))
    rng = np.random.default_rng(3)
    tiles = [_label_tile(rng) for _ in range(4 if FAST else 16)]
    with spawn_servers(1) as group:
        z = group.transport(wire_codec="zlib")
        for i, t in enumerate(tiles):
            z.store(0, key, (i,), box, t)
        t0 = time.perf_counter()
        got = z.fetch_many(0, [(key, (i,)) for i in range(len(tiles))])
        dt = time.perf_counter() - t0
        for want, have in zip(tiles, got):
            assert np.array_equal(want, have), "zlib round-trip not bit-exact"
        s = z.stats
        reduction = 1.0 - s.bytes_get / max(s.bytes_get_raw, 1)
        assert reduction >= ZLIB_MIN_REDUCTION, (
            f"zlib wire codec saved only {reduction:.0%} on uint8 label tiles "
            f"(need >={ZLIB_MIN_REDUCTION:.0%}): wire={s.bytes_get} "
            f"raw={s.bytes_get_raw}"
        )
        z.close()
    return row("transport_zlib_get", dt * 1e6 / len(tiles),
               f"wire_reduction={reduction:.0%},{len(tiles)}tiles")


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
