"""Fig. 17: PATS sensitivity to speedup-estimate error.

Low-speedup ops get their *estimates* inflated by e%, high-speedup ops
deflated (the paper's confounding scheme).  Scheduling uses the estimate
(Task.est_speedup); execution cost uses the true speedup — exactly the
paper's setup.  Reported: makespan degradation vs the error-free run, the
FCFS comparison, and the share of low-speedup tasks landing on the GPU.
"""
from __future__ import annotations


from benchmarks.common import row
from repro.configs.wsi import PAPER_OP_COSTS, PAPER_OP_SPEEDUPS
from repro.runtime import SchedulerConfig, SimulatedWRM, Task, TaskCost, make_devices

LOW_OPS = {"RBC detection", "Morph. Open", "AreaThreshold", "FillHolles", "BWLabel"}
N_STAGES = 40


def _tasks(error_pct: float):
    tasks = []
    for s in range(N_STAGES):
        prev = None
        for op, sp in PAPER_OP_SPEEDUPS.items():
            t = Task(op, deps=[prev] if prev else [],
                     cost=TaskCost(cpu_s=PAPER_OP_COSTS[op], speedup=sp))
            est = sp * (1 + error_pct / 100.0) if op in LOW_OPS else sp * (
                1 - error_pct / 100.0
            )
            t.est_speedup = max(est, 0.01)
            tasks.append(t)
            prev = t
    return tasks


def run() -> list:
    rows = []
    devs = make_devices(12, 3)
    fcfs = SimulatedWRM(devs, SchedulerConfig(policy="FCFS")).run(_tasks(0)).makespan
    base = None
    for err in (0, 10, 25, 50, 60, 70, 80, 100):
        res = SimulatedWRM(devs, SchedulerConfig(policy="PATS")).run(_tasks(err))
        if base is None:
            base = res.makespan
        low_on_gpu = sum(res.accel_task_count.get(op, 0) for op in LOW_OPS)
        total_gpu = sum(res.accel_task_count.values())
        rows.append(row(
            f"fig17_err{err}",
            res.makespan * 1e6,
            f"degradation={res.makespan/base:.3f}x(paper@50%~1.08),"
            f"low_ops_gpu_share={low_on_gpu/max(total_gpu,1):.2f},"
            f"vs_fcfs={fcfs/res.makespan:.2f}x",
        ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
