"""Elastic rebalance throughput + foreground latency under a paced sweep.

Two claims of the elastic membership layer, kept honest:

  * **minimal migration** — after a join, ``rebalance()`` moves ONLY the
    blocks whose ideal placement changed under the new epoch (the SFC
    arc-donation bound, ~K/(N+1) of K blocks when server N+1 joins), and
    a second sweep is a no-op.  Self-asserted exactly on the in-proc leg
    (R=1: migrated == homes-changed count) and as a bound on the socket
    leg (R=2: replica sets widen the set, but never past ``scanned``).
  * **pacing yields to foreground traffic** — a TokenBucket-paced sweep
    caps migration throughput, so concurrent reads keep a bounded p99
    and zero failures while blocks drain between real server processes.

Rows report the per-migrated-block sweep latency (in-proc and over a
live socket join) and the foreground get p99 measured DURING a paced
socket sweep.  Fast mode (``REPRO_BENCH_FAST=1``) shrinks the grid for
CI smoke runs, where ``rebalance_socket_block`` and ``rebalance_fg_p99``
are gated against benchmarks/baseline.json.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, TokenBucket, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 4 if FAST else 8
# 3 servers: a join donates 1/12-wide arcs, wide enough that every
# donation contains block points even on the FAST 4x4 grid (a 4->5 join
# donates 1/20-wide arcs, which can legitimately contain ZERO of 16
# block points -- minimality means nothing moves)
NUM_SERVERS = 3
REPL = 2


def _key() -> RegionKey:
    return RegionKey("x", "Mask", ElementType.FLOAT32)


def _fill(store: DistributedMemoryStorage, dom: BoundingBox) -> np.ndarray:
    arr = np.random.default_rng(0).random((TILE, TILE)).astype(np.float32)
    for box in dom.tiles((TILE, TILE)):
        store.put(_key(), box, arr)
    return arr


def _homes(dms: DistributedMemoryStorage) -> dict:
    return {tuple(bc): dms.home_server(tuple(bc)) for bc in np.ndindex(*dms._grid)}


def _assert_sweep(dms: DistributedMemoryStorage, report: dict, changed: int):
    assert report["migrated"] > 0, f"nothing migrated: {report}"
    assert report["lost"] == 0, f"rebalance lost blocks: {report}"
    assert report["unreachable"] == 0, f"unreachable members: {report}"
    assert report["complete"] and report["directories_agree"], report
    # minimal migration: only placement-changed blocks move
    assert changed <= report["migrated"] <= report["scanned"], (
        f"migrated {report['migrated']} vs {changed} changed of "
        f"{report['scanned']} scanned"
    )
    # convergence: a second sweep finds nothing to do
    again = dms.rebalance()
    assert (again["migrated"], again["copies_added"], again["trimmed"]) == (
        0,
        0,
        0,
    ), again
    return report["migrated"]


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    blocks = GRID * GRID
    rows = []

    # -- in-proc: join at R=1, migration count is exact ---------------------------
    dms = DistributedMemoryStorage(dom, (TILE, TILE), NUM_SERVERS)
    _fill(dms, dom)
    before = _homes(dms)
    dms.add_server()
    after = _homes(dms)
    changed = sum(1 for bc in before if after[bc] != before[bc])
    # arc donation: the newcomer takes ~1/(N+1) of the blocks, nothing
    # shuffles between incumbents (rounding slack: one block per arc seam)
    assert 0 < changed <= blocks // (NUM_SERVERS + 1) + NUM_SERVERS + 1, changed
    t0 = time.perf_counter()
    report = dms.rebalance()
    elapsed = time.perf_counter() - t0
    # R=1: a block migrates iff its home changed
    assert report["migrated"] == changed, (report["migrated"], changed)
    migrated = _assert_sweep(dms, report, changed)
    rows.append(
        row(
            "rebalance_inproc_block",
            elapsed * 1e6 / migrated,
            f"migrated={migrated},changed={changed},epoch={report['epoch']}",
        )
    )
    dms.close()

    # -- socket: live join at R=2, then a paced sweep under foreground gets -------
    fleet = spawn_servers(NUM_SERVERS)
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=30.0, dead_backoff=0.5)
        dms = DistributedMemoryStorage(dom, (TILE, TILE), transport=tr, replication=REPL)
        arr = _fill(dms, dom)
        before = _homes(dms)
        sid, addr = fleet.add_server()
        assert dms.add_server(addr, sid=sid) == sid
        after = _homes(dms)
        changed = sum(1 for bc in before if after[bc] != before[bc])
        t0 = time.perf_counter()
        report = dms.rebalance()
        elapsed = time.perf_counter() - t0
        migrated = _assert_sweep(dms, report, changed)
        rows.append(
            row(
                "rebalance_socket_block",
                elapsed * 1e6 / migrated,
                f"migrated={migrated},changed={changed},epoch={report['epoch']}",
            )
        )

        # now DRAIN a server, paced: foreground gets run concurrently and
        # must see zero failures + a bounded p99 while its blocks move out
        victim = min(dms.membership.servers)
        pacer = TokenBucket(rate=120.0, burst=1.0)
        sweep_report: dict = {}

        def _sweep():
            sweep_report.update(dms.remove_server(victim, pacer=pacer))

        hot = BoundingBox((0, 0), (TILE, TILE))
        lat: list[float] = []
        t = threading.Thread(target=_sweep)
        t.start()
        while t.is_alive() or len(lat) < 50:
            g0 = time.perf_counter()
            out = dms.get(_key(), hot)
            lat.append(time.perf_counter() - g0)
            np.testing.assert_array_equal(out, arr)
        t.join()
        assert sweep_report["migrated"] > 0 and sweep_report["lost"] == 0, sweep_report
        assert sweep_report["paced_wait_s"] > 0.0, sweep_report
        lat.sort()
        p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
        assert p99 < 0.25, f"foreground p99 {p99*1e3:.1f}ms during paced sweep"
        rows.append(
            row(
                "rebalance_fg_p99",
                p99 * 1e6,
                f"gets={len(lat)},migrated={sweep_report['migrated']},"
                f"paced_wait_s={sweep_report['paced_wait_s']:.3f}",
            )
        )
        np.testing.assert_array_equal(dms.get(_key(), dom), np.tile(arr, (GRID, GRID)))
        dms.close()
    finally:
        fleet.close()
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
