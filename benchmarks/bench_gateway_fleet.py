"""Multi-tenant gateway fleet: zipf hot-key serving over one DMS fleet.

Two :class:`~repro.serve.gateway.RegionGateway` instances front one
shared DMS fleet (one transport, two client views) with fleet
generation gossip on — the deployment shape the serving tier is built
for.  Three phases, each self-asserting (a failure fails the harness
and therefore the CI gate):

* **hot reads** — many logical clients issue zipf-distributed window
  reads, spread across both gateways.  Asserts the response cache
  actually absorbs the skew (hit ratio over the whole run) and that
  every payload is bit-exact with the staged slide.
* **fairness** — a batch-priority hog floods one gateway with
  cache-defeating reads while interactive clients trickle theirs in.
  Asserts the interactive p99 stays strictly below the hog's p99 (the
  DRR weights are doing their job) — the gated metric is the
  interactive p99 itself.
* **cross-gateway invalidation** — alternating writes through one
  gateway, immediately read through the other.  Asserts bit-exactness
  right after each remote put: the ``gen`` gossip must invalidate the
  sibling's response cache synchronously with the put.

Fast mode (``REPRO_BENCH_FAST=1``) shrinks client count and read mix
for CI smoke runs.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.serve.gateway import GatewayConfig, RegionGateway
from repro.storage import DistributedMemoryStorage, Tier, TieredStore
from repro.storage.dms import InProcTransport

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 4  # 512 x 512 slide
CLIENTS = 64 if FAST else 1000       # logical client ids (zipf-ranked)
HOT_READS = 600 if FAST else 6000    # phase-1 total reads
THREADS = 8 if FAST else 16          # OS threads carrying the clients
HOG_THREADS = 3
HOG_READS = 30 if FAST else 120      # per hog thread
VIP_READS = 40 if FAST else 150
ZIPF_S = 1.1


def _fleet(slide: np.ndarray, dom: BoundingBox, key: RegionKey):
    """Two gateways over one DMS fleet (one shared transport)."""
    transport = InProcTransport(4)
    gateways, stores = [], []
    for i in range(2):
        dms = DistributedMemoryStorage(
            dom, (TILE, TILE), transport=transport, name=f"DMS{i}"
        )
        store = TieredStore([Tier("DMS", dms)], name=f"FLEET{i}")
        stores.append(store)
        gateways.append(
            RegionGateway(
                store,
                name=f"GW{i}",
                config=GatewayConfig(
                    workers=2, max_queue=256, fleet_generations=True
                ),
            )
        )
    for tile in dom.tiles((TILE, TILE)):
        stores[0].put(key, tile, slide[tile.slices()])
    return gateways, stores, transport


def _zipf_weights(n: int) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** ZIPF_S
    return w / w.sum()


def _percentile_us(samples: list[float], q: float) -> float:
    return float(np.percentile(np.array(samples) * 1e6, q))


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    key = RegionKey("bench", "Slide", ElementType.FLOAT32)
    slide = np.random.default_rng(0).random((side, side)).astype(np.float32)
    gateways, stores, transport = _fleet(slide, dom, key)

    # -- phase 1: zipf hot reads across both gateways ------------------------
    # candidate windows: the 16 aligned tiles, zipf-ranked; each logical
    # client's reads follow the global skew (hot tiles dominate)
    windows = list(dom.tiles((TILE, TILE)))
    rng = np.random.default_rng(1)
    picks = rng.choice(len(windows), size=HOT_READS, p=_zipf_weights(len(windows)))
    clients = rng.integers(0, CLIENTS, size=HOT_READS)
    latencies: list[float] = []
    lat_lock = threading.Lock()
    errors: list[BaseException] = []

    def hot_worker(tid: int) -> None:
        local: list[float] = []
        try:
            for i in range(tid, HOT_READS, THREADS):
                win = windows[picks[i]]
                gw = gateways[int(clients[i]) % 2]
                t0 = time.perf_counter()
                got = gw.submit(key, win, client=int(clients[i])).result(60.0)
                local.append(time.perf_counter() - t0)
                if not np.array_equal(got, slide[win.slices()]):
                    raise RuntimeError(f"fleet hot read mismatch at {win}")
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)
        with lat_lock:
            latencies.extend(local)

    t0 = time.perf_counter()
    threads = [
        threading.Thread(target=hot_worker, args=(t,)) for t in range(THREADS)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    hot_wall = time.perf_counter() - t0
    if errors:
        raise RuntimeError(f"fleet hot-read phase failed: {errors[0]}") from errors[0]
    hits = sum(gw.stats.response_cache_hits for gw in gateways)
    requests = sum(gw.stats.requests for gw in gateways)
    hit_ratio = hits / max(1, requests)
    # 16 windows x 2 gateways bounds the misses; zipf repeats must hit
    if hit_ratio < 0.5:
        raise RuntimeError(
            f"response cache not absorbing the zipf skew: hit ratio "
            f"{hit_ratio:.2f} < 0.5 ({hits}/{requests})"
        )

    # -- phase 2: batch hog vs interactive clients on one gateway ------------
    gw = gateways[0]
    hog_lat: list[float] = []
    vip_lat: list[float] = []

    def hog(tid: int) -> None:
        # a real hog: floods the queue with async submissions (bounded
        # only by admission), every ROI unique so the cache can't absorb
        # it — the backlog is what the DRR weights must contain
        local: list[float] = []
        pending: list[tuple[float, object]] = []
        try:
            for i in range(HOG_READS):
                off = (tid * HOG_READS + i) % (side - 96)
                roi = BoundingBox((off, off // 2), (off + 96, off // 2 + 96))
                pending.append(
                    (
                        time.perf_counter(),
                        gw.submit(key, roi, priority="batch", client=f"hog{tid}"),
                    )
                )
            for t0, ticket in pending:
                ticket.result(120.0)
                local.append(time.perf_counter() - t0)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)
        with lat_lock:
            hog_lat.extend(local)

    def vip() -> None:
        # interactive clients trickle one blocking read at a time while
        # the hog backlog is deep
        local: list[float] = []
        try:
            for i in range(VIP_READS):
                off = (7 * i + 3) % (side - 80)
                roi = BoundingBox((off // 2, off), (off // 2 + 80, off + 80))
                t0 = time.perf_counter()
                gw.submit(
                    key, roi, priority="interactive", client="vip"
                ).result(120.0)
                local.append(time.perf_counter() - t0)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)
        with lat_lock:
            vip_lat.extend(local)

    hogs = [threading.Thread(target=hog, args=(t,)) for t in range(HOG_THREADS)]
    vip_t = threading.Thread(target=vip)
    for t in hogs:
        t.start()
    vip_t.start()
    vip_t.join()
    for t in hogs:
        t.join()
    if errors:
        raise RuntimeError(f"fleet fairness phase failed: {errors[0]}") from errors[0]
    vip_p99 = _percentile_us(vip_lat, 99)
    hog_p99 = _percentile_us(hog_lat, 99)
    if vip_p99 >= hog_p99:
        raise RuntimeError(
            f"fairness regression: interactive p99 {vip_p99:.0f}us not below "
            f"batch-hog p99 {hog_p99:.0f}us"
        )

    # -- phase 3: cross-gateway put -> immediate sibling read ----------------
    inval_rounds = 8
    t0 = time.perf_counter()
    for i in range(inval_rounds):
        win = windows[i % len(windows)]
        writer, reader = gateways[i % 2], gateways[(i + 1) % 2]
        shape = tuple(h - l for l, h in zip(win.lo, win.hi))
        payload = np.full(shape, float(i) + 0.25, np.float32)
        writer.put(key, win, payload)
        got = reader.get(key, win)  # the very next read through the sibling
        if not np.array_equal(got, payload):
            raise RuntimeError(
                f"stale read after cross-gateway put (round {i}, {win})"
            )
        if not np.array_equal(got, reader.store.get(key, win)):
            raise RuntimeError(f"gateway read diverges from direct read ({win})")
        slide[win.slices()] = payload  # keep the reference current
    inval_wall = time.perf_counter() - t0

    for gw_ in gateways:
        gw_.close(close_store=False)
    for store in stores:
        store.close()

    return [
        row(
            "gateway_fleet_hot_read",
            hot_wall * 1e6 / HOT_READS,
            f"hit_ratio={hit_ratio:.2f},clients={CLIENTS},threads={THREADS}",
        ),
        row(
            "gateway_fleet_interactive_p99",
            vip_p99,
            f"hog_p99={hog_p99:.0f}us,hogs={HOG_THREADS}x{HOG_READS}",
        ),
        row(
            "gateway_fleet_cross_invalidate",
            inval_wall * 1e6 / inval_rounds,
            f"rounds={inval_rounds},bit_exact=yes",
        ),
    ]


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
