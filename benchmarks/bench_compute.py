"""Near-data compute: server-side kernel chains vs shipping raw regions.

The paper's case studies push computation to the data (hierarchical
stages, §3; the astronomy service's server-side quantitative queries);
this module measures that trade for the serving path: a
``deconv|threshold`` chain over a large RGB ROI executed via
``RegionGateway.compute()`` — the client receives a uint8 segmentation
mask instead of the float32 RGB window.

The module FAILS (failing the harness and the CI gate) unless
  * the gateway result is bit-exact with a local fetch + chain run,
  * the derived reply is >= 10x smaller than the raw ROI it replaces,
  * a repeated (derived-cache hit) query is >= 5x faster than the cold
    compute.

Fast mode (``REPRO_BENCH_FAST=1``) shrinks the ROI from 4096x4096 to
1024x1024 for CI smoke runs; the assertions are identical.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row, time_call
from repro.core import BoundingBox, ElementType, RegionKey
from repro.kernels.chains import resolve_chain
from repro.serve.gateway import GatewayConfig, RegionGateway
from repro.storage import DistributedMemoryStorage, Tier, TieredStore

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
SIDE = 1024 if FAST else 4096
TILE = 256
CHAIN = "deconv|threshold"


def _staged_store(dom: BoundingBox, key: RegionKey) -> tuple[TieredStore, np.ndarray]:
    dms = DistributedMemoryStorage(dom, (3, TILE, TILE), 4, name="DMS")
    store = TieredStore([Tier("DMS", dms)], name="NDC-BENCH")
    rgb = np.random.default_rng(0).random((3, SIDE, SIDE)).astype(np.float32)
    for tile in dom.tiles((3, TILE, TILE)):
        store.put(key, tile, rgb[tile.slices()])
    return store, rgb


def run() -> list:
    dom = BoundingBox((0, 0, 0), (3, SIDE, SIDE))
    key = RegionKey("bench", "HE", ElementType.FLOAT32)
    store, rgb = _staged_store(dom, key)
    roi = dom
    chain = resolve_chain(CHAIN)

    raw_s = time_call(store.get, key, roi)
    raw_bytes = rgb.nbytes

    # cold path: cache disabled so repeats measure the compute, not the hit
    gw = RegionGateway(
        store, config=GatewayConfig(workers=2, compute_cache_bytes=0)
    )
    mask = gw.compute(key, roi, CHAIN)  # warmup (jit compile)
    want = chain(store.get(key, roi), impl=gw.config.compute_impl)
    if not (np.array_equal(mask, want) and mask.dtype == want.dtype):
        raise RuntimeError("gateway compute() is not bit-exact with local fetch+chain")
    if raw_bytes < 10 * mask.nbytes:
        raise RuntimeError(
            f"egress regression: raw ROI {raw_bytes} B is not >=10x the "
            f"derived mask {mask.nbytes} B"
        )
    cold_s = time_call(gw.compute, key, roi, CHAIN)
    gw.close(close_store=False)

    # cached path: same query twice through a caching gateway
    gwc = RegionGateway(store, config=GatewayConfig(workers=2))
    t0 = time.perf_counter()
    first = gwc.compute(key, roi, CHAIN)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    again = gwc.compute(key, roi, CHAIN)
    warm_s = time.perf_counter() - t0
    if not np.array_equal(first, again):
        raise RuntimeError("cached repeat diverged from the cold result")
    if gwc.stats.compute_cache_hits != 1:
        raise RuntimeError("repeated query did not hit the derived cache")
    if warm_s * 5 > first_s:
        raise RuntimeError(
            f"derived-cache speedup regression: cached {warm_s*1e3:.1f}ms "
            f"not >=5x faster than cold {first_s*1e3:.1f}ms"
        )
    gwc.close(close_store=False)
    store.close()

    return [
        row(
            "compute_raw_read",
            raw_s * 1e6,
            f"bytes={raw_bytes}",
        ),
        row(
            "compute_deconv_roi",
            cold_s * 1e6,
            f"roi={SIDE}x{SIDE},mask_bytes={mask.nbytes},"
            f"egress={raw_bytes / mask.nbytes:.0f}x_less",
        ),
        row(
            "compute_deconv_cached",
            warm_s * 1e6,
            f"speedup={first_s / warm_s:.0f}x",
        ),
    ]


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
