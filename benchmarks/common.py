"""Shared benchmark helpers: timing + CSV rows."""
from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> tuple[str, float, str]:
    return (name, us_per_call, derived)


def emit(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
