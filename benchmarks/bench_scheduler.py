"""Fig. 15: cooperative CPU-GPU execution configurations (virtual time).

Versions (paper S5.5): CPUs-only, GPUs-only, GPUs+CPUs 1-level (a stage is
one bundled task), GPUs+CPUs 2-level hierarchical (fine-grain ops as
tasks) under FCFS vs PATS, then +DL and +Pref.  Node model: 12 CPU cores +
3 GPUs; per-op costs/speedups follow the paper's profile (Fig. 16).
"""
from __future__ import annotations


from benchmarks.common import row
from repro.configs.wsi import PAPER_OP_COSTS, PAPER_OP_SPEEDUPS
from repro.runtime import SchedulerConfig, SimulatedWRM, Task, TaskCost, make_devices

SEG_OPS = ["Color deconv.", "RBC detection", "Morph. Open", "ReconToNuclei",
           "AreaThreshold", "FillHolles", "Pre-Watershed", "Watershed",
           "BWLabel", "Canny", "Gradient"]
FEAT_OPS = ["Features"]
N_STAGES = 60
TILE_BYTES = 48 * 1024 * 1024  # 4Kx4K x 3 channels uint8
SCALE = 0.05  # PAPER_OP_COSTS units -> seconds (transfers ~ paper's 12%)


def _two_level_tasks():
    tasks = []
    for s in range(N_STAGES):
        prev = None
        for op in SEG_OPS + FEAT_OPS:
            t = Task(
                f"{op}#{s}",
                deps=[prev] if prev else [],
                cost=TaskCost(
                    cpu_s=PAPER_OP_COSTS[op] * SCALE,
                    speedup=PAPER_OP_SPEEDUPS[op],
                    input_bytes=TILE_BYTES,
                    output_bytes=TILE_BYTES,
                ),
            )
            t.name = op  # group by op for profiles
            tasks.append(t)
            prev = t
    return tasks


def _one_level_tasks():
    total_cpu = sum(PAPER_OP_COSTS[o] for o in SEG_OPS + FEAT_OPS) * SCALE
    total_gpu = sum(
        PAPER_OP_COSTS[o] * SCALE / PAPER_OP_SPEEDUPS[o] for o in SEG_OPS + FEAT_OPS
    )
    bundle_speedup = total_cpu / total_gpu
    return [
        Task(
            f"stage#{s}",
            cost=TaskCost(cpu_s=total_cpu, speedup=bundle_speedup,
                          input_bytes=TILE_BYTES, output_bytes=TILE_BYTES),
        )
        for s in range(N_STAGES)
    ]


def run() -> list:
    cpus_only = SimulatedWRM(make_devices(12, 0), SchedulerConfig(policy="FCFS")).run(
        _two_level_tasks()
    ).makespan
    gpus_only = SimulatedWRM(make_devices(0, 3), SchedulerConfig(policy="FCFS")).run(
        _two_level_tasks()
    ).makespan
    coop_1l = SimulatedWRM(make_devices(12, 3), SchedulerConfig(policy="FCFS")).run(
        _one_level_tasks()
    ).makespan
    coop_2l_fcfs = SimulatedWRM(make_devices(12, 3), SchedulerConfig(policy="FCFS")).run(
        _two_level_tasks()
    ).makespan
    coop_2l_pats = SimulatedWRM(make_devices(12, 3), SchedulerConfig(policy="PATS")).run(
        _two_level_tasks()
    ).makespan
    pats_dl = SimulatedWRM(
        make_devices(12, 3),
        SchedulerConfig(policy="PATS", data_locality=True, transfer_impact=0.45),
    ).run(_two_level_tasks()).makespan
    pats_dl_pref = SimulatedWRM(
        make_devices(12, 3),
        SchedulerConfig(policy="PATS", data_locality=True, transfer_impact=0.45,
                        prefetch=True),
    ).run(_two_level_tasks()).makespan

    base = cpus_only
    rows = [
        row("fig15_cpus_only", cpus_only * 1e6, "speedup=1.00x"),
        row("fig15_gpus_only", gpus_only * 1e6, f"speedup={base/gpus_only:.2f}x(paper~2.25)"),
        row("fig15_coop_1L_fcfs", coop_1l * 1e6, f"speedup={base/coop_1l:.2f}x(paper~2.9)"),
        row("fig15_coop_2L_fcfs", coop_2l_fcfs * 1e6, f"speedup={base/coop_2l_fcfs:.2f}x"),
        row("fig15_coop_2L_pats", coop_2l_pats * 1e6,
            f"speedup={base/coop_2l_pats:.2f}x(paper~4;pats_over_fcfs="
            f"{coop_2l_fcfs/coop_2l_pats:.2f}x~1.38)"),
        row("fig15_2L_pats_dl", pats_dl * 1e6,
            f"dl_gain={coop_2l_pats/pats_dl:.3f}x(paper~1.05)"),
        row("fig15_2L_pats_dl_pref", pats_dl_pref * 1e6,
            f"pref_gain={pats_dl/pats_dl_pref:.3f}x(paper~1.03);total="
            f"{base/pats_dl_pref:.2f}x(paper~4.34)"),
    ]
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
