"""Anti-entropy repair throughput + read-replica load balancing.

Two claims of the self-healing replication layer, kept honest:

  * **repair()** — a server that rejoined empty is re-filled at
    near-transport speed: the sweep pays ONE fetch + ONE store per
    under-replicated block (self-asserted via ``TransportStats`` byte
    counters — repair bandwidth tracks the link, not directory chatter),
    and a second sweep is a no-op.
  * **read balancing** — a hot key's fetches spread over its replicas:
    with R=2 neither replica serves more than 70% of the gets
    (self-asserted via ``DMSStats.balanced_fetches``), so replication
    buys read bandwidth on a healthy fleet, not only availability.

Rows report the per-block repair latency (in-proc and over a real
killed-and-restarted socket server) and the per-get hot-key latency with
the measured primary share.  Fast mode (``REPRO_BENCH_FAST=1``) shrinks
the grid for CI smoke runs, where ``repair_socket_block`` is gated
against benchmarks/baseline.json.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, TransportError, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 2 if FAST else 4
NUM_SERVERS = 4
REPL = 2


def _key() -> RegionKey:
    return RegionKey("x", "Mask", ElementType.FLOAT32)


def _fill(store: DistributedMemoryStorage, dom: BoundingBox) -> int:
    arr = np.random.default_rng(0).random((TILE, TILE)).astype(np.float32)
    tiles = list(dom.tiles((TILE, TILE)))
    for box in tiles:
        store.put(_key(), box, arr)
    return arr.nbytes * len(tiles)


def _timed_repair(dms: DistributedMemoryStorage) -> tuple[float, dict]:
    t0 = time.perf_counter()
    report = dms.repair()
    return time.perf_counter() - t0, report


def _assert_repair(dms: DistributedMemoryStorage, report: dict, block_bytes: int):
    repaired = report["repaired"]
    assert repaired > 0, f"nothing repaired: {report}"
    assert report["lost"] == 0, f"repair lost blocks: {report}"
    stats = dms.transport.stats
    moved = repaired * block_bytes
    # one fetch + one store per repaired block: payload dominates, wire
    # framing and the directory sweep add only a sliver on top
    assert moved <= stats.bytes_get <= 1.5 * moved + 65536, (
        f"repair read {stats.bytes_get} bytes for {moved} repaired"
    )
    assert moved <= stats.bytes_put <= 1.5 * moved + 65536, (
        f"repair wrote {stats.bytes_put} bytes for {moved} repaired"
    )
    # convergence: a second sweep finds nothing to do
    again = dms.repair()
    assert again["repaired"] == 0 and again["lost"] == 0, again
    return repaired


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    block_bytes = TILE * TILE * 4
    rows = []

    # -- in-proc: wipe one shard, sweep ------------------------------------------
    dms = DistributedMemoryStorage(dom, (TILE, TILE), NUM_SERVERS, replication=REPL)
    _fill(dms, dom)
    shard = dms.transport.servers[1]
    shard._blocks.clear()
    shard._meta.clear()
    dms.transport.reset()
    elapsed, report = _timed_repair(dms)
    repaired = _assert_repair(dms, report, block_bytes)
    rows.append(
        row(
            "repair_inproc_block",
            elapsed * 1e6 / repaired,
            f"repaired={repaired},meta_fixes={report['meta_fixes']}",
        )
    )
    dms.close()

    # -- read balancing: hot single-block key at R=2 ------------------------------
    dms = DistributedMemoryStorage(dom, (TILE, TILE), NUM_SERVERS, replication=REPL)
    _fill(dms, dom)
    hot = BoundingBox((0, 0), (TILE, TILE))
    gets = 64
    dms.stats.reset()
    t0 = time.perf_counter()
    for _ in range(gets):
        dms.get(_key(), hot)
    t_get = time.perf_counter() - t0
    spread = dms.stats.balanced_fetches
    share = 1.0 - spread / gets  # fraction served by the primary
    assert dms.stats.failover_fetches == 0, "healthy fleet counted failovers"
    assert 0.3 <= share <= 0.7, (
        f"hot-key spread broken: primary served {share:.0%} of {gets} gets"
    )
    rows.append(
        row(
            "repair_read_spread",
            t_get * 1e6 / gets,
            f"primary_share={share:.2f},balanced={spread}",
        )
    )
    dms.close()

    # -- socket: kill a real server, restart empty, sweep --------------------------
    fleet = spawn_servers(NUM_SERVERS)
    try:
        tr = fleet.transport(connect_timeout=5.0, op_timeout=30.0, dead_backoff=0.5)
        dms = DistributedMemoryStorage(dom, (TILE, TILE), transport=tr, replication=REPL)
        _fill(dms, dom)
        fleet.procs[1].kill()
        fleet.procs[1].start()  # same port, empty shard
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            try:
                tr.ping(1)
                break
            except TransportError:
                time.sleep(0.05)
        tr.reset()
        elapsed, report = _timed_repair(dms)
        repaired = _assert_repair(dms, report, block_bytes)
        rows.append(
            row(
                "repair_socket_block",
                elapsed * 1e6 / repaired,
                f"repaired={repaired},meta_fixes={report['meta_fixes']}",
            )
        )
        dms.close()
    finally:
        fleet.close()
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
