"""Replicated vs unreplicated DMS put/get cost (in-proc + socket).

R-way replication buys availability (any R-1 dead servers cause zero
failed reads) by writing every payload block to R servers along the SFC
virtual-domain ring.  The bargain to keep honest: puts pay ~R x the
payload bytes (write amplification), while reads must stay flat — a
healthy fleet serves every block from its primary, so the replicas cost
nothing on the read path.

Rows report per-tile put/get wall latency at R=1 vs R=2 over both
transports plus the measured byte amplification; the module self-asserts
that bytes_put doubles and bytes_get does not.  Fast mode
(``REPRO_BENCH_FAST=1``) shrinks the grid for CI smoke runs, where
``replication_socket_*_r2`` are gated against benchmarks/baseline.json.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 2 if FAST else 4
NUM_SERVERS = 4
PROCESSES = 2
REPL = 2


def _exchange(store: DistributedMemoryStorage, dom: BoundingBox) -> dict:
    key = RegionKey("x", "Mask", ElementType.FLOAT32)
    arr = np.random.default_rng(0).random((TILE, TILE)).astype(np.float32)
    tiles = list(dom.tiles((TILE, TILE)))
    t0 = time.perf_counter()
    for box in tiles:
        store.put(key, box, arr)
    t_put = time.perf_counter() - t0
    t0 = time.perf_counter()
    for box in tiles:
        store.get(key, box)
    t_get = time.perf_counter() - t0
    n = len(tiles)
    stats = store.transport.stats
    return {
        "put_us": t_put * 1e6 / n,
        "get_us": t_get * 1e6 / n,
        "bytes_put": stats.bytes_put,
        "bytes_get": stats.bytes_get,
        "payload": arr.nbytes * n,
    }


def _pair(make_store, dom: BoundingBox, *, check_balance=False) -> tuple[dict, dict, float]:
    """(r1, r2, put amplification): same exchange at both factors."""
    store1 = make_store(1)
    r1 = _exchange(store1, dom)
    store1.close()
    store2 = make_store(REPL)
    r2 = _exchange(store2, dom)
    if check_balance:
        # the SFC balance check at R>1 must use the replica-aware view:
        # physical bytes double-count replica copies, the primary split
        # reflects the range partition (in-proc only: socket fleets are
        # shared across scopes, so physical bytes mix both factors)
        prim = store2.server_load(by_role=True)["primary"]
        assert max(prim) <= 2 * max(1, min(prim)), f"primary imbalance: {prim}"
    store2.close()
    amp = r2["bytes_put"] / max(r1["bytes_put"], 1)
    # the replication bargain, self-asserted: puts pay ~R x the bytes
    # (wire framing adds a little on the socket), reads stay flat
    assert REPL <= amp < REPL + 0.5, f"write amplification {amp} != ~{REPL}"
    get_ratio = r2["bytes_get"] / max(r1["bytes_get"], 1)
    assert get_ratio < 1.1, f"replicated reads moved {get_ratio}x the bytes"
    return r1, r2, amp


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    rows = []

    def make_inproc(r: int) -> DistributedMemoryStorage:
        return DistributedMemoryStorage(
            dom, (TILE, TILE), NUM_SERVERS, name="DMS", replication=r
        )

    r1, r2, amp = _pair(make_inproc, dom, check_balance=True)
    rows.append(row("replication_inproc_put_r1", r1["put_us"], "baseline"))
    rows.append(row("replication_inproc_put_r2", r2["put_us"],
                    f"amp={amp:.2f}x"))
    rows.append(row("replication_inproc_get_r2", r2["get_us"],
                    f"vs_r1={r2['get_us'] / max(r1['get_us'], 1e-9):.2f}x"))

    with spawn_servers(NUM_SERVERS, processes=PROCESSES) as group:

        def make_socket(r: int) -> DistributedMemoryStorage:
            # one scope per factor: both stores share the fleet untangled
            return DistributedMemoryStorage(
                dom, (TILE, TILE), name="DMS", replication=r,
                transport=group.transport(scope=f"r{r}"),
            )

        r1, r2, amp = _pair(make_socket, dom)
    rows.append(row("replication_socket_put_r1", r1["put_us"], "baseline"))
    rows.append(row("replication_socket_put_r2", r2["put_us"],
                    f"amp={amp:.2f}x,{PROCESSES}procs"))
    rows.append(row("replication_socket_get_r2", r2["get_us"],
                    f"vs_r1={r2['get_us'] / max(r1['get_us'], 1e-9):.2f}x"))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
