"""Region gateway: coalesced shared reads vs naive per-client reads.

Many clients reading overlapping ROI windows of one region is the
serving-path analogue of the paper's inter-stage exchange (Fig. 13/14):
the interesting cost is transport round-trips, not wall-clock.  This
module replays the same overlapping read mix two ways against a
DMS-tier store over BOTH transports:

  * naive   — every read goes straight to the store: one ``lookup`` +
    one scatter-gather ``fetch_many`` per touched server, per read;
  * gateway — the reads are queued on a ``RegionGateway`` and drained
    by its worker pool, which merges overlapping/adjacent ROIs into
    windows and issues one store read per window.

The round-trip counts come from ``TransportStats`` (gets + meta_msgs),
and the module FAILS (which fails the benchmark harness and therefore
the CI gate) if the gateway does not issue strictly fewer round-trips
than the naive replay.  Fast mode (``REPRO_BENCH_FAST=1``) shrinks the
mix for CI smoke runs.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.serve.gateway import GatewayConfig, RegionGateway
from repro.storage import DistributedMemoryStorage, Tier, TieredStore, spawn_servers

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 4 if FAST else 8
CLIENTS = 4 if FAST else 8
READS = 6 if FAST else 20
WINDOW = 160


def _read_mix(side: int) -> list[BoundingBox]:
    """CLIENTS x READS overlapping windows: a shared hot band plus a
    deterministic scatter (heavy cross-client overlap, like concurrent
    stages sweeping the same slide)."""
    rng = np.random.default_rng(2)
    rois = []
    for c in range(CLIENTS):
        for r in range(READS):
            if r % 2 == 0:
                y, x = (r * 32) % (side - WINDOW), 64
            else:
                y = int(rng.integers(0, side - WINDOW))
                x = int(rng.integers(0, side - WINDOW))
            rois.append(BoundingBox((y, x), (y + WINDOW, x + WINDOW)))
    return rois


def _round_trips(transport) -> int:
    return transport.stats.gets + transport.stats.meta_msgs


def _measure(transport_name: str, dms: DistributedMemoryStorage) -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    key = RegionKey("bench", "Slide", ElementType.FLOAT32)
    slide = np.random.default_rng(0).random((side, side)).astype(np.float32)
    # single DMS tier: every read pays the transport, so the frame counts
    # isolate exactly what coalescing saves (no promotion noise)
    store = TieredStore([Tier("DMS", dms)], name="GW-BENCH")
    for tile in dom.tiles((TILE, TILE)):
        store.put(key, tile, slide[tile.slices()])
    rois = _read_mix(side)
    transport = dms.transport

    transport.reset()
    t0 = time.perf_counter()
    for roi in rois:
        store.get(key, roi)
    naive_wall = time.perf_counter() - t0
    naive_rtts = _round_trips(transport)

    # max_queue must admit the whole paused burst (160 reads in full mode)
    gw = RegionGateway(
        store,
        config=GatewayConfig(workers=2, batch_window=64, max_queue=len(rois)),
    )
    gw.pause()  # queue the whole burst so the drain is maximally batched
    tickets = [gw.submit(key, roi) for roi in rois]
    transport.reset()
    t0 = time.perf_counter()
    gw.resume()
    outs = [t.result(120.0) for t in tickets]
    gw_wall = time.perf_counter() - t0
    gw_rtts = _round_trips(transport)
    for roi, out in zip(rois, outs):
        if not np.array_equal(out, slide[roi.slices()]):
            raise RuntimeError(f"gateway read mismatch at {roi} ({transport_name})")
    if gw_rtts >= naive_rtts:
        raise RuntimeError(
            f"gateway coalescing regression ({transport_name}): "
            f"{gw_rtts} round-trips not fewer than naive {naive_rtts}"
        )
    stats = gw.stats
    gw.close(close_store=False)
    store.close()

    n = len(rois)
    return [
        row(
            f"gateway_{transport_name}_naive",
            naive_wall * 1e6 / n,
            f"rtts={naive_rtts}",
        ),
        row(
            f"gateway_{transport_name}_read",
            gw_wall * 1e6 / n,
            f"rtts={gw_rtts},{naive_rtts / gw_rtts:.1f}x_fewer,"
            f"windows={stats.windows},coalesced={stats.coalesced}",
        ),
    ]


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    rows = _measure(
        "inproc", DistributedMemoryStorage(dom, (TILE, TILE), 4, name="DMS")
    )
    with spawn_servers(4, processes=2) as group:
        rows += _measure(
            "socket",
            DistributedMemoryStorage(
                dom, (TILE, TILE), 4, name="DMS", transport=group.transport()
            ),
        )
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
