"""Table 1: single-node multi-core / multi-GPU scalability (virtual time).

Multi-core runs model the paper's memory-bandwidth ceiling: each task's
effective time is max(compute, memory_bytes / node_bandwidth-share) so the
12-core run lands sub-linear (paper: 10.1x; 10.9x with DL).
"""
from __future__ import annotations

from benchmarks.common import row
from repro.configs.wsi import PAPER_OP_COSTS, PAPER_OP_SPEEDUPS
from repro.runtime import SchedulerConfig, SimulatedWRM, Task, TaskCost, make_devices

OPS = list(PAPER_OP_COSTS)
N_STAGES = 48
MEM_FRACTION = 0.18  # fraction of each op that is bandwidth-bound


def _tasks(mem_penalty: float):
    """mem_penalty inflates cpu_s to model shared-bandwidth contention."""
    tasks = []
    for s in range(N_STAGES):
        prev = None
        for op in OPS:
            cpu = PAPER_OP_COSTS[op] * (1.0 + MEM_FRACTION * mem_penalty)
            t = Task(
                op,
                deps=[prev] if prev else [],
                cost=TaskCost(cpu_s=cpu, speedup=PAPER_OP_SPEEDUPS[op],
                              input_bytes=8_000_000, output_bytes=8_000_000),
            )
            tasks.append(t)
            prev = t
    return tasks


def run() -> list:
    rows = []
    base = SimulatedWRM(make_devices(1, 0), SchedulerConfig(policy="FCFS")).run(
        _tasks(0.0)
    ).makespan
    for n in (2, 4, 6, 8, 10, 12):
        contention = (n - 1) / 11.0  # saturates at 12 cores
        mk = SimulatedWRM(make_devices(n, 0), SchedulerConfig(policy="FCFS")).run(
            _tasks(contention)
        ).makespan
        # DL reduces the contention term (cache/NUMA reuse)
        mk_dl = SimulatedWRM(
            make_devices(n, 0),
            SchedulerConfig(policy="FCFS", data_locality=True),
        ).run(_tasks(contention * 0.55)).makespan
        rows.append(row(f"tab1_cpu{n}", mk * 1e6,
                        f"speedup={base/mk:.1f}x,dl={base/mk_dl:.1f}x"))
    gpu1 = SimulatedWRM(make_devices(0, 1), SchedulerConfig(policy="FCFS")).run(
        _tasks(0.0)
    ).makespan
    for g in (2, 3):
        mk = SimulatedWRM(make_devices(0, g), SchedulerConfig(policy="FCFS")).run(
            _tasks(0.0)
        ).makespan
        rows.append(row(f"tab1_gpu{g}", mk * 1e6,
                        f"speedup_vs_1gpu={gpu1/mk:.2f}x(paper:{1.94 if g==2 else 2.82})"))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
