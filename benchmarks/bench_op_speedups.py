"""Fig. 16: per-operation cost profile.

Measures the real wall time of each pipeline operation (xla reference
implementations on this host) and reports it next to the paper's measured
GPU speedup for that operation — the inputs PATS runs on.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.configs.wsi import PAPER_OP_SPEEDUPS, WSIConfig
from repro.kernels import ops, ref
from repro.pipeline import make_tile

TILE = 128


def run() -> list:
    cfg = WSIConfig(seg_threshold=0.5)
    rgb, _ = make_tile(TILE, num_nuclei=8, seed=0)
    rgb = jnp.asarray(rgb)
    minv = jnp.asarray(ref.stain_inverse())
    stains = ops.color_deconv(rgb, minv, impl="xla")
    hema = jnp.clip(stains[0] / jnp.maximum(jnp.percentile(stains[0], 99.5), 1e-6), 0, 1)
    raw = (hema > cfg.seg_threshold).astype(jnp.float32)
    marker = jnp.minimum(raw, jnp.roll(raw, 1, -1) * jnp.roll(raw, -1, -1))
    mask_i = (raw > 0.5).astype(jnp.int32)
    bins = ref.quantize_ref(hema[None], cfg.num_bins)

    cases = {
        "Color deconv.": lambda: ops.color_deconv(rgb, minv, impl="xla").block_until_ready(),
        "AreaThreshold": lambda: (hema > cfg.seg_threshold).astype(jnp.float32).block_until_ready(),
        "FillHolles": lambda: ops.fill_holes(raw, impl="xla").block_until_ready(),
        "ReconToNuclei": lambda: ops.morph_recon(marker, raw, impl="xla").block_until_ready(),
        "BWLabel": lambda: ops.connected_components(mask_i, impl="xla").block_until_ready(),
        "Features": lambda: ops.texture_features(bins, cfg.num_bins, impl="xla").block_until_ready(),
    }
    rows = []
    for op, fn in cases.items():
        us = time_call(fn, repeats=3, warmup=1) * 1e6
        rows.append(row(
            f"fig16_{op.replace(' ', '_').replace('.', '')}",
            us,
            f"paper_gpu_speedup={PAPER_OP_SPEEDUPS.get(op, float('nan')):.1f}x",
        ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
