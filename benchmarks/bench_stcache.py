"""§7 extension: spatio-temporal cache on a tracking access pattern.

A synthetic object-tracking client reads a moving ROI across frames
(exactly the paper's cell-tracking motivation).  We compare backend
round-trips with/without the predictive cache, plus the I/O auto-tuner's
chosen configuration.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DistributedMemoryStorage, SpatioTemporalCache
from repro.storage.autotune import autotune_io

DOM = BoundingBox((0, 0), (512, 512))
FRAMES = 24
ROI = 64
STEP = 12  # constant drift per frame


def _tracking_reads(read_store, backend):
    arr = np.random.default_rng(0).random((512, 512), dtype=np.float32)
    base = RegionKey("track", "frame", ElementType.FLOAT32)
    for t in range(FRAMES):
        backend.put(base.at(t), DOM, arr)  # frames land in global storage
    t0 = time.perf_counter()
    for t in range(FRAMES):
        lo = min(t * STEP, 512 - ROI)
        roi = BoundingBox((lo, lo), (lo + ROI, lo + ROI))
        read_store.get(base.at(t), roi)
        time.sleep(0.002)  # per-frame "compute" the prefetch hides under
    return time.perf_counter() - t0


def run() -> list:
    rows = []
    raw = DistributedMemoryStorage(DOM, (128, 128), 4)
    t_raw = _tracking_reads(raw, raw)
    gets_raw = raw.transport.stats.gets

    cached_backend = DistributedMemoryStorage(DOM, (128, 128), 4)
    cache = SpatioTemporalCache(cached_backend, prefetch=True)
    t_cache = _tracking_reads(cache, cached_backend)
    time.sleep(0.1)  # let trailing prefetches settle
    rows.append(row("stcache_no_cache", t_raw * 1e6 / FRAMES,
                    f"backend_gets={gets_raw}"))
    rows.append(row(
        "stcache_predictive", t_cache * 1e6 / FRAMES,
        f"hit_rate={cache.stats.hit_rate:.2f},critical_path_fetches="
        f"{cache.stats.misses}(vs {FRAMES} frames),prefetch_issued="
        f"{cache.stats.prefetch_issued}",
    ))

    res = autotune_io(num_writers=8, workload_chunks=32)
    rows.append(row(
        "iotune_best", res.virtual_s * 1e6,
        f"cfg={res.best.transport}/{res.best.io_mode}/g{res.best.io_group_size}"
        f"/q{res.best.queue_threshold}(paper:colocated+small-groups)",
    ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
