"""Per-kernel timings: Pallas (interpret) vs jnp reference on CPU.

Interpret mode measures kernel-body *semantics* cost, not TPU speed; the
reference column is the production CPU path.  TPU timing comes from the
roofline analysis, not this box.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.kernels import ref
from repro.kernels.color_deconv import color_deconv_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.glcm import glcm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(0)


def run() -> list:
    rows = []
    rgb = jnp.asarray(RNG.random((3, 256, 256), dtype=np.float32))
    minv = jnp.asarray(ref.stain_inverse())
    rows.append(row(
        "kernel_color_deconv_ref",
        time_call(lambda: ref.color_deconv_ref(rgb, minv).block_until_ready()) * 1e6,
        "shape=3x256x256",
    ))
    rows.append(row(
        "kernel_color_deconv_pallas_interp",
        time_call(lambda: color_deconv_pallas(rgb, minv, interpret=True).block_until_ready()) * 1e6,
        "shape=3x256x256",
    ))

    mask = jnp.asarray((RNG.random((128, 128)) > 0.4).astype(np.float32))
    marker = jnp.asarray(RNG.random((128, 128)).astype(np.float32)) * mask
    rows.append(row(
        "kernel_morph_recon_ref",
        time_call(lambda: ref.morph_recon_ref(marker, mask).block_until_ready()) * 1e6,
        "shape=128x128",
    ))

    m = jnp.asarray((RNG.random((128, 128)) > 0.5).astype(np.int32))
    rows.append(row(
        "kernel_ccl_ref",
        time_call(lambda: ref.ccl_ref(m).block_until_ready()) * 1e6,
        "shape=128x128",
    ))

    bins = jnp.asarray(RNG.integers(0, 32, (16, 64, 64), dtype=np.int32))
    rows.append(row(
        "kernel_glcm_ref",
        time_call(lambda: ref.glcm_ref(bins, 32).block_until_ready()) * 1e6,
        "16 objects 64x64 nb=32",
    ))
    rows.append(row(
        "kernel_glcm_pallas_interp",
        time_call(lambda: glcm_pallas(bins, 32, interpret=True)[0].block_until_ready()) * 1e6,
        "16 objects 64x64 nb=32",
    ))

    q = jnp.asarray(RNG.standard_normal((1, 8, 256, 64), dtype=np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 4, 256, 64), dtype=np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 4, 256, 64), dtype=np.float32))
    rows.append(row(
        "kernel_attention_ref",
        time_call(lambda: ref.attention_ref(q, k, v).block_until_ready()) * 1e6,
        "B1 H8/4 T256 D64 causal",
    ))
    rows.append(row(
        "kernel_flash_attention_pallas_interp",
        time_call(
            lambda: flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                           interpret=True).block_until_ready()
        ) * 1e6,
        "B1 H8/4 T256 D64 causal",
    ))

    x = jnp.asarray(RNG.standard_normal((1, 256, 8, 32), dtype=np.float32))
    dt = jnp.asarray(RNG.random((1, 256, 8), dtype=np.float32) * 0.1)
    a = jnp.asarray(-np.ones(8, np.float32))
    bm = jnp.asarray(RNG.standard_normal((1, 256, 1, 16), dtype=np.float32))
    cm = jnp.asarray(RNG.standard_normal((1, 256, 1, 16), dtype=np.float32))
    rows.append(row(
        "kernel_ssd_scan_ref",
        time_call(lambda: ref.ssd_scan_ref(x, dt, a, bm, cm)[0].block_until_ready()) * 1e6,
        "B1 T256 H8 P32 N16",
    ))
    rows.append(row(
        "kernel_ssd_scan_pallas_interp",
        time_call(
            lambda: ssd_scan_pallas(x, dt, a, bm, cm, chunk=64, interpret=True)[0]
            .block_until_ready()
        ) * 1e6,
        "B1 T256 H8 P32 N16",
    ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
