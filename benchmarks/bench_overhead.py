"""Fig. 11: region-template abstraction overhead (paper: ~3%).

Runs the same segmentation+features pipeline over a set of tiles twice:
  * non-RT: plain function calls on in-memory arrays;
  * RT:     through the full Manager/Worker runtime with DMS staging.
Reports the RT/non-RT wall-time ratio per "image" (a group of tiles).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs.wsi import WSIConfig
from repro.core import BoundingBox, Intent, RegionTemplate, StorageRegistry
from repro.pipeline import FeatureStage, SegmentationStage, analyze_tile, make_tile
from repro.runtime import SysEnv
from repro.storage import DistributedMemoryStorage

TILE = 96
TILES_PER_IMAGE = 4


def _image(seed: int):
    return [make_tile(TILE, num_nuclei=6, seed=seed * 100 + i)[0]
            for i in range(TILES_PER_IMAGE)]


def run() -> list:
    cfg = WSIConfig(seg_threshold=0.5, nucleus_roi=16)
    rows = []
    for img_id in range(3):
        tiles = _image(img_id)
        # ---- non-RT baseline (warm: every tile pre-run once so data-
        # dependent while-loop compilation/retracing is off the clock) ----
        for t in tiles:
            analyze_tile(jnp.asarray(t), cfg, impl="xla")
        t0 = time.perf_counter()
        for t in tiles:
            analyze_tile(jnp.asarray(t), cfg, impl="xla")
        non_rt = time.perf_counter() - t0

        # ---- RT-based ----
        reg = StorageRegistry()
        h = w = TILE
        n = TILES_PER_IMAGE
        dom3 = BoundingBox((0, 0, 0), (3, h, w * n))
        dom2 = BoundingBox((0, 0), (h, w * n))
        dms3 = reg.register(DistributedMemoryStorage(dom3, (3, h, w), 2, name="DMS3"))
        dms2 = reg.register(DistributedMemoryStorage(dom2, (h, w), 2, name="DMS2"))
        rt = RegionTemplate("Patient")
        rgb_region = rt.new_region("RGB", dom3, np.float32, input_storage="DMS3", lazy=True)
        for i, t in enumerate(tiles):
            box = BoundingBox((0, 0, i * w), (3, h, (i + 1) * w))
            dms3.put(rgb_region.key, box, t)
        env = SysEnv(num_workers=1, cpus_per_worker=1, accels_per_worker=1, registry=reg)
        t0 = time.perf_counter()
        for i in range(n):
            part3 = BoundingBox((0, 0, i * w), (3, h, (i + 1) * w))
            part2 = BoundingBox((0, i * w), (h, (i + 1) * w))
            seg = SegmentationStage(cfg, impl="xla")
            seg.add_region_template(rt, "RGB", part3, Intent.INPUT, read_storage="DMS3")
            seg.add_region_template(rt, "Mask", part2, Intent.OUTPUT, storage="DMS2")
            seg.add_region_template(rt, "Hema", part2, Intent.OUTPUT, storage="DMS2")
            feat = FeatureStage(cfg, impl="xla")
            feat.add_region_template(rt, "Mask", part2, Intent.INPUT, read_storage="DMS2")
            feat.add_region_template(rt, "Hema", part2, Intent.INPUT, read_storage="DMS2")
            feat.add_dependency(seg)
            env.execute_component(seg)
            env.execute_component(feat)
        env.startup_execution()
        rt_based = time.perf_counter() - t0
        env.finalize_system()

        ratio = rt_based / non_rt
        rows.append(row(
            f"fig11_overhead_image{img_id + 1}",
            rt_based * 1e6 / TILES_PER_IMAGE,
            f"rt_over_nonrt={ratio:.3f}x(paper<=1.03)",
        ))

    # tile-size scaling: the RT fixed cost amortizes with tile compute
    # (the paper's tiles are 4Kx4K; at 96^2 the runtime dominates)
    per_tile_overhead_s = max(rt_based - non_rt, 0.0) / TILES_PER_IMAGE
    big = make_tile(384, num_nuclei=24, seed=99)[0]
    analyze_tile(jnp.asarray(big), cfg, impl="xla")
    t0 = time.perf_counter()
    analyze_tile(jnp.asarray(big), cfg, impl="xla")
    big_compute = time.perf_counter() - t0
    projected = 1.0 + per_tile_overhead_s / max(big_compute, 1e-9)
    rows.append(row(
        "fig11_overhead_384px_tile",
        big_compute * 1e6,
        f"rt_over_nonrt~{projected:.3f}x(fixed-cost amortized; paper tiles 4Kx4K)",
    ))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
