"""Fig. 13/14: DMS vs DISK inter-stage exchange + aggregate throughput.

Segmentation writes "Mask" regions; FeatureComputation reads them back.
DISK persists to the filesystem; DMS keeps them in the distributed store.
The paper reports >=10x cheaper staging with DMS and ~200 GB/s aggregate
at 100 nodes — we reproduce the trend in virtual time with a 100-server
DMS (per-server link ~4 GB/s, DataSpaces-like)."""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DiskStorage, DistributedMemoryStorage, InProcTransport

TILE = 128
GRID = 10  # GRID x GRID tiles exchanged


def run() -> list:
    dom = BoundingBox((0, 0), (GRID * TILE, GRID * TILE))
    rows = []

    # ---- DMS: 100 virtual servers, 4 GB/s links ----
    transport = InProcTransport(100, link_bandwidth=4.0e9, latency=2e-6)
    dms = DistributedMemoryStorage(dom, (TILE, TILE), 100, transport=transport)
    arr = np.ones((TILE, TILE), np.float32)
    key = RegionKey("x", "Mask", ElementType.FLOAT32)
    t0 = time.perf_counter()
    for box in dom.tiles((TILE, TILE)):
        dms.put(key, box, arr)
    stage_wall = time.perf_counter() - t0
    stage_vt = transport.virtual_time()
    t0 = time.perf_counter()
    for box in dom.tiles((TILE, TILE)):
        dms.get(key, box)
    read_wall = time.perf_counter() - t0
    agg = dms.aggregate_throughput()
    rows.append(row("fig13_dms_stage", stage_wall * 1e6,
                    f"virtual_s={stage_vt:.5f}"))
    rows.append(row("fig14_dms_throughput", read_wall * 1e6,
                    f"aggregate={agg/1e9:.0f}GB/s(paper~200)"))

    # ---- DISK: best paper config (colocated, posix, group 1) ----
    tmp = tempfile.mkdtemp(prefix="bench_dms_disk_")
    disk = DiskStorage(tmp, transport="posix", io_mode="colocated")
    t0 = time.perf_counter()
    for box in dom.tiles((TILE, TILE)):
        disk.put(key, box, arr)
    disk.flush()
    disk_stage_wall = time.perf_counter() - t0
    disk_vt = disk.stats.virtual_total_s
    t0 = time.perf_counter()
    for box in dom.tiles((TILE, TILE)):
        disk.get(key, box)
    disk_read_wall = time.perf_counter() - t0
    shutil.rmtree(tmp, ignore_errors=True)

    rows.append(row("fig13_disk_stage", disk_stage_wall * 1e6,
                    f"virtual_s={disk_vt:.5f}"))
    ratio = disk_vt / max(stage_vt, 1e-12)
    rows.append(row("fig13_dms_advantage", 0.0,
                    f"disk_over_dms={ratio:.1f}x(paper>=10)"))
    rows.append(row("fig13_disk_read", disk_read_wall * 1e6,
                    f"dms_read_wall={read_wall*1e6:.0f}us"))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
