"""Roofline table from dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and emits one CSV row per (arch x shape x mesh) cell with the three terms,
the bottleneck, and the usefulness ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

ARTIFACTS = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def run() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        name = f"roofline_{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec.get("tag"):
            name += f"__{rec['tag']}"
        if rec["status"] != "ok" or "roofline" not in rec:
            rows.append(row(name, 0.0, f"status={rec['status']}"))
            continue
        r = rec["roofline"]
        rows.append(row(
            name,
            r["step_s"] * 1e6,
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;bottleneck={r['bottleneck']};"
            f"useful={r['useful_ratio']:.2f};roofline_frac={r['roofline_fraction']:.3f}",
        ))
    if not rows:
        rows.append(row("roofline_missing", 0.0,
                        f"no artifacts in {ARTIFACTS}; run repro.launch.dryrun"))
    return rows


def main() -> None:
    from benchmarks.common import emit

    emit(run())


if __name__ == "__main__":
    main()
