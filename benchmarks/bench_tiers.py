"""Tiered staging vs. single-backend storage under the WSI access pattern.

The WSI pipeline's storage traffic is tile-structured: a stage writes a
tile-sized region, the downstream stage immediately reads it back, and
re-analysis passes sweep the whole slide again later.  We replay that
pattern against

  * raw ``DiskStorage``      (every read pays the disk path),
  * raw ``DistributedMemoryStorage``,
  * ``TieredStore`` (bounded RAM -> DISK -> DMS) — the handoff read is
    RAM-resident, the sweep shows promotion/demotion churn under a
    memory budget of half the slide.

Rows report per-op latency plus tier hit/promotion/demotion counters.
Fast mode (``REPRO_BENCH_FAST=1``) shrinks the slide for CI smoke runs.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import row
from repro.core import BoundingBox, ElementType, RegionKey
from repro.storage import DiskStorage, DistributedMemoryStorage, TieredStore

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))
TILE = 128
GRID = 3 if FAST else 6  # GRID x GRID tiles per slide
SWEEPS = 2


def _tiles(dom: BoundingBox):
    return list(dom.tiles((TILE, TILE)))


def _wsi_pattern(store) -> dict:
    """Write every tile, read it back twice (stage handoff), then sweep."""
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    rng = np.random.default_rng(0)
    base = RegionKey("slide", "mask", ElementType.FLOAT32)
    tiles = _tiles(dom)
    payloads = [rng.random((TILE, TILE), np.float32) for _ in tiles]

    t0 = time.perf_counter()
    for i, bb in enumerate(tiles):
        store.put(base.at(i), bb, payloads[i])
    t_write = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i, bb in enumerate(tiles):  # downstream stage reads the fresh tile
        store.get(base.at(i), bb)
        store.get(base.at(i), bb)
    t_handoff = time.perf_counter() - t0

    t0 = time.perf_counter()
    for _ in range(SWEEPS):  # re-analysis sweeps
        for i, bb in enumerate(tiles):
            store.get(base.at(i), bb)
    t_sweep = time.perf_counter() - t0

    # warm set: a few tiles re-read until cache-resident, then measured
    warm = list(enumerate(tiles))[:3]
    for i, bb in warm:
        store.get(base.at(i), bb)
        store.get(base.at(i), bb)
    t0 = time.perf_counter()
    for _ in range(5):
        for i, bb in warm:
            store.get(base.at(i), bb)
    t_warm = time.perf_counter() - t0

    n = len(tiles)
    return {
        "write_us": t_write * 1e6 / n,
        "handoff_us": t_handoff * 1e6 / (2 * n),
        "sweep_us": t_sweep * 1e6 / (SWEEPS * n),
        "warm_us": t_warm * 1e6 / (5 * len(warm)),
    }


def run() -> list:
    side = GRID * TILE
    dom = BoundingBox((0, 0), (side, side))
    tile_bytes = TILE * TILE * 4
    rows = []

    tmp_disk = tempfile.mkdtemp(prefix="bench_tiers_disk_")
    disk = DiskStorage(tmp_disk, name="DISK")
    r_disk = _wsi_pattern(disk)
    rows.append(row("tiers_disk_write", r_disk["write_us"], "raw DiskStorage"))
    rows.append(row("tiers_disk_read", r_disk["handoff_us"],
                    f"sweep_us={r_disk['sweep_us']:.1f},warm_us={r_disk['warm_us']:.1f}"))

    dms = DistributedMemoryStorage(dom, (TILE, TILE), 4, name="DMS")
    r_dms = _wsi_pattern(dms)
    rows.append(row("tiers_dms_read", r_dms["handoff_us"],
                    f"write_us={r_dms['write_us']:.1f}"))

    tmp_tier = tempfile.mkdtemp(prefix="bench_tiers_stack_")
    tiered = TieredStore.standard(
        dom,
        (TILE, TILE),
        root=tmp_tier,
        mem_capacity_bytes=(GRID * GRID // 2 + 1) * tile_bytes,
        promote_after=2,
        write_policy="write_back",
    )
    r_tier = _wsi_pattern(tiered)
    tiered.drain()
    stats = tiered.tier_stats()
    mem = stats["MEM"]
    rows.append(row("tiers_tiered_write", r_tier["write_us"],
                    "write_back(drained)"))
    rows.append(row(
        "tiers_tiered_read", r_tier["handoff_us"],
        f"mem_hit_rate={mem.hit_rate:.2f},sweep_us={r_tier['sweep_us']:.1f},"
        f"warm_us={r_tier['warm_us']:.1f}",
    ))
    rows.append(row(
        "tiers_tiered_stats", 0.0,
        f"hits={mem.hits},promotions={mem.promotions},"
        f"demotions={mem.demotions},bytes_demoted={mem.bytes_demoted},"
        f"flushes={stats['DMS'].flushes}",
    ))
    # acceptance: cache-resident reads must not lose to the raw disk
    # path.  The margin is deliberately loose (1.5x): both sides are
    # microsecond-scale wall timings and a CI scheduler hiccup must not
    # fail the gate — real regressions here have been 10-75x.
    ok = r_tier["warm_us"] <= r_disk["warm_us"] * 1.5
    rows.append(row(
        "tiers_warm_vs_disk", r_tier["warm_us"],
        f"disk={r_disk['warm_us']:.1f}us,{'OK' if ok else 'REGRESSION'}",
    ))

    tiered.close()
    shutil.rmtree(tmp_disk, ignore_errors=True)
    shutil.rmtree(tmp_tier, ignore_errors=True)
    return rows


def main() -> None:
    """CLI entry: unlike the aggregate harness, this is a CI gate — a
    REGRESSION row fails the run so scripts/ci_smoke.sh can catch it."""
    from benchmarks.common import emit

    rows = run()
    emit(rows)
    bad = [r for r in rows if "REGRESSION" in r[2]]
    if bad:
        raise SystemExit(f"bench_tiers: {len(bad)} acceptance check(s) failed")


if __name__ == "__main__":
    main()
