"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json out.json`` additionally
writes a machine-readable report (consumed by ``scripts/bench_gate.py``
and uploaded as a CI artifact).  Mapping:
  Fig. 11 -> bench_overhead       (RT abstraction overhead, paper ~3%)
  Tab. 1  -> bench_scaling        (multi-core / multi-GPU scalability)
  Fig. 12 -> bench_disk_groups    (I/O group sizes vs stock ADIOS, 1.13x)
  Fig. 13/14 -> bench_dms_vs_disk (DMS vs DISK exchange, ~200 GB/s)
  Fig. 15 -> bench_scheduler      (FCFS/PATS/DL/Pref cooperative configs)
  Fig. 16 -> bench_op_speedups    (per-op cost profile)
  Fig. 17 -> bench_pats_error     (estimate-error sensitivity)
  kernels -> bench_kernels        (pallas-interpret vs jnp reference)
  roofline-> bench_roofline       (dry-run artifacts -> 3-term table)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (
    bench_compute,
    bench_disk_groups,
    bench_dms_vs_disk,
    bench_gateway,
    bench_gateway_fleet,
    bench_kernels,
    bench_op_speedups,
    bench_overhead,
    bench_pats_error,
    bench_rebalance,
    bench_repair,
    bench_replication,
    bench_roofline,
    bench_scaling,
    bench_scheduler,
    bench_stcache,
    bench_tiers,
    bench_transport,
)
from benchmarks.common import emit

MODULES = [
    ("fig11", bench_overhead),
    ("tab1", bench_scaling),
    ("fig12", bench_disk_groups),
    ("fig13_14", bench_dms_vs_disk),
    ("fig15", bench_scheduler),
    ("fig16", bench_op_speedups),
    ("fig17", bench_pats_error),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("sec7_stcache", bench_stcache),
    ("tiered_staging", bench_tiers),
    ("transport", bench_transport),
    ("gateway", bench_gateway),
    ("gateway_fleet", bench_gateway_fleet),
    ("compute", bench_compute),
    ("replication", bench_replication),
    ("repair", bench_repair),
    ("rebalance", bench_rebalance),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--json",
        metavar="OUT",
        default=None,
        help="also write results as JSON (rows + failures + wall seconds)",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated module tags to run (default: all); "
        f"tags: {','.join(tag for tag, _ in MODULES)}",
    )
    args = ap.parse_args(argv)
    selected = MODULES
    if args.only:
        want = {t.strip() for t in args.only.split(",") if t.strip()}
        unknown = want - {tag for tag, _ in MODULES}
        if unknown:
            raise SystemExit(f"unknown benchmark tag(s): {sorted(unknown)}")
        selected = [(tag, mod) for tag, mod in MODULES if tag in want]

    print("name,us_per_call,derived")
    report = {"started": time.time(), "rows": [], "failed_modules": []}
    failures = 0
    for tag, mod in selected:
        t0 = time.time()
        try:
            rows = mod.run()
            emit(rows)
            report["rows"] += [
                {"name": n, "us_per_call": us, "derived": d, "module": tag}
                for n, us, d in rows
            ]
            print(f"# {tag} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            report["failed_modules"].append(tag)
            print(f"{tag}_FAILED,0.0,exception", flush=True)
            traceback.print_exc()
    report["wall_s"] = time.time() - report["started"]
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
