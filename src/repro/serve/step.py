"""Serving substrate: cache construction, shardings, prefill/decode steps.

``decode_*`` / ``long_*`` dry-run shapes lower these serve steps (one new
token against a populated cache), per the assignment brief.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.models.spec import DEFAULT_RULES


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def make_cache(cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 4096):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len, enc_len)
    return transformer.init_cache(cfg, batch, max_len)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, *, enc_len: int = 4096):
    return jax.eval_shape(lambda: make_cache(cfg, batch, max_len, enc_len=enc_len))


def _divisible(n: int, mesh, axes) -> bool:
    names = [a for a in (axes if isinstance(axes, tuple) else (axes,)) if a in mesh.axis_names]
    if not names:
        return False
    return n % int(np.prod([mesh.shape[a] for a in names])) == 0


def cache_pspecs(
    cfg: ModelConfig, cache: Any, mesh, rules=None, *, seq_shard: bool = False
) -> Any:
    """PartitionSpecs for a cache pytree: batch over (pod, data), heads /
    inner dims over model where divisible.

    ``seq_shard=True`` shards the cache *sequence* dim over "model" when
    the kv-head dim cannot use it (MQA/GQA with kv < model size): decode
    attention then runs sequence-parallel — XLA inserts the softmax
    partial reductions — and per-chip cache traffic drops by the model
    size.  This is the beyond-paper optimization for decode cells.
    """
    rules = rules or DEFAULT_RULES
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in mesh.axis_names else None

    def leaf_ps(path, leaf) -> P:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        batch_ok = len(shape) >= 2 and _divisible(shape[1], mesh, dp)
        b = dp_spec if batch_ok else None
        if key in ("k", "v", "xk", "xv"):  # (L, B, kv, T, hd)
            kv_ok = tp and _divisible(shape[2], mesh, tp)
            if kv_ok:
                return P(None, b, tp, None, None)
            if seq_shard and tp and _divisible(shape[3], mesh, tp):
                return P(None, b, None, tp, None)
            return P(None, b, None, None, None)
        if key in ("ckv", "kr"):  # (L, B, T, r)
            if seq_shard and tp and _divisible(shape[2], mesh, tp):
                return P(None, b, tp, None)
            return P(None, b, None, None)
        if key == "conv":  # (L, B, ck, conv_dim)
            cd_ok = tp and _divisible(shape[3], mesh, tp)
            return P(None, b, None, tp if cd_ok else None)
        if key == "ssm":  # (L, B, H, N, P)
            h_ok = tp and _divisible(shape[2], mesh, tp)
            return P(None, b, tp if h_ok else None, None, None)
        if key == "slotpos":
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(leaf_ps, cache)


def cache_shardings(
    cfg: ModelConfig, cache: Any, mesh, rules=None, *, seq_shard: bool = False
) -> Any:
    ps = cache_pspecs(cfg, cache, mesh, rules, seq_shard=seq_shard)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), ps, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Serve step functions
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        def prefill(params, batch, cache):
            return encdec.prefill(params, batch["frames"], batch["tokens"], cfg, cache)

        return prefill

    def prefill(params, batch, cache):
        prefix = batch.get("prefix") if cfg.frontend else None
        return transformer.prefill(params, batch["tokens"], cfg, cache, prefix_embeds=prefix)

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "encdec":
        def decode(params, tokens, cache, pos):
            return encdec.decode_step(params, tokens, cfg, cache, pos)

        return decode

    def decode(params, tokens, cache, pos):
        return transformer.decode_step(params, tokens, cfg, cache, pos)

    return decode


# ---------------------------------------------------------------------------
# Simple batched generation loop (examples / tests)
# ---------------------------------------------------------------------------
def generate(
    params: Any,
    cfg: ModelConfig,
    prompt: jax.Array,  # (B, S0)
    *,
    max_new: int = 16,
    max_len: int | None = None,
    temperature: float = 0.0,
    key: jax.Array | None = None,
    frames: jax.Array | None = None,
    prefix: jax.Array | None = None,
) -> jax.Array:
    b, s0 = prompt.shape
    max_len = max_len or (s0 + max_new + 1)
    cache = make_cache(cfg, b, max_len, enc_len=frames.shape[1] if frames is not None else 64)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    batch: dict[str, Any] = {"tokens": prompt}
    if frames is not None:
        batch["frames"] = frames
    if prefix is not None:
        batch["prefix"] = prefix
    logits, cache = prefill(params, batch, cache)
    out = [prompt]
    pos_offset = cfg.frontend_len if (cfg.frontend and prefix is not None) else 0
    tok = _sample(logits[:, -1], temperature, key, 0)
    for i in range(max_new):
        out.append(tok)
        pos = jnp.asarray(s0 + pos_offset + i, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        tok = _sample(logits[:, -1], temperature, key, i + 1)
    return jnp.concatenate(out, axis=1)


def _sample(logits: jax.Array, temperature: float, key, i: int) -> jax.Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    k = jax.random.fold_in(key, i)
    return jax.random.categorical(k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
