"""Serving substrate: caches, prefill/decode steps, generation."""
from repro.serve.step import (
    abstract_cache,
    cache_pspecs,
    cache_shardings,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

__all__ = [
    "abstract_cache",
    "cache_pspecs",
    "cache_shardings",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]
