"""Serving substrate: caches, prefill/decode steps, generation, the
region-serving gateway (staged admission -> fairness -> response-cache
-> coalesce pipeline over the tiered region store), and the near-data
compute engine (server-side kernel chains)."""
from repro.serve.compute import (
    ComputeEngine,
    ComputeRequest,
    ComputeTicket,
    DerivedCache,
)
from repro.serve.fair import DEFAULT_CLASSES, ClientPacer, FairScheduler
from repro.serve.gateway import (
    GatewayClosed,
    GatewayConfig,
    GatewayStats,
    Overloaded,
    ReadTicket,
    RegionGateway,
    WriteTicket,
)
from repro.serve.rcache import GenerationTracker, ResponseCache, WindowPrefetcher
from repro.serve.step import (
    abstract_cache,
    cache_pspecs,
    cache_shardings,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

__all__ = [
    "DEFAULT_CLASSES",
    "ClientPacer",
    "ComputeEngine",
    "ComputeRequest",
    "ComputeTicket",
    "DerivedCache",
    "FairScheduler",
    "GatewayClosed",
    "GatewayConfig",
    "GatewayStats",
    "GenerationTracker",
    "Overloaded",
    "ReadTicket",
    "RegionGateway",
    "ResponseCache",
    "WindowPrefetcher",
    "WriteTicket",
    "abstract_cache",
    "cache_pspecs",
    "cache_shardings",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]
