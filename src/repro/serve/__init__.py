"""Serving substrate: caches, prefill/decode steps, generation, the
region-serving gateway (batching front for the tiered region store), and
the near-data compute engine (server-side kernel chains)."""
from repro.serve.compute import (
    ComputeEngine,
    ComputeRequest,
    ComputeTicket,
    DerivedCache,
)
from repro.serve.gateway import (
    GatewayClosed,
    GatewayConfig,
    GatewayStats,
    Overloaded,
    ReadTicket,
    RegionGateway,
)
from repro.serve.step import (
    abstract_cache,
    cache_pspecs,
    cache_shardings,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

__all__ = [
    "ComputeEngine",
    "ComputeRequest",
    "ComputeTicket",
    "DerivedCache",
    "GatewayClosed",
    "GatewayConfig",
    "GatewayStats",
    "Overloaded",
    "ReadTicket",
    "RegionGateway",
    "abstract_cache",
    "cache_pspecs",
    "cache_shardings",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]
