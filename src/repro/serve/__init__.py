"""Serving substrate: caches, prefill/decode steps, generation, and the
region-serving gateway (batching front for the tiered region store)."""
from repro.serve.gateway import (
    GatewayClosed,
    GatewayConfig,
    GatewayStats,
    Overloaded,
    ReadTicket,
    RegionGateway,
)
from repro.serve.step import (
    abstract_cache,
    cache_pspecs,
    cache_shardings,
    generate,
    make_cache,
    make_decode_step,
    make_prefill_step,
)

__all__ = [
    "GatewayClosed",
    "GatewayConfig",
    "GatewayStats",
    "Overloaded",
    "ReadTicket",
    "RegionGateway",
    "abstract_cache",
    "cache_pspecs",
    "cache_shardings",
    "generate",
    "make_cache",
    "make_decode_step",
    "make_prefill_step",
]
