"""Per-client fairness for the serving gateway: weighted priority
classes and per-client pacing.

One FIFO admission queue lets a single hog monopolize every worker: its
burst sits at the head and the batch window drains it first, every time.
This module replaces the FIFO with two explicit mechanisms:

* :class:`FairScheduler` — one queue per priority class, drained by
  deficit round-robin with unit request cost: each visit to a class
  grants it ``weight`` requests of budget, so over any window the
  classes share workers in proportion to their weights no matter how
  deep any one backlog is.  Within a class, order stays FIFO, and the
  coalescer's same-key batching drains from the *scheduled* class only —
  fairness is decided before batching, so a low-priority scan cannot
  ride a high-priority request's batch window.
* :class:`ClientPacer` — a lazily-created
  :class:`~repro.core.pacing.TokenBucket` per client id.  ``submit``
  pays one token before admission, so a client exceeding its rate blocks
  *itself* (bounded by its own bucket, outside every gateway lock) while
  everyone else's admission latency is untouched.

The scheduler is deliberately lock-free: the gateway serializes access
under its own admission lock, exactly as it did with the plain deque.
"""
from __future__ import annotations

import collections
import threading
from typing import Iterable, Iterator, Mapping

from repro.core.pacing import TokenBucket

DEFAULT_CLASSES: tuple[tuple[str, int], ...] = (
    ("interactive", 4),
    ("default", 2),
    ("batch", 1),
)


class FairScheduler:
    """Weighted deficit-round-robin over per-class FIFO queues.

    NOT thread-safe: the owning gateway calls every method under its
    admission lock.  Tickets carry a ``priority`` attribute naming their
    class; unknown names fall back to ``"default"`` (or the first
    configured class when no ``"default"`` exists) so a typo degrades a
    request's priority instead of dropping it.
    """

    def __init__(self, classes: "Mapping[str, int] | Iterable[tuple[str, int]]") -> None:
        pairs = list(classes.items() if isinstance(classes, Mapping) else classes)
        if not pairs:
            raise ValueError("need at least one priority class")
        self._weights: dict[str, int] = {}
        for name, weight in pairs:
            if int(weight) < 1:
                raise ValueError(f"class {name!r} weight must be >= 1, got {weight}")
            self._weights[str(name)] = int(weight)
        self._order = list(self._weights)
        self._fallback = "default" if "default" in self._weights else self._order[0]
        self._queues: dict[str, collections.deque] = {
            name: collections.deque() for name in self._order
        }
        self._ptr = 0
        # current class's remaining budget (deficit counter with unit
        # request cost): refilled to the class weight when the pointer
        # arrives, spent one request at a time
        self._budget = self._weights[self._order[0]]
        self._len = 0

    def resolve(self, priority: "str | None") -> str:
        name = priority if priority in self._weights else self._fallback
        return name

    def push(self, ticket) -> None:
        self._queues[self.resolve(getattr(ticket, "priority", None))].append(ticket)
        self._len += 1

    def __len__(self) -> int:
        return self._len

    def pop_head(self):
        """The next ticket by weighted round-robin, or None when empty."""
        if self._len == 0:
            return None
        while True:
            name = self._order[self._ptr]
            queue = self._queues[name]
            if queue and self._budget > 0:
                self._budget -= 1
                self._len -= 1
                return queue.popleft()
            # class idle or budget spent: move on, refill the next class
            self._ptr = (self._ptr + 1) % len(self._order)
            self._budget = self._weights[self._order[self._ptr]]

    def drain_matching(self, head, limit: int, coalesce: bool) -> list:
        """Pop up to ``limit - 1`` more tickets batchable with ``head``
        (same key, same group) from *head's own class* — other classes'
        budgets are not consumed by someone else's batch window."""
        batch = [head]
        if not coalesce or limit <= 1:
            return batch
        queue = self._queues[self.resolve(getattr(head, "priority", None))]
        keep: collections.deque = collections.deque()
        while queue:
            ticket = queue.popleft()
            if (
                ticket.key == head.key
                and ticket.group == head.group
                and len(batch) < limit
            ):
                batch.append(ticket)
                self._len -= 1
            else:
                keep.append(ticket)
        queue.extend(keep)
        return batch

    def tickets(self) -> Iterator:
        """Every queued ticket (shutdown sweep)."""
        for queue in self._queues.values():
            yield from queue


class ClientPacer:
    """Per-client token buckets: one client's burst throttles only
    itself.  ``None`` client ids share one anonymous bucket (they are
    indistinguishable anyway, and an unthrottled anonymous path would be
    the obvious loophole)."""

    def __init__(
        self,
        rate: float,
        burst: float | None = None,
        *,
        clock=None,
        sleep=None,
    ) -> None:
        self.rate = float(rate)
        self.burst = burst
        self._kw = {}
        if clock is not None:
            self._kw["clock"] = clock
        if sleep is not None:
            self._kw["sleep"] = sleep
        self._lock = threading.Lock()
        self._buckets: dict[object, TokenBucket] = {}

    def take(self, client) -> float:
        """Pay one token from ``client``'s bucket; returns seconds waited.
        The wait happens inside the bucket, never under this lock."""
        with self._lock:
            bucket = self._buckets.get(client)
            if bucket is None:
                bucket = TokenBucket(self.rate, self.burst, **self._kw)
                self._buckets[client] = bucket
        return bucket.take(1.0)

    def clients(self) -> int:
        with self._lock:
            return len(self._buckets)
