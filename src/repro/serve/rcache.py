"""Response caching for the serving tier: generation-validated payloads.

Three pieces, one invalidation discipline:

* :class:`ResponseCache` — a bytes-bounded LRU of served payloads keyed
  ``(region key, window)`` (the gateway's hot-window response cache) or
  ``(region key, chain digest, roi)`` (the compute engine's derived-
  product cache — :class:`~repro.serve.compute.DerivedCache` is this
  class).  Every entry records the key's *write generation* captured
  BEFORE the payload was fetched, and a lookup revalidates against the
  current generation — a racing put can only cause a spurious miss,
  never a stale hit.
* :class:`GenerationTracker` — the single source of "current generation"
  for a gateway: the wrapped store's
  :meth:`~repro.storage.tiers.TieredStore.generation` (catches writes
  that bypass the gateway), a local counter for stores without one, and
  — in fleet mode — the fleet-wide max gossiped through the ``gen``
  transport op, so a put through *any* gateway sharing the DMS fleet
  invalidates *every* gateway's caches.
* :class:`WindowPrefetcher` — speculative window prefetch driven by the
  coalescer's observed access pattern: consecutive fetch windows for a
  key yield a stride, the next window along that stride is fetched in
  the background through a
  :class:`~repro.runtime.prefetch.DevicePipeline` (bounded in-flight
  depth), and lands in the response cache before the client asks.
  Prefetch is advisory: a mispredicted window is a wasted fetch, never a
  wrong answer — entries carry the same generation validation as demand
  fills.
"""
from __future__ import annotations

import collections
import threading

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey
from repro.runtime.prefetch import DevicePipeline


class ResponseCache:
    """Bytes-bounded LRU of served payloads, generation-validated.

    Cache keys are tuples whose first element is the
    :class:`~repro.core.regions.RegionKey`; entries store the write
    generation they were fetched under, and :meth:`get` /
    :meth:`lookup_window` revalidate against the caller-supplied current
    generation — a stale entry is a miss (and is dropped).  All methods
    are thread-safe.
    """

    def __init__(self, capacity_bytes: int) -> None:
        self.capacity_bytes = int(capacity_bytes)
        self._lock = threading.Lock()
        self._entries: "collections.OrderedDict[tuple, tuple[int, np.ndarray]]" = (
            collections.OrderedDict()
        )
        self._by_key: dict[RegionKey, set[tuple]] = {}
        self._prefetched: set[tuple] = set()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def _drop_locked(self, ck: tuple) -> None:
        gen_arr = self._entries.pop(ck, None)
        if gen_arr is None:
            return
        self._bytes -= gen_arr[1].nbytes
        self._prefetched.discard(ck)
        keyset = self._by_key.get(ck[0])
        if keyset is not None:
            keyset.discard(ck)
            if not keyset:
                self._by_key.pop(ck[0], None)

    def get(self, ck: tuple, current_gen: int) -> np.ndarray | None:
        with self._lock:
            entry = self._entries.get(ck)
            if entry is None:
                self.misses += 1
                return None
            gen, arr = entry
            if gen != current_gen:
                self._drop_locked(ck)  # stale: the region was rewritten
                self.misses += 1
                return None
            self._entries.move_to_end(ck)
            self.hits += 1
            return arr

    def lookup_window(
        self, key: RegionKey, roi: BoundingBox, current_gen: int
    ) -> "tuple[np.ndarray, bool] | None":
        """Serve ``roi`` from a cached window of ``key``: an exact
        ``(key, roi)`` hit, or a slice out of any valid cached window
        that contains it (the hot-read repeat costs a slice, not a tier
        fetch).  Returns ``(payload copy, came_from_prefetch)`` or None;
        stale windows encountered during the scan are dropped."""
        with self._lock:
            exact = self._entries.get((key, roi))
            candidates = [(key, roi)] if exact is not None else []
            candidates += [
                ck
                for ck in list(self._by_key.get(key, ()))
                if ck != (key, roi) and len(ck) == 2 and ck[1].contains(roi)
            ]
            for ck in candidates:
                entry = self._entries.get(ck)
                if entry is None:
                    continue
                gen, arr = entry
                if gen != current_gen:
                    self._drop_locked(ck)  # stale: the region was rewritten
                    continue
                self._entries.move_to_end(ck)
                self.hits += 1
                # copy: callers never alias the cached window (or each other)
                return arr[roi.local_slices(ck[1])].copy(), ck in self._prefetched
            self.misses += 1
            return None

    def put(
        self, ck: tuple, gen: int, arr: np.ndarray, *, prefetched: bool = False
    ) -> None:
        if arr.nbytes > self.capacity_bytes:
            return  # would evict everything for one entry
        with self._lock:
            self._drop_locked(ck)
            self._entries[ck] = (gen, arr)
            self._by_key.setdefault(ck[0], set()).add(ck)
            if prefetched:
                self._prefetched.add(ck)
            self._bytes += arr.nbytes
            while self._bytes > self.capacity_bytes and self._entries:
                victim = next(iter(self._entries))
                self._drop_locked(victim)
                self.evictions += 1

    def invalidate(self, key: RegionKey) -> int:
        """Drop every cached payload of ``key`` (gateway put/delete)."""
        with self._lock:
            cks = list(self._by_key.get(key, ()))
            for ck in cks:
                self._drop_locked(ck)
            self.invalidations += len(cks)
            return len(cks)

    def as_dict(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "capacity_bytes": self.capacity_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
            }


class GenerationTracker:
    """One gateway's source of per-key write generations.

    The generation is a SUM of two independent monotone lines:

    * the **base** line — the wrapped store's own ``generation()`` when
      it has one (so direct ``store.put`` calls that bypass the gateway
      still invalidate), a local counter otherwise;
    * the **fleet** line (``fleet=True`` only) — a per-key counter
      gossiped via the DMS ``gen`` transport op: every gateway write
      increments it on every ring member, and reads take the max over
      the members.  The two lines are summed, not merged: each
      gateway's base line starts wherever its own write history left it,
      so comparing absolute values across gateways would leave a sibling
      blind to remote writes until the fleet counter "caught up" — the
      sum instead moves on EVERY write, local (base +1, and fleet +1
      when pushed) or remote (fleet +1).

    The observed fleet value is floored per key (monotone), so a remote
    write permanently advances the local view even if the member holding
    the max is briefly unreachable afterwards — a pull regression can
    never resurrect a stale cache entry.
    """

    def __init__(self, store, *, fleet: bool = False) -> None:
        gen = getattr(store, "generation", None)
        self._store_gen = gen if callable(gen) else None
        self._lock = threading.Lock()
        self._local: collections.Counter = collections.Counter()
        self._floor: collections.Counter = collections.Counter()
        self._fleet: list = []
        if fleet:
            backends = [store] + [t.backend for t in getattr(store, "tiers", ())]
            self._fleet = [
                b for b in backends if callable(getattr(b, "pull_generation", None))
            ]

    @property
    def fleet_enabled(self) -> bool:
        return bool(self._fleet)

    def _base(self, key: RegionKey) -> int:
        if self._store_gen is not None:
            return int(self._store_gen(key))
        with self._lock:
            return self._local[key]

    def _fleet_component(self, key: RegionKey, observed: int) -> int:
        with self._lock:
            if self._floor[key] < observed:
                self._floor[key] = observed
            return self._floor[key]

    def current(self, key: RegionKey) -> int:
        """The generation cached payloads of ``key`` must match to be
        served.  In fleet mode this pays one small ``gen`` round-trip
        per ring member — metadata, not a tier fetch."""
        base = self._base(key)
        if not self._fleet:
            return base
        observed = 0
        for dms in self._fleet:
            observed = max(observed, int(dms.pull_generation(key)))
        return base + self._fleet_component(key, observed)

    def note_write(self, key: RegionKey) -> int:
        """Record a write through the gateway facade: bump the local
        counter (stores with their own ``generation()`` already bumped
        in their put path) and push the fleet counter so sibling
        gateways' caches see the key move."""
        if self._store_gen is None:
            with self._lock:
                self._local[key] += 1
        base = self._base(key)
        if not self._fleet:
            return base
        observed = 0
        for dms in self._fleet:
            observed = max(observed, int(dms.push_generation(key)))
        return base + self._fleet_component(key, observed)


def _identity(x):
    return x


class WindowPrefetcher:
    """Speculative next-window prefetch from the coalescer's pattern.

    :meth:`observe` records each fetched window; two consecutive windows
    for a key give a stride (the SFC-ordered scans the coalescer
    produces have a stable one), and the predicted next window is
    fetched on a background thread through a
    :class:`~repro.runtime.prefetch.DevicePipeline` with ``depth``
    windows in flight (upload overlaps the next fetch), landing in the
    response cache with demand-fill generation validation.  Advisory by
    construction: failures and mispredictions are dropped silently.
    """

    def __init__(self, store, cache, gens, stats, *, depth: int = 2, name: str = "GW") -> None:
        self.store = store
        self.cache = cache
        self.gens = gens
        self.stats = stats
        self.depth = max(1, int(depth))
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._last: dict[RegionKey, BoundingBox] = {}
        self._queue: "collections.deque[tuple[RegionKey, BoundingBox]]" = (
            collections.deque()
        )
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name=f"{name}-prefetch"
        )
        self._thread.start()

    def observe(self, key: RegionKey, window: BoundingBox) -> None:
        """Feed one fetched window; maybe enqueue a prediction."""
        with self._lock:
            if self._closed:
                return
            prev = self._last.get(key)
            self._last[key] = window
            if prev is None or prev == window:
                return
            delta = tuple(a - b for a, b in zip(window.lo, prev.lo))
            if all(d == 0 for d in delta):
                return
            if len(self._queue) >= 4 * self.depth:
                return  # bounded backlog: drop predictions, never block
            self._queue.append((key, window.translate(delta)))
            self._cv.notify()

    def _pending(self):
        """Generator of fetched predicted windows (feeds the pipeline)."""
        while True:
            with self._lock:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed:
                    return
                key, window = self._queue.popleft()
            gen = self.gens.current(key)  # BEFORE the fetch (race -> spurious miss)
            try:
                arr = self.store.get(key, window)
            except Exception:  # noqa: BLE001 — a mispredicted window
                # (coverage hole, out of domain) is a dropped prediction
                continue
            self.stats.add(prefetch_issued=1)
            yield key, window, gen, arr

    def _loop(self) -> None:
        pipe = DevicePipeline(_identity, window=self.depth)
        tagged = (
            ((key, window, gen), arr) for key, window, gen, arr in self._pending()
        )
        try:
            for (key, window, gen), out in pipe.map_tagged(tagged):
                self.cache.put((key, window), gen, np.asarray(out), prefetched=True)
        except Exception:  # noqa: BLE001 — prefetch is advisory; a dead
            # prefetcher degrades to demand fills, never a gateway crash
            pass

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=10.0)
