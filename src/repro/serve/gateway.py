"""Region-serving gateway: many clients, one tiered region store.

The paper's runtime keeps many concurrent analysis stages reading from
one shared region store, and its hierarchical-pipelines companion work
(arXiv:1209.3332) shows throughput comes from batching fine-grain
requests onto shared resources.  :class:`RegionGateway` is that front
door: it implements the ``StorageBackend`` protocol (so it registers
under the store's own name with zero call-site changes) while

* **bounding admission** — requests enter a bounded queue; when the
  queue is full a client waits at most ``admit_timeout`` seconds for a
  slot and then gets an explicit :class:`Overloaded` (never a deadlock,
  never an unbounded pile-up);
* **shedding load under RAM pressure** — the top (RAM) tier's fill
  fraction, read from the store's ``TierStats``/capacity accounting,
  shrinks the admission queue to ``shed_queue_factor`` of its size and
  turns the bounded wait into an immediate :class:`Overloaded` — when
  the hot tier is thrashing, queueing more reads only makes it worse;
* **coalescing reads** — a worker that picks up a request drains every
  queued request for the same region, merges overlapping/adjacent ROIs
  into minimal bounding windows (duplicates collapse for free), issues
  ONE tier fetch per window, and slices each caller's ROI out of the
  shared payload.  Under a DMS-backed tier each window fetch rides the
  transport's scatter-gather ``fetch_many`` frame, so N clients hitting
  M servers cost one round-trip per server instead of one per block per
  client.

A merged window can cover cells none of the members asked for; if the
store cannot serve the window (a coverage hole raises ``KeyError``) the
gateway falls back to per-request fetches, so coalescing is a pure
optimization — results are always bit-exact with direct reads.  A
:class:`~repro.storage.dms.TransportError` is distinguished in the
stats (``window_failures``, an infrastructure failure operators should
see, vs ``window_fallbacks``, a benign coverage artifact) but degrades
the same way: per-request reads still serve members whose ROIs live in
an upper tier, and members that genuinely need the dead servers fail
with their own error — cheaply, because the transport's liveness cache
fails fast.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey, StorageBackend
from repro.storage.dms import DMSStats, TransportError


class Overloaded(RuntimeError):
    """Admission control rejected the request; retry later or back off."""


class GatewayClosed(RuntimeError):
    """The gateway is shut down; no new requests are accepted."""


@dataclasses.dataclass
class GatewayConfig:
    """Admission + coalescing knobs (see class docstring for semantics)."""

    workers: int = 4
    max_queue: int = 128          # bounded admission queue (requests)
    batch_window: int = 32        # max requests drained into one batch
    admit_timeout: float = 10.0   # bounded wait for a queue slot (s)
    request_timeout: float | None = 120.0  # get() wait for the result (s)
    mem_highwater: float = 0.85   # RAM-tier fill fraction that sheds load
    shed_queue_factor: float = 0.25  # queue share admitted under pressure
    max_window_waste: float = 1.5  # window vol <= waste * sum(member vols)
    coalesce: bool = True

    def __post_init__(self) -> None:
        if self.workers < 1:
            raise ValueError("gateway needs at least one worker")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.batch_window < 1:
            raise ValueError("batch_window must be >= 1")


@dataclasses.dataclass
class GatewayStats:
    """Request accounting (all counters monotonic, read under the lock)."""

    requests: int = 0     # submitted (admitted + rejected)
    served: int = 0       # completed with a payload
    failed: int = 0       # completed with a backend error
    rejected: int = 0     # Overloaded at admission
    abandoned: int = 0    # tickets cancelled after a get() timeout
    batches: int = 0      # worker drain cycles
    windows: int = 0      # tier fetches issued (merged windows)
    coalesced: int = 0    # requests served from a window shared with others
    window_fallbacks: int = 0  # window had a hole -> per-request reads
    window_failures: int = 0   # window died on the wire -> per-request degrade
    queue_peak: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ReadTicket(concurrent.futures.Future):
    """Handle on one submitted ROI read (a Future carrying key + roi)."""

    def __init__(self, key: RegionKey, roi: BoundingBox) -> None:
        super().__init__()
        self.key = key
        self.roi = roi

    def result(self, timeout: float | None = None) -> np.ndarray:
        try:
            return super().result(timeout)
        except concurrent.futures.TimeoutError:
            # on 3.10 the futures TimeoutError is NOT the builtin; callers
            # should only ever need `except TimeoutError`
            raise TimeoutError(
                f"gateway read of {self.key} {self.roi} timed out"
            ) from None


def _deliver(ticket: ReadTicket, value: np.ndarray) -> bool:
    """set_result unless the client cancelled meanwhile; True = counted."""
    try:
        ticket.set_result(value)
        return True
    except concurrent.futures.InvalidStateError:
        return False


def _deliver_error(ticket: ReadTicket, error: BaseException) -> bool:
    try:
        ticket.set_exception(error)
        return True
    except concurrent.futures.InvalidStateError:
        return False


class _Cluster:
    """One merged fetch window and the requests it serves.

    ``covered`` is a lower bound on the union volume of the member ROIs
    (each absorbed ROI contributes only its cells OUTSIDE the window so
    far, so duplicates and overlaps contribute nothing) — the waste
    check is against distinct requested cells, never an inflated sum.
    """

    __slots__ = ("window", "covered", "members")

    def __init__(self, first: ReadTicket) -> None:
        self.window = first.roi
        self.covered = first.roi.volume
        self.members = [first]

    def try_absorb(self, req: ReadTicket, max_waste: float) -> bool:
        # overlapping or adjacent (touching counts: the merged window is
        # still gap-free along the shared face)
        if not self.window.inflate(1).intersects(req.roi):
            return False
        merged = self.window.union(req.roi)
        gain = req.roi.volume - req.roi.intersect(self.window).volume
        if merged.volume > max_waste * (self.covered + gain):
            return False  # merging would fetch mostly unrequested cells
        self.window = merged
        self.covered += gain
        self.members.append(req)
        return True


class RegionGateway:
    """Request-batching front for one shared region store.

    Implements ``StorageBackend`` (``get`` blocks on a submitted ticket;
    ``put``/``query``/``delete`` pass through), so a gateway registers in
    a :class:`~repro.core.regions.StorageRegistry` under the store's own
    name and stages never notice.  Unknown attributes (``drain``,
    ``tier_stats``, ``locality``, ...) delegate to the wrapped store.
    """

    def __init__(
        self,
        store: StorageBackend,
        *,
        name: str | None = None,
        config: GatewayConfig | None = None,
        pressure_fn: Callable[[], float] | None = None,
    ) -> None:
        self.store = store
        self.name = name or getattr(store, "name", "GATEWAY")
        self.config = config or GatewayConfig()
        self.stats = GatewayStats()
        self._pressure_fn = pressure_fn
        self._pending: "collections.deque[ReadTicket]" = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._slot_free = threading.Condition(self._lock)
        self._paused = False
        self._closed = False
        self._workers = [
            threading.Thread(
                target=self._worker_loop, daemon=True, name=f"{self.name}-gw{i}"
            )
            for i in range(self.config.workers)
        ]
        for w in self._workers:
            w.start()

    # -- admission control --------------------------------------------------------
    def pressure(self) -> float:
        """RAM-tier fill fraction in [0, 1] (0 when unbounded/unknown).

        Reads the wrapped :class:`~repro.storage.tiers.TieredStore`'s
        capacity accounting; a custom ``pressure_fn`` overrides (e.g. to
        fold in host RSS or downstream backpressure).
        """
        if self._pressure_fn is not None:
            return max(0.0, min(1.0, float(self._pressure_fn())))
        tiers = getattr(self.store, "tiers", None)
        used = getattr(self.store, "used_bytes", None)
        if tiers and callable(used):
            top = tiers[0]
            cap = getattr(top, "capacity_bytes", None)
            if cap:
                return min(1.0, used(top.name) / cap)
        return 0.0

    def _admit_limit(self, pressure: float) -> int:
        cfg = self.config
        if pressure >= cfg.mem_highwater:
            return max(1, int(cfg.max_queue * cfg.shed_queue_factor))
        return cfg.max_queue

    def submit(self, key: RegionKey, roi: BoundingBox) -> ReadTicket:
        """Enqueue one ROI read; returns a ticket to wait on.

        Blocks at most ``admit_timeout`` for a queue slot; raises
        :class:`Overloaded` when the queue stays full (immediately when
        the RAM tier is past ``mem_highwater`` — shedding, not queueing,
        is the right response to memory pressure).
        """
        ticket = ReadTicket(key, roi)
        deadline = time.monotonic() + self.config.admit_timeout
        with self._lock:
            self.stats.requests += 1
        while True:
            # sample pressure OUTSIDE the gateway lock: the store takes
            # its own lock, and a custom pressure_fn may legitimately
            # consult this gateway (e.g. queue_depth)
            p = self.pressure()
            with self._lock:
                if self._closed:
                    raise GatewayClosed(f"gateway {self.name} is closed")
                limit = self._admit_limit(p)
                depth = len(self._pending)
                if depth < limit:
                    self._pending.append(ticket)
                    self.stats.queue_peak = max(self.stats.queue_peak, depth + 1)
                    self._not_empty.notify()
                    return ticket
                if p >= self.config.mem_highwater:
                    self.stats.rejected += 1
                    raise Overloaded(
                        f"{self.name}: queue {depth} >= {limit} with RAM tier at "
                        f"{p:.0%} of capacity; shedding load (retry with backoff)"
                    )
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    self.stats.rejected += 1
                    raise Overloaded(
                        f"{self.name}: queue full ({depth}/{limit}) for "
                        f"{self.config.admit_timeout:.1f}s; rejecting (bounded wait)"
                    )
                self._slot_free.wait(remaining)

    # -- worker pool --------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve_batch(batch)
            except BaseException as e:  # noqa: BLE001 — a worker must
                # survive anything (even MemoryError mid-batch): answer
                # every unresolved ticket and keep draining, or queued
                # clients would hang for their full request_timeout
                failed = sum(
                    1 for m in batch if not m.done() and _deliver_error(m, e)
                )
                with self._lock:
                    self.stats.failed += failed

    def _next_batch(self) -> list[ReadTicket] | None:
        """Pop the head request plus every queued same-key request (up to
        ``batch_window``) — the coalescing unit.  None = closed + drained."""
        with self._lock:
            while True:
                if self._pending and (not self._paused or self._closed):
                    break
                if self._closed and not self._pending:
                    return None
                self._not_empty.wait()
            head = self._pending.popleft()
            batch = [head]
            if self.config.coalesce and self._pending:
                keep: "collections.deque[ReadTicket]" = collections.deque()
                while self._pending:
                    r = self._pending.popleft()
                    if r.key == head.key and len(batch) < self.config.batch_window:
                        batch.append(r)
                    else:
                        keep.append(r)
                self._pending = keep
            self.stats.batches += 1
            self._slot_free.notify_all()
        return batch

    def _coalesce(self, batch: list[ReadTicket]) -> list[_Cluster]:
        """Greedy window merge: sorted-by-origin requests fold into the
        first cluster they overlap/touch without exceeding the waste
        bound.  Duplicated ROIs collapse into one fetch for free."""
        clusters: list[_Cluster] = []
        for req in sorted(batch, key=lambda r: (r.roi.lo, r.roi.hi)):
            for c in clusters:
                if c.try_absorb(req, self.config.max_window_waste):
                    break
            else:
                clusters.append(_Cluster(req))
        return clusters

    def _serve_batch(self, batch: list[ReadTicket]) -> None:
        if self.config.coalesce and len(batch) > 1:
            clusters = self._coalesce(batch)
        else:
            clusters = [_Cluster(r) for r in batch]
        for c in clusters:
            with self._lock:
                self.stats.windows += 1
                if len(c.members) > 1:
                    self.stats.coalesced += len(c.members)
            if len(c.members) == 1:
                self._serve_one(c.members[0])
                continue
            try:
                window_arr = self.store.get(c.members[0].key, c.window)
            except TransportError:
                # infrastructure failure (replica failover exhausted), not
                # a coverage hole: counted separately so operators see it,
                # but still degraded to per-request reads — a member whose
                # ROI lives in an upper tier (RAM/DISK) is served even
                # while the DMS is down, and members that genuinely need
                # the dead servers fail with their own TransportError
                # (cheap: the transport's liveness cache fails fast)
                with self._lock:
                    self.stats.window_failures += 1
                for m in c.members:
                    self._serve_one(m)
                continue
            except Exception:  # noqa: BLE001 — coverage hole (KeyError) or
                # another per-window tier error: degrade to per-request
                # reads, which either succeed or surface the member's own
                # error — coalescing stays a pure optimization
                with self._lock:
                    self.stats.window_fallbacks += 1
                for m in c.members:
                    self._serve_one(m)
                continue
            served = failed = 0
            for m in c.members:
                if m.done():
                    continue  # cancelled while queued
                try:
                    # slice per caller; copy so clients never alias the
                    # shared window payload (or each other — duplicated
                    # ROIs would otherwise all receive the same view)
                    payload = window_arr[m.roi.local_slices(c.window)].copy()
                except BaseException as e:  # noqa: BLE001 — e.g. MemoryError
                    # on the copy: fail this member, keep serving the rest
                    if _deliver_error(m, e):
                        failed += 1
                    continue
                if _deliver(m, payload):
                    served += 1
            with self._lock:
                self.stats.served += served
                self.stats.failed += failed

    def _serve_one(self, req: ReadTicket) -> None:
        if req.done():
            return  # cancelled while queued: don't fetch, don't re-resolve
        try:
            value = self.store.get(req.key, req.roi)
        except BaseException as e:  # noqa: BLE001 — surfaced on the ticket
            if _deliver_error(req, e):
                with self._lock:
                    self.stats.failed += 1
            return
        if _deliver(req, value):
            with self._lock:
                self.stats.served += 1

    # -- StorageBackend protocol ----------------------------------------------------
    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        ticket = self.submit(key, roi)
        try:
            return ticket.result(self.config.request_timeout)
        except TimeoutError:
            # cancel so a worker skips the ticket (workers already skip
            # done() members) instead of fetching a window for a caller
            # that gave up — and counting the orphan as served
            if ticket.cancel():
                with self._lock:
                    self.stats.abandoned += 1
            raise

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        self.store.put(key, bb, array)

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        return self.store.query(namespace, name)

    def delete(self, key: RegionKey) -> None:
        self.store.delete(key)

    # -- lifecycle ------------------------------------------------------------------
    def pause(self) -> None:
        """Stop dispatching (admission continues up to the queue bound).
        Maintenance hook; also makes coalescing deterministic in tests."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._not_empty.notify_all()

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    def storage_stats(self) -> dict:
        """One operator view of the whole serving path: the gateway's own
        request counters plus whatever the wrapped store exposes — tier
        hit/miss accounting (:class:`~repro.storage.tiers.TierStats`),
        the DMS availability counters (:class:`~repro.storage.dms.
        DMSStats`: failover/balanced fetches, put failovers/rollbacks,
        repair activity), and the transport byte counters.  A dashboard
        polling the gateway sees replica failover and anti-entropy repair
        happening below it without reaching around the facade.
        """
        out: dict = {"gateway": self.stats.as_dict()}
        tier_stats = getattr(self.store, "tier_stats", None)
        if callable(tier_stats):
            out["tiers"] = {n: s.as_dict() for n, s in tier_stats().items()}
        backends = [self.store]
        backends += [t.backend for t in getattr(self.store, "tiers", ())]
        for backend in backends:
            stats = getattr(backend, "stats", None)
            if not isinstance(stats, DMSStats):
                continue
            entry = {"dms": stats.as_dict()}
            transport = getattr(backend, "transport", None)
            tstats = getattr(transport, "stats", None)
            if tstats is not None:
                entry["transport"] = dataclasses.asdict(tstats)
            out.setdefault("dms", {})[getattr(backend, "name", "DMS")] = entry
        return out

    def close(self, *, close_store: bool = True) -> None:
        """Clean shutdown: refuse new requests, drain + answer every
        queued/in-flight request, join the workers, then (by default)
        close the wrapped store."""
        with self._lock:
            already = self._closed
            self._closed = True
            self._paused = False  # a paused gateway still drains on close
            self._not_empty.notify_all()
            self._slot_free.notify_all()
        if not already:
            for w in self._workers:
                w.join(timeout=60.0)
        if close_store:
            store_close = getattr(self.store, "close", None)
            if callable(store_close):
                store_close()

    def __getattr__(self, attr: str):
        # transparency: drain/flush/tier_stats/locality/... reach the store
        store = self.__dict__.get("store")
        if store is None:
            raise AttributeError(attr)
        return getattr(store, attr)

    def __repr__(self) -> str:
        return (
            f"RegionGateway({self.name}: {self.config.workers} workers, "
            f"queue {self.queue_depth()}/{self.config.max_queue})"
        )
