"""Region-serving gateway: many clients, one tiered region store.

The paper's runtime keeps many concurrent analysis stages reading from
one shared region store, and its hierarchical-pipelines companion work
(arXiv:1209.3332) shows throughput comes from batching fine-grain
requests onto shared resources.  :class:`RegionGateway` is that front
door: it implements the ``StorageBackend`` protocol (so it registers
under the store's own name with zero call-site changes) while

* **bounding admission** — requests enter a bounded queue; when the
  queue is full a client waits at most ``admit_timeout`` seconds for a
  slot and then gets an explicit :class:`Overloaded` (never a deadlock,
  never an unbounded pile-up);
* **shedding load under RAM pressure** — the top (RAM) tier's fill
  fraction, read from the store's ``TierStats``/capacity accounting,
  shrinks the admission queue to ``shed_queue_factor`` of its size and
  turns the bounded wait into an immediate :class:`Overloaded` — when
  the hot tier is thrashing, queueing more reads only makes it worse;
* **coalescing reads** — a worker that picks up a request drains every
  queued request for the same region, merges overlapping/adjacent ROIs
  into minimal bounding windows (duplicates collapse for free), issues
  ONE tier fetch per window, and slices each caller's ROI out of the
  shared payload.  Under a DMS-backed tier each window fetch rides the
  transport's scatter-gather ``fetch_many`` frame, so N clients hitting
  M servers cost one round-trip per server instead of one per block per
  client;
* **near-data compute** — :meth:`RegionGateway.compute` /
  :meth:`RegionGateway.submit_compute` run a named kernel chain
  (:mod:`repro.kernels.chains`, e.g. ``"deconv|threshold|ccl"``)
  server-side over the requested ROI and return only the derived array
  or feature vector; fetches are coalesced exactly like reads, windows
  flow through :class:`~repro.runtime.prefetch.DevicePipeline`, and
  repeated hot queries hit a generation-invalidated derived-product
  cache (see :mod:`repro.serve.compute`).

A merged window can cover cells none of the members asked for; if the
store cannot serve the window (a coverage hole raises ``KeyError``) the
gateway falls back to per-request fetches, so coalescing is a pure
optimization — results are always bit-exact with direct reads.  A
:class:`~repro.storage.dms.TransportError` is distinguished in the
stats (``window_failures``, an infrastructure failure operators should
see, vs ``window_fallbacks``, a benign coverage artifact) but degrades
the same way: per-request reads still serve members whose ROIs live in
an upper tier, and members that genuinely need the dead servers fail
with their own error — cheaply, because the transport's liveness cache
fails fast.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey, StorageBackend
from repro.storage.dms import DMSStats, TransportError


class Overloaded(RuntimeError):
    """Admission control rejected the request; retry later or back off."""


class GatewayClosed(RuntimeError):
    """The gateway is shut down; no new requests are accepted."""


@dataclasses.dataclass
class GatewayConfig:
    """Admission + coalescing knobs (see class docstring for semantics)."""

    workers: int = 4
    max_queue: int = 128          # bounded admission queue (requests)
    batch_window: int = 32        # max requests drained into one batch
    admit_timeout: float = 10.0   # bounded wait for a queue slot (s)
    request_timeout: float | None = 120.0  # get() wait for the result (s)
    mem_highwater: float = 0.85   # RAM-tier fill fraction that sheds load
    shed_queue_factor: float = 0.25  # queue share admitted under pressure
    max_window_waste: float = 1.5  # window vol <= waste * sum(member vols)
    coalesce: bool = True
    # near-data compute (serve/compute.py): derived-product cache bound,
    # DevicePipeline in-flight window, and kernel impl dispatch
    # ("auto" = Pallas on TPU, jnp references elsewhere)
    compute_cache_bytes: int = 64 << 20
    compute_pipeline_window: int = 2
    compute_impl: str = "auto"

    def __post_init__(self) -> None:
        if self.workers < 1:
            raise ValueError("gateway needs at least one worker")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.batch_window < 1:
            raise ValueError("batch_window must be >= 1")
        if self.compute_cache_bytes < 0:
            raise ValueError("compute_cache_bytes must be >= 0")


class GatewayStats:
    """Request accounting: monotonic counters behind ONE internal lock.

    Writers use :meth:`add` (an atomic multi-counter bump: related
    counters like ``served``+``failed`` from one batch move together) or
    :meth:`peak`; readers use :meth:`as_dict`, which snapshots every
    counter under the same lock — a concurrent-worker snapshot can never
    observe a half-applied update (torn read).  Plain attribute reads of
    a single counter remain lock-free (individual ints are consistent;
    only cross-counter invariants need the snapshot).
    """

    _FIELDS = (
        "requests",      # submitted reads (admitted + rejected)
        "served",        # reads completed with a payload
        "failed",        # reads completed with a backend error
        "rejected",      # Overloaded at admission (reads + computes)
        "abandoned",     # tickets cancelled after a get() timeout
        "batches",       # worker drain cycles
        "windows",       # tier fetches issued (merged read windows)
        "coalesced",     # reads served from a window shared with others
        "window_fallbacks",  # read window had a hole -> per-request reads
        "window_failures",   # read window died on the wire -> degrade
        "queue_peak",
        # near-data compute path (disjoint from the read counters)
        "compute_requests",
        "compute_served",
        "compute_failed",
        "compute_cache_hits",
        "compute_windows",           # fetch windows issued for computes
        "compute_coalesced",         # computes sharing a fetched window
        "compute_window_fallbacks",  # compute window hole -> per-member
        "compute_window_failures",   # compute window wire death -> degrade
        "raw_fetch_bytes",       # bytes pulled from the store for computes
        "derived_reply_bytes",   # bytes actually returned to compute clients
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for f in self._FIELDS:
            setattr(self, f, 0)

    def add(self, **deltas: int) -> None:
        """Atomically bump several counters (one lock acquisition)."""
        with self._lock:
            for name, delta in deltas.items():
                if name not in self._FIELDS:
                    raise AttributeError(f"unknown gateway counter {name!r}")
                setattr(self, name, getattr(self, name) + delta)

    def peak(self, name: str, value: int) -> None:
        with self._lock:
            setattr(self, name, max(getattr(self, name), value))

    def as_dict(self) -> dict:
        """Consistent snapshot of every counter (taken under the lock)."""
        with self._lock:
            return {f: getattr(self, f) for f in self._FIELDS}


class ReadTicket(concurrent.futures.Future):
    """Handle on one submitted ROI read (a Future carrying key + roi)."""

    # worker batching groups same-key same-group tickets; plain reads all
    # share the None group, compute tickets override with their chain
    # digest so reads and unrelated chains never mix in one batch
    group = None

    def __init__(self, key: RegionKey, roi: BoundingBox) -> None:
        super().__init__()
        self.key = key
        self.roi = roi

    def result(self, timeout: float | None = None) -> np.ndarray:
        try:
            return super().result(timeout)
        except concurrent.futures.TimeoutError:
            # on 3.10 the futures TimeoutError is NOT the builtin; callers
            # should only ever need `except TimeoutError`
            raise TimeoutError(
                f"gateway read of {self.key} {self.roi} timed out"
            ) from None


def _deliver(ticket: ReadTicket, value: np.ndarray) -> bool:
    """set_result unless the client cancelled meanwhile; True = counted.

    Callers must bump their stats counters BEFORE calling this (rolling
    back with a negative delta on False): set_result wakes the client,
    and a client reading ``gateway.stats`` right after ``result()``
    returns must already see its own request counted.
    """
    try:
        ticket.set_result(value)
        return True
    except concurrent.futures.InvalidStateError:
        return False


def _deliver_error(ticket: ReadTicket, error: BaseException) -> bool:
    try:
        ticket.set_exception(error)
        return True
    except concurrent.futures.InvalidStateError:
        return False


class _Cluster:
    """One merged fetch window and the requests it serves.

    ``covered`` is a lower bound on the union volume of the member ROIs
    (each absorbed ROI contributes only its cells OUTSIDE the window so
    far, so duplicates and overlaps contribute nothing) — the waste
    check is against distinct requested cells, never an inflated sum.
    """

    __slots__ = ("window", "covered", "members")

    def __init__(self, first: ReadTicket) -> None:
        self.window = first.roi
        self.covered = first.roi.volume
        self.members = [first]

    def try_absorb(self, req: ReadTicket, max_waste: float) -> bool:
        # overlapping or adjacent (touching counts: the merged window is
        # still gap-free along the shared face)
        if not self.window.inflate(1).intersects(req.roi):
            return False
        merged = self.window.union(req.roi)
        gain = req.roi.volume - req.roi.intersect(self.window).volume
        if merged.volume > max_waste * (self.covered + gain):
            return False  # merging would fetch mostly unrequested cells
        self.window = merged
        self.covered += gain
        self.members.append(req)
        return True


class RegionGateway:
    """Request-batching front for one shared region store.

    Implements ``StorageBackend`` (``get`` blocks on a submitted ticket;
    ``put``/``query``/``delete`` pass through), so a gateway registers in
    a :class:`~repro.core.regions.StorageRegistry` under the store's own
    name and stages never notice.  Unknown attributes (``drain``,
    ``tier_stats``, ``locality``, ...) delegate to the wrapped store.
    """

    def __init__(
        self,
        store: StorageBackend,
        *,
        name: str | None = None,
        config: GatewayConfig | None = None,
        pressure_fn: Callable[[], float] | None = None,
    ) -> None:
        self.store = store
        self.name = name or getattr(store, "name", "GATEWAY")
        self.config = config or GatewayConfig()
        self.stats = GatewayStats()
        self._pressure_fn = pressure_fn
        self._pending: "collections.deque[ReadTicket]" = collections.deque()
        self._engine = None  # near-data ComputeEngine, created on first use
        self._engine_lock = threading.Lock()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._slot_free = threading.Condition(self._lock)
        self._paused = False
        self._closed = False
        self._workers = [
            threading.Thread(
                target=self._worker_loop, daemon=True, name=f"{self.name}-gw{i}"
            )
            for i in range(self.config.workers)
        ]
        for w in self._workers:
            w.start()

    # -- admission control --------------------------------------------------------
    def pressure(self) -> float:
        """RAM-tier fill fraction in [0, 1] (0 when unbounded/unknown).

        Reads the wrapped :class:`~repro.storage.tiers.TieredStore`'s
        capacity accounting; a custom ``pressure_fn`` overrides (e.g. to
        fold in host RSS or downstream backpressure).
        """
        if self._pressure_fn is not None:
            return max(0.0, min(1.0, float(self._pressure_fn())))
        tiers = getattr(self.store, "tiers", None)
        used = getattr(self.store, "used_bytes", None)
        if tiers and callable(used):
            top = tiers[0]
            cap = getattr(top, "capacity_bytes", None)
            if cap:
                return min(1.0, used(top.name) / cap)
        return 0.0

    def _admit_limit(self, pressure: float) -> int:
        cfg = self.config
        if pressure >= cfg.mem_highwater:
            return max(1, int(cfg.max_queue * cfg.shed_queue_factor))
        return cfg.max_queue

    def submit(self, key: RegionKey, roi: BoundingBox) -> ReadTicket:
        """Enqueue one ROI read; returns a ticket to wait on.

        Blocks at most ``admit_timeout`` for a queue slot; raises
        :class:`Overloaded` when the queue stays full (immediately when
        the RAM tier is past ``mem_highwater`` — shedding, not queueing,
        is the right response to memory pressure).
        """
        ticket = ReadTicket(key, roi)
        self.stats.add(requests=1)
        self._admit(ticket)
        return ticket

    def _admit(self, ticket: ReadTicket) -> None:
        """Shared bounded-admission path for read and compute tickets."""
        deadline = time.monotonic() + self.config.admit_timeout
        while True:
            # sample pressure OUTSIDE the gateway lock: the store takes
            # its own lock, and a custom pressure_fn may legitimately
            # consult this gateway (e.g. queue_depth)
            p = self.pressure()
            with self._lock:
                if self._closed:
                    raise GatewayClosed(f"gateway {self.name} is closed")
                limit = self._admit_limit(p)
                depth = len(self._pending)
                if depth < limit:
                    self._pending.append(ticket)
                    self.stats.peak("queue_peak", depth + 1)
                    self._not_empty.notify()
                    return
                if p >= self.config.mem_highwater:
                    self.stats.add(rejected=1)
                    raise Overloaded(
                        f"{self.name}: queue {depth} >= {limit} with RAM tier at "
                        f"{p:.0%} of capacity; shedding load (retry with backoff)"
                    )
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    self.stats.add(rejected=1)
                    raise Overloaded(
                        f"{self.name}: queue full ({depth}/{limit}) for "
                        f"{self.config.admit_timeout:.1f}s; rejecting (bounded wait)"
                    )
                self._slot_free.wait(remaining)

    # -- near-data compute ---------------------------------------------------------
    @property
    def engine(self):
        """The lazily-built :class:`~repro.serve.compute.ComputeEngine`."""
        # double-checked lazy init: _engine only ever transitions
        # None -> engine (under _engine_lock), so the lock-free fast
        # path can at worst take the slow path once more
        if self._engine is None:  # relint: allow(guarded-attribute) — see above
            with self._engine_lock:
                if self._engine is None:
                    from repro.serve.compute import ComputeEngine

                    self._engine = ComputeEngine(self.store, self.config)
        return self._engine  # relint: allow(guarded-attribute) — monotonic once set

    def submit_compute(
        self,
        key: RegionKey | "object",
        roi: BoundingBox | None = None,
        chain: str | None = None,
        params=None,
    ) -> "ReadTicket":
        """Enqueue one server-side kernel-chain execution.

        Accepts either a :class:`~repro.serve.compute.ComputeRequest` or
        the unpacked ``(key, roi, chain, params)``.  Chain resolution and
        parameter validation happen HERE, synchronously — unknown chains
        raise :class:`~repro.kernels.chains.UnknownChainError` and bad
        params/ranks raise :class:`~repro.kernels.chains.ChainParamError`
        before anything is queued.  A derived-cache hit resolves the
        ticket immediately (no queue, no fetch, no kernel).
        """
        from repro.serve.compute import ComputeRequest, make_ticket

        if isinstance(key, ComputeRequest):
            request = key
        else:
            if roi is None or chain is None:
                raise TypeError("submit_compute needs (key, roi, chain) or a ComputeRequest")
            request = ComputeRequest(key, roi, chain, params)
        ticket = make_ticket(request)  # typed errors fail fast, pre-queue
        self.stats.add(compute_requests=1)
        self.engine.chain_stats.add(ticket.chain_obj.name, requests=1)
        cached = self.engine.cached(ticket)
        if cached is not None:
            self.stats.add(
                compute_cache_hits=1,
                compute_served=1,
                derived_reply_bytes=cached.nbytes,
            )
            ticket.set_result(cached)
            return ticket
        self._admit(ticket)
        return ticket

    def compute(
        self,
        key: RegionKey | "object",
        roi: BoundingBox | None = None,
        chain: str | None = None,
        params=None,
    ) -> np.ndarray:
        """Blocking server-side chain execution; returns the derived
        array/feature vector (bit-exact with a local fetch + chain run)."""
        ticket = self.submit_compute(key, roi, chain, params)
        try:
            return ticket.result(self.config.request_timeout)
        except TimeoutError:
            if ticket.cancel():
                self.stats.add(abandoned=1)
            raise

    # -- worker pool --------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve_batch(batch)
            except BaseException as e:  # noqa: BLE001 — a worker must
                # survive anything (even MemoryError mid-batch): answer
                # every unresolved ticket and keep draining, or queued
                # clients would hang for their full request_timeout
                for m in batch:
                    if m.done():
                        continue
                    field = "failed" if m.group is None else "compute_failed"
                    self.stats.add(**{field: 1})
                    if not _deliver_error(m, e):
                        self.stats.add(**{field: -1})

    def _next_batch(self) -> list[ReadTicket] | None:
        """Pop the head request plus every queued same-key same-group
        request (up to ``batch_window``) — the coalescing unit; reads
        (group None) and each distinct kernel chain batch separately.
        None = closed + drained."""
        with self._lock:
            while True:
                if self._pending and (not self._paused or self._closed):
                    break
                if self._closed and not self._pending:
                    return None
                self._not_empty.wait()
            head = self._pending.popleft()
            batch = [head]
            if self.config.coalesce and self._pending:
                keep: "collections.deque[ReadTicket]" = collections.deque()
                while self._pending:
                    r = self._pending.popleft()
                    if (
                        r.key == head.key
                        and r.group == head.group
                        and len(batch) < self.config.batch_window
                    ):
                        batch.append(r)
                    else:
                        keep.append(r)
                self._pending = keep
            self.stats.add(batches=1)
            self._slot_free.notify_all()
        return batch

    def _coalesce(self, batch: list[ReadTicket]) -> list[_Cluster]:
        """Greedy window merge: sorted-by-origin requests fold into the
        first cluster they overlap/touch without exceeding the waste
        bound.  Duplicated ROIs collapse into one fetch for free."""
        clusters: list[_Cluster] = []
        for req in sorted(batch, key=lambda r: (r.roi.lo, r.roi.hi)):
            for c in clusters:
                if c.try_absorb(req, self.config.max_window_waste):
                    break
            else:
                clusters.append(_Cluster(req))
        return clusters

    def _serve_batch(self, batch: list[ReadTicket]) -> None:
        if batch[0].group is not None:
            # compute batch (same key, same chain digest): the engine
            # coalesces the FETCHES like reads, then runs the chain on
            # each member's own ROI slice through the device pipeline
            self.engine.serve_batch(batch, self)
            return
        if self.config.coalesce and len(batch) > 1:
            clusters = self._coalesce(batch)
        else:
            clusters = [_Cluster(r) for r in batch]
        for c in clusters:
            self.stats.add(
                windows=1, coalesced=len(c.members) if len(c.members) > 1 else 0
            )
            if len(c.members) == 1:
                self._serve_one(c.members[0])
                continue
            try:
                window_arr = self.store.get(c.members[0].key, c.window)
            except TransportError:
                # infrastructure failure (replica failover exhausted), not
                # a coverage hole: counted separately so operators see it,
                # but still degraded to per-request reads — a member whose
                # ROI lives in an upper tier (RAM/DISK) is served even
                # while the DMS is down, and members that genuinely need
                # the dead servers fail with their own TransportError
                # (cheap: the transport's liveness cache fails fast)
                self.stats.add(window_failures=1)
                for m in c.members:
                    self._serve_one(m)
                continue
            except Exception:  # noqa: BLE001 — coverage hole (KeyError) or
                # another per-window tier error: degrade to per-request
                # reads, which either succeed or surface the member's own
                # error — coalescing stays a pure optimization
                self.stats.add(window_fallbacks=1)
                for m in c.members:
                    self._serve_one(m)
                continue
            for m in c.members:
                if m.done():
                    continue  # cancelled while queued
                try:
                    # slice per caller; copy so clients never alias the
                    # shared window payload (or each other — duplicated
                    # ROIs would otherwise all receive the same view)
                    payload = window_arr[m.roi.local_slices(c.window)].copy()
                except BaseException as e:  # noqa: BLE001 — e.g. MemoryError
                    # on the copy: fail this member, keep serving the rest
                    self.stats.add(failed=1)
                    if not _deliver_error(m, e):
                        self.stats.add(failed=-1)
                    continue
                self.stats.add(served=1)
                if not _deliver(m, payload):
                    self.stats.add(served=-1)

    def _serve_one(self, req: ReadTicket) -> None:
        if req.done():
            return  # cancelled while queued: don't fetch, don't re-resolve
        try:
            value = self.store.get(req.key, req.roi)
        except BaseException as e:  # noqa: BLE001 — surfaced on the ticket
            self.stats.add(failed=1)
            if not _deliver_error(req, e):
                self.stats.add(failed=-1)
            return
        self.stats.add(served=1)
        if not _deliver(req, value):
            self.stats.add(served=-1)

    # -- StorageBackend protocol ----------------------------------------------------
    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        ticket = self.submit(key, roi)
        try:
            return ticket.result(self.config.request_timeout)
        except TimeoutError:
            # cancel so a worker skips the ticket (workers already skip
            # done() members) instead of fetching a window for a caller
            # that gave up — and counting the orphan as served
            if ticket.cancel():
                self.stats.add(abandoned=1)
            raise

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        self.store.put(key, bb, array)
        engine = self._engine  # relint: allow(guarded-attribute) — monotonic None->engine; a racing first build has no derived products to invalidate
        if engine is not None:
            # a write through the facade invalidates the key's derived
            # products (stores with generation() also catch direct puts)
            engine.note_write(key)

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        return self.store.query(namespace, name)

    def delete(self, key: RegionKey) -> None:
        self.store.delete(key)
        engine = self._engine  # relint: allow(guarded-attribute) — monotonic None->engine; a racing first build has no derived products to invalidate
        if engine is not None:
            engine.note_write(key)

    # -- lifecycle ------------------------------------------------------------------
    def pause(self) -> None:
        """Stop dispatching (admission continues up to the queue bound).
        Maintenance hook; also makes coalescing deterministic in tests."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._not_empty.notify_all()

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    def storage_stats(self) -> dict:
        """One operator view of the whole serving path: the gateway's own
        request counters plus whatever the wrapped store exposes — tier
        hit/miss accounting (:class:`~repro.storage.tiers.TierStats`),
        the DMS availability counters (:class:`~repro.storage.dms.
        DMSStats`: failover/balanced fetches, put failovers/rollbacks,
        repair activity), and the transport byte counters.  A dashboard
        polling the gateway sees replica failover and anti-entropy repair
        happening below it without reaching around the facade.
        """
        out: dict = {"gateway": self.stats.as_dict()}
        engine = self._engine  # relint: allow(guarded-attribute) — monotonic None->engine; stats snapshots tolerate missing the engine being built right now
        if engine is not None:
            # per-chain latency + egress savings and derived-cache health
            out["compute"] = engine.as_dict()
        tier_stats = getattr(self.store, "tier_stats", None)
        if callable(tier_stats):
            out["tiers"] = {n: s.as_dict() for n, s in tier_stats().items()}
        backends = [self.store]
        backends += [t.backend for t in getattr(self.store, "tiers", ())]
        for backend in backends:
            stats = getattr(backend, "stats", None)
            if not isinstance(stats, DMSStats):
                continue
            entry = {"dms": stats.as_dict()}
            transport = getattr(backend, "transport", None)
            tstats = getattr(transport, "stats", None)
            if tstats is not None:
                # as_dict() snapshots every counter under the stats lock;
                # asdict() here was the PR-7 torn-read bug class
                entry["transport"] = tstats.as_dict()
            rebalance = getattr(backend, "rebalance_stats", None)
            if callable(rebalance):
                # elastic-fleet health: ring epoch/checksum, whether a
                # paced sweep is running, and the last sweep's report
                entry["rebalance"] = rebalance()
            out.setdefault("dms", {})[getattr(backend, "name", "DMS")] = entry
        return out

    def close(self, *, close_store: bool = True) -> None:
        """Clean shutdown: refuse new requests, drain + answer every
        queued/in-flight request, join the workers, then (by default)
        close the wrapped store."""
        with self._lock:
            already = self._closed
            self._closed = True
            self._paused = False  # a paused gateway still drains on close
            self._not_empty.notify_all()
            self._slot_free.notify_all()
        if not already:
            for w in self._workers:
                w.join(timeout=60.0)
        if close_store:
            store_close = getattr(self.store, "close", None)
            if callable(store_close):
                store_close()

    def __getattr__(self, attr: str):
        # transparency: drain/flush/tier_stats/locality/... reach the store
        store = self.__dict__.get("store")
        if store is None:
            raise AttributeError(attr)
        return getattr(store, attr)

    def __repr__(self) -> str:
        return (
            f"RegionGateway({self.name}: {self.config.workers} workers, "
            f"queue {self.queue_depth()}/{self.config.max_queue})"
        )
