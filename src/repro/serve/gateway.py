"""Region-serving gateway: many clients, one tiered region store.

The paper's runtime keeps many concurrent analysis stages reading from
one shared region store, and its hierarchical-pipelines companion work
(arXiv:1209.3332) shows throughput comes from batching fine-grain
requests onto shared resources.  :class:`RegionGateway` is that front
door, built as an explicit staged pipeline —

    admission -> fairness -> response cache -> coalesce -> store

* **bounded admission** — requests enter a bounded queue; when the
  queue is full a client waits at most ``admit_timeout`` seconds for a
  slot and then gets an explicit :class:`Overloaded` (never a deadlock,
  never an unbounded pile-up); RAM pressure (the top tier's fill
  fraction) shrinks the queue to ``shed_queue_factor`` of its size and
  turns the bounded wait into immediate shedding;
* **fairness** (:mod:`repro.serve.fair`) — per-priority-class queues
  drained by weighted deficit round-robin, so a low-priority scan
  cannot monopolize the batch window, plus an optional per-client
  :class:`~repro.core.pacing.TokenBucket` that makes a hog throttle
  itself before admission;
* **response cache** (:mod:`repro.serve.rcache`) — served windows are
  kept in a bytes-bounded, generation-validated LRU; a repeated hot
  read costs a slice of a cached window, not a tier fetch.  Generations
  come from the store (writes that bypass the gateway still invalidate)
  and, in fleet mode, from the ``gen`` gossip op — N gateways sharing
  one DMS fleet see each other's writes, so any gateway's put
  invalidates every gateway's cache;
* **coalescing reads** — a worker drains every batchable queued request
  (same key, same class), merges overlapping/adjacent ROIs into minimal
  bounding windows, issues ONE tier fetch per window, and slices each
  caller's ROI out of the shared payload; fetched windows feed the
  response cache and a speculative :class:`~repro.serve.rcache.
  WindowPrefetcher` that follows the observed scan stride;
* **coalescing writes** — with ``coalesce_puts`` enabled, puts queue as
  tickets too and a worker flushes a same-key batch with per-ROI
  last-writer-wins (N overwrites of one tile within a flush window cost
  one store put);
* **near-data compute** — :meth:`RegionGateway.compute` runs a named
  kernel chain server-side and returns only the derived array; its
  derived-product cache shares the response-cache implementation and
  the same generation validation (see :mod:`repro.serve.compute`).

A merged window can cover cells none of the members asked for; if the
store cannot serve the window (a coverage hole raises ``KeyError``) the
gateway falls back to per-request fetches, so coalescing is a pure
optimization — results are always bit-exact with direct reads.  A
:class:`~repro.storage.dms.TransportError` is distinguished in the
stats (``window_failures`` vs ``window_fallbacks``) but degrades the
same way.  The response cache preserves bit-exactness by construction:
entries record the write generation captured BEFORE their fetch, so a
racing put causes a spurious miss, never a stale hit.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, Iterable, Mapping

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey, StorageBackend
from repro.serve.fair import DEFAULT_CLASSES, ClientPacer, FairScheduler
from repro.serve.rcache import GenerationTracker, ResponseCache, WindowPrefetcher
from repro.storage.dms import DMSStats, TransportError


class Overloaded(RuntimeError):
    """Admission control rejected the request; retry later or back off."""


class GatewayClosed(RuntimeError):
    """The gateway is shut down; no new requests are accepted."""


@dataclasses.dataclass
class GatewayConfig:
    """Staged-pipeline knobs (see class docstring for semantics)."""

    workers: int = 4
    max_queue: int = 128          # bounded admission queue (requests)
    batch_window: int = 32        # max requests drained into one batch
    admit_timeout: float = 10.0   # bounded wait for a queue slot (s)
    request_timeout: float | None = 120.0  # get() wait for the result (s)
    mem_highwater: float = 0.85   # RAM-tier fill fraction that sheds load
    shed_queue_factor: float = 0.25  # queue share admitted under pressure
    max_window_waste: float = 1.5  # window vol <= waste * sum(member vols)
    coalesce: bool = True
    # fairness stage: priority classes (name -> DRR weight), and an
    # optional per-client token bucket (requests/s; None = unthrottled)
    classes: "Mapping[str, int] | Iterable[tuple[str, int]]" = DEFAULT_CLASSES
    client_rate: float | None = None
    client_burst: float | None = None
    # response-cache stage: hot-window payload cache bound (0 disables),
    # speculative stride prefetch, and cross-gateway generation gossip
    # (fleet mode: validate/invalidate through the shared DMS fleet)
    response_cache_bytes: int = 32 << 20
    prefetch: bool = False
    prefetch_depth: int = 2
    fleet_generations: bool = False
    # write coalescing: puts queue as tickets and flush with per-ROI
    # last-writer-wins inside a same-key batch window
    coalesce_puts: bool = False
    # near-data compute (serve/compute.py): derived-product cache bound,
    # DevicePipeline in-flight window, and kernel impl dispatch
    # ("auto" = Pallas on TPU, jnp references elsewhere)
    compute_cache_bytes: int = 64 << 20
    compute_pipeline_window: int = 2
    compute_impl: str = "auto"

    def __post_init__(self) -> None:
        if self.workers < 1:
            raise ValueError("gateway needs at least one worker")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.batch_window < 1:
            raise ValueError("batch_window must be >= 1")
        if self.compute_cache_bytes < 0:
            raise ValueError("compute_cache_bytes must be >= 0")
        if self.response_cache_bytes < 0:
            raise ValueError("response_cache_bytes must be >= 0")
        if self.prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        if self.client_rate is not None and self.client_rate <= 0:
            raise ValueError("client_rate must be positive (or None)")


class GatewayStats:
    """Request accounting: monotonic counters behind ONE internal lock.

    Writers use :meth:`add` (an atomic multi-counter bump: related
    counters like ``served``+``failed`` from one batch move together),
    :meth:`class_add` (the per-priority-class admission/shed/hit rows)
    or :meth:`peak`; readers use :meth:`as_dict`, which snapshots every
    counter — including the class rows — under the same lock, so a
    concurrent-worker snapshot can never observe a half-applied update
    (torn read).  Plain attribute reads of a single counter remain
    lock-free (individual ints are consistent; only cross-counter
    invariants need the snapshot).
    """

    _FIELDS = (
        "requests",      # submitted reads (admitted + rejected + cache hits)
        "served",        # reads completed with a payload
        "failed",        # reads completed with a backend error
        "rejected",      # Overloaded at admission (reads + writes + computes)
        "abandoned",     # tickets cancelled after a get() timeout
        "throttled",     # submissions that waited on their client bucket
        "batches",       # worker drain cycles
        "windows",       # tier fetches issued (merged read windows)
        "coalesced",     # reads served from a window shared with others
        "window_fallbacks",  # read window had a hole -> per-request reads
        "window_failures",   # read window died on the wire -> degrade
        "queue_peak",
        # response-cache stage
        "response_cache_hits",   # reads served from a cached hot window
        "prefetch_issued",       # speculative windows fetched
        "prefetch_hits",         # cache hits served by a prefetched window
        # write-coalescing stage
        "writes",            # submitted puts (facade or submit_put)
        "writes_applied",    # store puts actually issued after dedup
        "write_coalesced",   # puts superseded by a later same-ROI put
        "write_batches",     # write flush cycles
        "write_failed",      # puts completed with a backend error
        # near-data compute path (disjoint from the read counters)
        "compute_requests",
        "compute_served",
        "compute_failed",
        "compute_cache_hits",
        "compute_windows",           # fetch windows issued for computes
        "compute_coalesced",         # computes sharing a fetched window
        "compute_window_fallbacks",  # compute window hole -> per-member
        "compute_window_failures",   # compute window wire death -> degrade
        "raw_fetch_bytes",       # bytes pulled from the store for computes
        "derived_reply_bytes",   # bytes actually returned to compute clients
    )

    _CLASS_FIELDS = ("requests", "admitted", "shed", "served", "cache_hits")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for f in self._FIELDS:
            setattr(self, f, 0)
        self._classes: dict[str, dict[str, int]] = {}

    def add(self, **deltas: int) -> None:
        """Atomically bump several counters (one lock acquisition)."""
        with self._lock:
            for name, delta in deltas.items():
                if name not in self._FIELDS:
                    raise AttributeError(f"unknown gateway counter {name!r}")
                setattr(self, name, getattr(self, name) + delta)

    def class_add(self, cls: str, **deltas: int) -> None:
        """Atomically bump counters on one priority class's row."""
        with self._lock:
            row = self._classes.setdefault(
                cls, {f: 0 for f in self._CLASS_FIELDS}
            )
            for name, delta in deltas.items():
                if name not in self._CLASS_FIELDS:
                    raise AttributeError(f"unknown class counter {name!r}")
                row[name] += delta

    def peak(self, name: str, value: int) -> None:
        with self._lock:
            setattr(self, name, max(getattr(self, name), value))

    def as_dict(self) -> dict:
        """Consistent snapshot of every counter (taken under the lock)."""
        with self._lock:
            out = {f: getattr(self, f) for f in self._FIELDS}
            out["classes"] = {c: dict(row) for c, row in self._classes.items()}
            return out


class ReadTicket(concurrent.futures.Future):
    """Handle on one submitted ROI read (a Future carrying key + roi)."""

    # worker batching groups same-key same-group tickets; plain reads all
    # share the None group, compute tickets override with their chain
    # digest (and write tickets with a "put" marker) so reads, writes,
    # and unrelated chains never mix in one batch
    group = None
    # fairness class (normalized at submit) and client id (throttling)
    priority = "default"
    client = None

    def __init__(self, key: RegionKey, roi: BoundingBox) -> None:
        super().__init__()
        self.key = key
        self.roi = roi

    def result(self, timeout: float | None = None) -> np.ndarray:
        try:
            return super().result(timeout)
        except concurrent.futures.TimeoutError:
            # on 3.10 the futures TimeoutError is NOT the builtin; callers
            # should only ever need `except TimeoutError`
            raise TimeoutError(
                f"gateway read of {self.key} {self.roi} timed out"
            ) from None


class WriteTicket(ReadTicket):
    """Handle on one queued put.  All writes share one batching group,
    so a worker flushes every queued same-key put in one cycle with
    per-ROI last-writer-wins.  The caller must not mutate ``array``
    until the ticket resolves (the facade ``put()`` blocks, so only
    direct ``submit_put`` users can observe this)."""

    group = ("put",)

    def __init__(self, key: RegionKey, roi: BoundingBox, array: np.ndarray) -> None:
        super().__init__(key, roi)
        self.array = array


def _deliver(ticket: ReadTicket, value) -> bool:
    """set_result unless the client cancelled meanwhile; True = counted.

    Callers must bump their stats counters BEFORE calling this (rolling
    back with a negative delta on False): set_result wakes the client,
    and a client reading ``gateway.stats`` right after ``result()``
    returns must already see its own request counted.
    """
    try:
        ticket.set_result(value)
        return True
    except concurrent.futures.InvalidStateError:
        return False


def _deliver_error(ticket: ReadTicket, error: BaseException) -> bool:
    try:
        ticket.set_exception(error)
        return True
    except concurrent.futures.InvalidStateError:
        return False


class _Cluster:
    """One merged fetch window and the requests it serves.

    ``covered`` is a lower bound on the union volume of the member ROIs
    (each absorbed ROI contributes only its cells OUTSIDE the window so
    far, so duplicates and overlaps contribute nothing) — the waste
    check is against distinct requested cells, never an inflated sum.
    """

    __slots__ = ("window", "covered", "members")

    def __init__(self, first: ReadTicket) -> None:
        self.window = first.roi
        self.covered = first.roi.volume
        self.members = [first]

    def try_absorb(self, req: ReadTicket, max_waste: float) -> bool:
        # overlapping or adjacent (touching counts: the merged window is
        # still gap-free along the shared face)
        if not self.window.inflate(1).intersects(req.roi):
            return False
        merged = self.window.union(req.roi)
        gain = req.roi.volume - req.roi.intersect(self.window).volume
        if merged.volume > max_waste * (self.covered + gain):
            return False  # merging would fetch mostly unrequested cells
        self.window = merged
        self.covered += gain
        self.members.append(req)
        return True


class RegionGateway:
    """Staged request pipeline fronting one shared region store.

    Implements ``StorageBackend`` (``get`` blocks on a submitted ticket;
    ``put``/``query``/``delete`` pass through — or queue, with
    ``coalesce_puts``), so a gateway registers in a
    :class:`~repro.core.regions.StorageRegistry` under the store's own
    name and stages never notice.  Unknown attributes (``drain``,
    ``tier_stats``, ``locality``, ...) delegate to the wrapped store.

    Fleet mode: construct N gateways whose stores share one DMS fleet
    (one transport) with ``fleet_generations=True`` — they keep a
    consistent membership view through the epoch gossip, and the ``gen``
    gossip propagates write generations so any gateway's put invalidates
    every gateway's response cache.
    """

    def __init__(
        self,
        store: StorageBackend,
        *,
        name: str | None = None,
        config: GatewayConfig | None = None,
        pressure_fn: Callable[[], float] | None = None,
    ) -> None:
        self.store = store
        self.name = name or getattr(store, "name", "GATEWAY")
        self.config = config or GatewayConfig()
        self.stats = GatewayStats()
        self._pressure_fn = pressure_fn
        self._engine = None  # near-data ComputeEngine, created on first use
        self._engine_lock = threading.Lock()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._slot_free = threading.Condition(self._lock)
        self._paused = False
        self._closed = False
        # pipeline stages: fairness scheduler (guarded by _lock, like the
        # plain deque it replaced), per-client pacer, generation tracker,
        # response cache, speculative prefetcher
        self._sched = FairScheduler(self.config.classes)
        self._pacer = (
            ClientPacer(self.config.client_rate, self.config.client_burst)
            if self.config.client_rate is not None
            else None
        )
        self._gens = GenerationTracker(
            store, fleet=self.config.fleet_generations
        )
        self._rcache = (
            ResponseCache(self.config.response_cache_bytes)
            if self.config.response_cache_bytes > 0
            else None
        )
        self._prefetcher = (
            WindowPrefetcher(
                store,
                self._rcache,
                self._gens,
                self.stats,
                depth=self.config.prefetch_depth,
                name=self.name,
            )
            if self.config.prefetch and self._rcache is not None
            else None
        )
        self._workers = [
            threading.Thread(
                target=self._worker_loop, daemon=True, name=f"{self.name}-gw{i}"
            )
            for i in range(self.config.workers)
        ]
        for w in self._workers:
            w.start()

    # -- admission control --------------------------------------------------------
    def pressure(self) -> float:
        """RAM-tier fill fraction in [0, 1] (0 when unbounded/unknown).

        Reads the wrapped :class:`~repro.storage.tiers.TieredStore`'s
        capacity accounting; a custom ``pressure_fn`` overrides (e.g. to
        fold in host RSS or downstream backpressure).
        """
        if self._pressure_fn is not None:
            return max(0.0, min(1.0, float(self._pressure_fn())))
        tiers = getattr(self.store, "tiers", None)
        used = getattr(self.store, "used_bytes", None)
        if tiers and callable(used):
            top = tiers[0]
            cap = getattr(top, "capacity_bytes", None)
            if cap:
                return min(1.0, used(top.name) / cap)
        return 0.0

    def _admit_limit(self, pressure: float) -> int:
        cfg = self.config
        if pressure >= cfg.mem_highwater:
            return max(1, int(cfg.max_queue * cfg.shed_queue_factor))
        return cfg.max_queue

    def submit(
        self,
        key: RegionKey,
        roi: BoundingBox,
        *,
        priority: str | None = None,
        client=None,
    ) -> ReadTicket:
        """Enqueue one ROI read; returns a ticket to wait on.

        ``priority`` names a fairness class (unknown names degrade to
        the default class), ``client`` is the per-client throttling id.
        Blocks at most ``admit_timeout`` for a queue slot; raises
        :class:`Overloaded` when the queue stays full (immediately when
        the RAM tier is past ``mem_highwater`` — shedding, not queueing,
        is the right response to memory pressure).  A response-cache hit
        resolves the ticket immediately: no queue, no tier fetch.
        """
        with self._lock:
            if self._closed:  # don't serve cache hits from a closed gateway
                raise GatewayClosed(f"gateway {self.name} is closed")
        ticket = ReadTicket(key, roi)
        ticket.priority = self._sched.resolve(priority)
        ticket.client = client
        self._throttle(ticket)
        self.stats.add(requests=1)
        self.stats.class_add(ticket.priority, requests=1)
        if self._rcache is not None:
            gen = self._gens.current(key)  # fleet mode validates here
            hit = self._rcache.lookup_window(key, roi, gen)
            if hit is not None:
                payload, prefetched = hit
                deltas = {"served": 1, "response_cache_hits": 1}
                if prefetched:
                    deltas["prefetch_hits"] = 1
                self.stats.add(**deltas)
                self.stats.class_add(ticket.priority, served=1, cache_hits=1)
                ticket.set_result(payload)
                return ticket
        self._admit(ticket)
        return ticket

    def submit_put(
        self,
        key: RegionKey,
        bb: BoundingBox,
        array: np.ndarray,
        *,
        priority: str | None = None,
        client=None,
    ) -> WriteTicket:
        """Enqueue one put for batched flushing (last-writer-wins per
        ROI within the flush window); resolves with None once applied.
        Do not mutate ``array`` until then."""
        with self._lock:
            if self._closed:  # don't sleep on the pacer for a closed gateway
                raise GatewayClosed(f"gateway {self.name} is closed")
        ticket = WriteTicket(key, bb, array)
        ticket.priority = self._sched.resolve(priority)
        ticket.client = client
        self._throttle(ticket)
        self.stats.add(writes=1)
        self.stats.class_add(ticket.priority, requests=1)
        self._admit(ticket)
        return ticket

    def _throttle(self, ticket: ReadTicket) -> None:
        """Per-client pacing, BEFORE admission and outside every lock:
        a client over its rate sleeps on its own bucket, shaping its
        arrival rate instead of occupying a queue slot while it waits."""
        if self._pacer is None:
            return
        if self._pacer.take(ticket.client) > 0:
            self.stats.add(throttled=1)

    def _admit(self, ticket: ReadTicket) -> None:
        """Shared bounded-admission path for read/write/compute tickets."""
        deadline = time.monotonic() + self.config.admit_timeout
        while True:
            # sample pressure OUTSIDE the gateway lock: the store takes
            # its own lock, and a custom pressure_fn may legitimately
            # consult this gateway (e.g. queue_depth)
            p = self.pressure()
            with self._lock:
                if self._closed:
                    raise GatewayClosed(f"gateway {self.name} is closed")
                limit = self._admit_limit(p)
                depth = len(self._sched)
                if depth < limit:
                    self._sched.push(ticket)
                    self.stats.peak("queue_peak", depth + 1)
                    self.stats.class_add(ticket.priority, admitted=1)
                    self._not_empty.notify()
                    return
                if p >= self.config.mem_highwater:
                    self.stats.add(rejected=1)
                    self.stats.class_add(ticket.priority, shed=1)
                    raise Overloaded(
                        f"{self.name}: queue {depth} >= {limit} with RAM tier at "
                        f"{p:.0%} of capacity; shedding load (retry with backoff)"
                    )
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    self.stats.add(rejected=1)
                    self.stats.class_add(ticket.priority, shed=1)
                    raise Overloaded(
                        f"{self.name}: queue full ({depth}/{limit}) for "
                        f"{self.config.admit_timeout:.1f}s; rejecting (bounded wait)"
                    )
                self._slot_free.wait(remaining)

    # -- near-data compute ---------------------------------------------------------
    @property
    def engine(self):
        """The lazily-built :class:`~repro.serve.compute.ComputeEngine`."""
        # double-checked lazy init: _engine only ever transitions
        # None -> engine (under _engine_lock), so the lock-free fast
        # path can at worst take the slow path once more
        if self._engine is None:  # relint: allow(guarded-attribute) — see above
            with self._engine_lock:
                if self._engine is None:
                    from repro.serve.compute import ComputeEngine

                    self._engine = ComputeEngine(
                        self.store, self.config, gens=self._gens
                    )
        return self._engine  # relint: allow(guarded-attribute) — monotonic once set

    def submit_compute(
        self,
        key: RegionKey | "object",
        roi: BoundingBox | None = None,
        chain: str | None = None,
        params=None,
    ) -> "ReadTicket":
        """Enqueue one server-side kernel-chain execution.

        Accepts either a :class:`~repro.serve.compute.ComputeRequest` or
        the unpacked ``(key, roi, chain, params)``.  Chain resolution and
        parameter validation happen HERE, synchronously — unknown chains
        raise :class:`~repro.kernels.chains.UnknownChainError` and bad
        params/ranks raise :class:`~repro.kernels.chains.ChainParamError`
        before anything is queued.  A derived-cache hit resolves the
        ticket immediately (no queue, no fetch, no kernel).
        """
        from repro.serve.compute import ComputeRequest, make_ticket

        if isinstance(key, ComputeRequest):
            request = key
        else:
            if roi is None or chain is None:
                raise TypeError("submit_compute needs (key, roi, chain) or a ComputeRequest")
            request = ComputeRequest(key, roi, chain, params)
        ticket = make_ticket(request)  # typed errors fail fast, pre-queue
        self.stats.add(compute_requests=1)
        self.engine.chain_stats.add(ticket.chain_obj.name, requests=1)
        cached = self.engine.cached(ticket)
        if cached is not None:
            self.stats.add(
                compute_cache_hits=1,
                compute_served=1,
                derived_reply_bytes=cached.nbytes,
            )
            ticket.set_result(cached)
            return ticket
        self._admit(ticket)
        return ticket

    def compute(
        self,
        key: RegionKey | "object",
        roi: BoundingBox | None = None,
        chain: str | None = None,
        params=None,
    ) -> np.ndarray:
        """Blocking server-side chain execution; returns the derived
        array/feature vector (bit-exact with a local fetch + chain run)."""
        ticket = self.submit_compute(key, roi, chain, params)
        try:
            return ticket.result(self.config.request_timeout)
        except TimeoutError:
            if ticket.cancel():
                self.stats.add(abandoned=1)
            raise

    # -- worker pool --------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve_batch(batch)
            except BaseException as e:  # noqa: BLE001 — a worker must
                # survive anything (even MemoryError mid-batch): answer
                # every unresolved ticket and keep draining, or queued
                # clients would hang for their full request_timeout
                for m in batch:
                    if m.done():
                        continue
                    if isinstance(m, WriteTicket):
                        field = "write_failed"
                    elif m.group is None:
                        field = "failed"
                    else:
                        field = "compute_failed"
                    self.stats.add(**{field: 1})
                    if not _deliver_error(m, e):
                        self.stats.add(**{field: -1})

    def _next_batch(self) -> list[ReadTicket] | None:
        """Pop the scheduler's next request (weighted round-robin over
        the priority classes) plus every batchable queued request from
        the same class (same key, same group, up to ``batch_window``) —
        the coalescing unit.  None = closed + drained."""
        with self._lock:
            while True:
                if len(self._sched) and (not self._paused or self._closed):
                    break
                if self._closed and not len(self._sched):
                    return None
                self._not_empty.wait()
            head = self._sched.pop_head()
            batch = self._sched.drain_matching(
                head, self.config.batch_window, self.config.coalesce
            )
            self.stats.add(batches=1)
            self._slot_free.notify_all()
        return batch

    def _coalesce(self, batch: list[ReadTicket]) -> list[_Cluster]:
        """Greedy window merge: sorted-by-origin requests fold into the
        first cluster they overlap/touch without exceeding the waste
        bound.  Duplicated ROIs collapse into one fetch for free."""
        clusters: list[_Cluster] = []
        for req in sorted(batch, key=lambda r: (r.roi.lo, r.roi.hi)):
            for c in clusters:
                if c.try_absorb(req, self.config.max_window_waste):
                    break
            else:
                clusters.append(_Cluster(req))
        return clusters

    def _serve_batch(self, batch: list[ReadTicket]) -> None:
        if isinstance(batch[0], WriteTicket):
            self._serve_writes(batch)
            return
        if batch[0].group is not None:
            # compute batch (same key, same chain digest): the engine
            # coalesces the FETCHES like reads, then runs the chain on
            # each member's own ROI slice through the device pipeline
            self.engine.serve_batch(batch, self)
            return
        if self.config.coalesce and len(batch) > 1:
            clusters = self._coalesce(batch)
        else:
            clusters = [_Cluster(r) for r in batch]
        for c in clusters:
            self.stats.add(
                windows=1, coalesced=len(c.members) if len(c.members) > 1 else 0
            )
            if len(c.members) == 1:
                self._serve_one(c.members[0])
                continue
            key = c.members[0].key
            # generation BEFORE the fetch: a racing put makes the cached
            # window a spurious miss, never a stale hit
            gen = self._gens.current(key) if self._rcache is not None else 0
            try:
                window_arr = self.store.get(key, c.window)
            except TransportError:
                # infrastructure failure (replica failover exhausted), not
                # a coverage hole: counted separately so operators see it,
                # but still degraded to per-request reads — a member whose
                # ROI lives in an upper tier (RAM/DISK) is served even
                # while the DMS is down, and members that genuinely need
                # the dead servers fail with their own TransportError
                # (cheap: the transport's liveness cache fails fast)
                self.stats.add(window_failures=1)
                for m in c.members:
                    self._serve_one(m)
                continue
            except Exception:  # noqa: BLE001 — coverage hole (KeyError) or
                # another per-window tier error: degrade to per-request
                # reads, which either succeed or surface the member's own
                # error — coalescing stays a pure optimization
                self.stats.add(window_fallbacks=1)
                for m in c.members:
                    self._serve_one(m)
                continue
            if self._rcache is not None:
                self._rcache.put((key, c.window), gen, window_arr)
            if self._prefetcher is not None:
                self._prefetcher.observe(key, c.window)
            for m in c.members:
                if m.done():
                    continue  # cancelled while queued
                try:
                    # slice per caller; copy so clients never alias the
                    # shared window payload (or each other — duplicated
                    # ROIs would otherwise all receive the same view)
                    payload = window_arr[m.roi.local_slices(c.window)].copy()
                except BaseException as e:  # noqa: BLE001 — e.g. MemoryError
                    # on the copy: fail this member, keep serving the rest
                    self.stats.add(failed=1)
                    if not _deliver_error(m, e):
                        self.stats.add(failed=-1)
                    continue
                self.stats.add(served=1)
                self.stats.class_add(m.priority, served=1)
                if not _deliver(m, payload):
                    self.stats.add(served=-1)
                    self.stats.class_add(m.priority, served=-1)

    def _serve_one(self, req: ReadTicket) -> None:
        if req.done():
            return  # cancelled while queued: don't fetch, don't re-resolve
        gen = self._gens.current(req.key) if self._rcache is not None else 0
        try:
            value = self.store.get(req.key, req.roi)
        except BaseException as e:  # noqa: BLE001 — surfaced on the ticket
            self.stats.add(failed=1)
            if not _deliver_error(req, e):
                self.stats.add(failed=-1)
            return
        if self._rcache is not None:
            # the cache keeps the fetched array; the caller gets a copy
            # so a client mutating its result never corrupts future hits
            self._rcache.put((req.key, req.roi), gen, value)
            value = value.copy()
        if self._prefetcher is not None:
            self._prefetcher.observe(req.key, req.roi)
        self.stats.add(served=1)
        self.stats.class_add(req.priority, served=1)
        if not _deliver(req, value):
            self.stats.add(served=-1)
            self.stats.class_add(req.priority, served=-1)

    def _serve_writes(self, batch: list[WriteTicket]) -> None:
        """Flush one same-key write batch: last-writer-wins per ROI
        (submission order — later queued puts supersede earlier ones to
        the same ROI), one store put per surviving write."""
        live = [t for t in batch if not t.done()]
        survivors: dict[BoundingBox, WriteTicket] = {}
        order: list[BoundingBox] = []
        for t in live:
            if t.roi not in survivors:
                order.append(t.roi)
            survivors[t.roi] = t
        self.stats.add(
            write_batches=1, write_coalesced=len(live) - len(survivors)
        )
        errors: dict[BoundingBox, BaseException] = {}
        applied = 0
        for bb in order:
            t = survivors[bb]
            try:
                self.store.put(t.key, bb, t.array)
                applied += 1
            except BaseException as e:  # noqa: BLE001 — surfaced per ticket
                errors[bb] = e
        if applied:
            self.stats.add(writes_applied=applied)
            # one invalidation per flushed key: caches + fleet gossip see
            # the final batch state, not every superseded intermediate
            self._note_write(live[0].key)
        for t in live:
            err = errors.get(t.roi)
            if err is not None:
                self.stats.add(write_failed=1)
                if not _deliver_error(t, err):
                    self.stats.add(write_failed=-1)
            elif not _deliver(t, None):
                pass  # cancelled after flush: the write still happened

    # -- StorageBackend protocol ----------------------------------------------------
    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        ticket = self.submit(key, roi)
        try:
            return ticket.result(self.config.request_timeout)
        except TimeoutError:
            # cancel so a worker skips the ticket (workers already skip
            # done() members) instead of fetching a window for a caller
            # that gave up — and counting the orphan as served
            if ticket.cancel():
                self.stats.add(abandoned=1)
            raise

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        if self.config.coalesce_puts:
            ticket = self.submit_put(key, bb, array)
            try:
                ticket.result(self.config.request_timeout)
            except TimeoutError:
                if ticket.cancel():
                    self.stats.add(abandoned=1)
                raise
            return
        self.store.put(key, bb, array)
        self.stats.add(writes=1, writes_applied=1)
        # a write through the facade invalidates the key's cached
        # responses/derived products and gossips the fleet generation
        # (stores with generation() also catch direct puts)
        self._note_write(key)

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        return self.store.query(namespace, name)

    def delete(self, key: RegionKey) -> None:
        self.store.delete(key)
        self._note_write(key)

    def _note_write(self, key: RegionKey) -> None:
        """Post-write invalidation fan-out: generation tracker (local +
        fleet gossip), response cache, and the derived-product cache."""
        self._gens.note_write(key)
        if self._rcache is not None:
            self._rcache.invalidate(key)
        engine = self._engine  # relint: allow(guarded-attribute) — monotonic None->engine; a racing first build has no derived products to invalidate
        if engine is not None:
            engine.cache.invalidate(key)

    # -- lifecycle ------------------------------------------------------------------
    def pause(self) -> None:
        """Stop dispatching (admission continues up to the queue bound).
        Maintenance hook; also makes coalescing deterministic in tests."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._not_empty.notify_all()

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._sched)

    def storage_stats(self) -> dict:
        """One operator view of the whole serving path: the gateway's own
        request counters (including per-priority-class rows, the
        response-cache health, and — as the ``"compute"`` sub-namespace —
        the per-chain compute counters) plus whatever the wrapped store
        exposes — tier hit/miss accounting
        (:class:`~repro.storage.tiers.TierStats`), the DMS availability
        counters (:class:`~repro.storage.dms.DMSStats`), and the
        transport byte counters.  A dashboard polling the gateway sees
        replica failover and anti-entropy repair happening below it
        without reaching around the facade.

        The top-level ``"compute"`` key is a deprecated alias of
        ``["gateway"]["compute"]``, kept for one release.
        """
        gw: dict = self.stats.as_dict()
        if self._rcache is not None:
            gw["response_cache"] = self._rcache.as_dict()
        engine = self._engine  # relint: allow(guarded-attribute) — monotonic None->engine; stats snapshots tolerate missing the engine being built right now
        if engine is not None:
            # per-chain latency + egress savings and derived-cache health
            gw["compute"] = engine.as_dict()
        out: dict = {"gateway": gw}
        if engine is not None:
            out["compute"] = gw["compute"]  # deprecated alias (one release)
        tier_stats = getattr(self.store, "tier_stats", None)
        if callable(tier_stats):
            out["tiers"] = {n: s.as_dict() for n, s in tier_stats().items()}
        backends = [self.store]
        backends += [t.backend for t in getattr(self.store, "tiers", ())]
        for backend in backends:
            stats = getattr(backend, "stats", None)
            if not isinstance(stats, DMSStats):
                continue
            entry = {"dms": stats.as_dict()}
            transport = getattr(backend, "transport", None)
            tstats = getattr(transport, "stats", None)
            if tstats is not None:
                # as_dict() snapshots every counter under the stats lock;
                # asdict() here was the PR-7 torn-read bug class
                entry["transport"] = tstats.as_dict()
            rebalance = getattr(backend, "rebalance_stats", None)
            if callable(rebalance):
                # elastic-fleet health: ring epoch/checksum, whether a
                # paced sweep is running, and the last sweep's report
                entry["rebalance"] = rebalance()
            out.setdefault("dms", {})[getattr(backend, "name", "DMS")] = entry
        return out

    def close(self, *, close_store: bool = True) -> None:
        """Clean shutdown: refuse new requests, drain + answer every
        queued/in-flight request, join the workers, then (by default)
        close the wrapped store."""
        with self._lock:
            already = self._closed
            self._closed = True
            self._paused = False  # a paused gateway still drains on close
            self._not_empty.notify_all()
            self._slot_free.notify_all()
        if not already:
            for w in self._workers:
                w.join(timeout=60.0)
            if self._prefetcher is not None:
                self._prefetcher.close()
        if close_store:
            store_close = getattr(self.store, "close", None)
            if callable(store_close):
                store_close()

    def __getattr__(self, attr: str):
        # transparency: drain/flush/tier_stats/locality/... reach the store
        store = self.__dict__.get("store")
        if store is None:
            raise AttributeError(attr)
        return getattr(store, attr)

    def __repr__(self) -> str:
        return (
            f"RegionGateway({self.name}: {self.config.workers} workers, "
            f"queue {self.queue_depth()}/{self.config.max_queue})"
        )
