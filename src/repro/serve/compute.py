"""Near-data compute: server-side kernel chains over the region gateway.

The paper's hierarchical dataflow (§3) runs each computing stage next to
its data; this module is that claim for the serving path.  A client
submits a :class:`ComputeRequest` naming a registered kernel chain
(:mod:`repro.kernels.chains`) and the gateway executes it *server-side*:

  client → gateway (admission) → coalesce compute ROIs → ONE window
  fetch per cluster → DevicePipeline (upload | kernels | download,
  paper §3.2.1) → derived-product cache → derived array / feature vector

Only the derived result crosses the wire back — a uint8 mask (4× smaller
than a float32 plane, 12× smaller than the RGB tiles it came from) or a
9-float feature vector (~10⁶× smaller) — which is the egress win the
astronomy case study's server-side quantitative queries demonstrate
(arXiv:1111.6661).

Correctness contract: a gateway ``compute()`` is bit-exact with fetching
the same ROI locally and running the same chain — coalescing merges the
*fetches*, never the kernel inputs (each member's chain runs on its own
ROI slice of the shared window), so non-local stages (percentile
normalization, CCL) see exactly the bytes a local run would.

The derived-product cache is keyed ``(region key, chain digest, roi)``
and validated by *put generation*: every entry records the key's write
generation at fetch time (captured BEFORE the fetch, so a racing put can
only cause a spurious miss, never a stale hit) and a lookup re-checks it
against the store's :meth:`~repro.storage.tiers.TieredStore.generation`
— writes that bypass the gateway still invalidate.  Stores without
generation tracking fall back to a gateway-local counter bumped on every
``put``/``delete`` through the facade.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Mapping

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey
from repro.kernels.chains import Chain, resolve_chain
from repro.runtime.prefetch import DevicePipeline
from repro.serve.gateway import ReadTicket, _Cluster, _deliver, _deliver_error
from repro.serve.rcache import GenerationTracker, ResponseCache
from repro.storage.dms import TransportError


@dataclasses.dataclass(frozen=True)
class ComputeRequest:
    """One server-side chain execution over one ROI."""

    key: RegionKey
    roi: BoundingBox
    chain: str
    params: Mapping[str, Any] | None = None


class ComputeTicket(ReadTicket):
    """Future for a submitted compute; ``group`` keys worker batching so
    only same-key same-chain requests drain into one coalescing batch."""

    def __init__(self, request: ComputeRequest, chain: Chain) -> None:
        super().__init__(request.key, request.roi)
        self.request = request
        self.chain_obj = chain
        self.digest = chain.digest()
        self.group = ("compute", self.digest)


class DerivedCache(ResponseCache):
    """Bytes-bounded LRU of derived products, generation-validated.

    Key: ``(region key, chain digest, roi)``.  This IS the serving
    tier's :class:`~repro.serve.rcache.ResponseCache` (re-exported under
    its derived-product name): entries store the write generation they
    were computed under, :meth:`get` revalidates against the caller-
    supplied current generation, and a stale entry is a miss (and is
    dropped) — never a stale hit.  All methods are thread-safe.
    """


class ChainStats:
    """Per-chain accounting (latency + egress bytes saved), lock-guarded."""

    _ZERO = {
        "requests": 0,
        "served": 0,
        "failed": 0,
        "cache_hits": 0,
        "raw_bytes": 0,      # bytes fetched from the store, server-side
        "derived_bytes": 0,  # bytes returned to clients
        "compute_ms": 0.0,
    }

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._chains: dict[str, dict] = {}

    def add(self, chain: str, **deltas) -> None:
        with self._lock:
            row = self._chains.setdefault(chain, dict(self._ZERO))
            for k, v in deltas.items():
                row[k] += v

    def as_dict(self) -> dict:
        with self._lock:
            return {c: dict(row) for c, row in self._chains.items()}


class ComputeEngine:
    """Executes compute batches for a gateway's worker pool.

    One engine per gateway; it owns the derived cache and the per-chain
    stats, and borrows the gateway's coalescer/stats for the fetch side.
    """

    def __init__(self, store, config, *, gens: GenerationTracker | None = None) -> None:
        self.store = store
        self.config = config
        self.cache = DerivedCache(config.compute_cache_bytes)
        self.chain_stats = ChainStats()
        # generation source, shared with the owning gateway's response
        # cache when the gateway built us: a store with its own
        # write-generation tracking (TieredStore) catches puts that
        # bypass the gateway, a local counter covers plain backends, and
        # fleet mode folds in the gossiped fleet-wide max
        self._gens = gens if gens is not None else GenerationTracker(store)

    # -- generations ----------------------------------------------------------
    def generation(self, key: RegionKey) -> int:
        return self._gens.current(key)

    def note_write(self, key: RegionKey) -> None:
        """Record a facade write: standalone-engine users only — a
        gateway-owned engine shares the gateway's tracker, and the
        gateway's ``_note_write`` already bumped it."""
        self._gens.note_write(key)
        self.cache.invalidate(key)

    # -- cache fast path (called at submit time, before queueing) --------------
    def cached(self, ticket: ComputeTicket) -> np.ndarray | None:
        ck = (ticket.key, ticket.digest, ticket.roi)
        arr = self.cache.get(ck, self.generation(ticket.key))
        if arr is None:
            return None
        self.chain_stats.add(
            ticket.chain_obj.name, cache_hits=1, derived_bytes=arr.nbytes
        )
        return arr.copy()  # callers never alias the cached entry

    # -- batch execution (called from a gateway worker) -------------------------
    def serve_batch(self, batch: list[ComputeTicket], gateway) -> None:
        chain = batch[0].chain_obj
        cfg = gateway.config
        stats = gateway.stats
        if cfg.coalesce and len(batch) > 1:
            clusters = gateway._coalesce(batch)
        else:
            clusters = [_Cluster(t) for t in batch]
        # fetch phase: one store read per merged window, degraded to
        # per-member reads on coverage holes / transport failures —
        # exactly the read path's semantics
        items: list[tuple[ComputeTicket, np.ndarray, int]] = []
        raw_bytes = 0
        for c in clusters:
            live = [m for m in c.members if not m.done()]
            if not live:
                continue
            stats.add(
                compute_windows=1,
                compute_coalesced=len(c.members) if len(c.members) > 1 else 0,
            )
            gen = self.generation(c.members[0].key)  # BEFORE the fetch
            window_arr = None
            if len(live) == 1:
                c = _Cluster(live[0])  # no sharing: fetch the exact ROI
            try:
                window_arr = gateway.store.get(live[0].key, c.window)
            except TransportError:
                stats.add(compute_window_failures=1)
            except Exception:  # noqa: BLE001 — coverage hole etc.
                if len(c.members) > 1:
                    stats.add(compute_window_fallbacks=1)
            if window_arr is not None:
                raw_bytes += window_arr.nbytes
                for m in live:
                    items.append((m, window_arr[m.roi.local_slices(c.window)], gen))
                continue
            # degraded path: per-member fetches (each may still succeed
            # from an upper tier, or surface its own error)
            for m in live:
                gen = self.generation(m.key)
                try:
                    arr = gateway.store.get(m.key, m.roi)
                except BaseException as e:  # noqa: BLE001
                    stats.add(compute_failed=1)
                    self.chain_stats.add(chain.name, failed=1)
                    if not _deliver_error(m, e):
                        stats.add(compute_failed=-1)
                        self.chain_stats.add(chain.name, failed=-1)
                    continue
                raw_bytes += arr.nbytes
                items.append((m, arr, gen))
        # raw-fetch accounting lands BEFORE any ticket is fulfilled so a
        # client waking on .result() already sees its window's bytes
        if raw_bytes:
            self.chain_stats.add(chain.name, raw_bytes=raw_bytes)
            stats.add(raw_fetch_bytes=raw_bytes)
        if not items:
            return
        # compute phase: batched windows through the 3-phase device
        # pipeline (upload | kernel chain | download overlap, §3.2.1)
        pipe = DevicePipeline(
            chain.device_fn(cfg.compute_impl),
            window=cfg.compute_pipeline_window,
            host_fn=chain.host_fn(),
        )
        t0 = time.perf_counter()
        try:
            for (m, _, gen), out in zip(items, pipe.map(a for _, a, _ in items)):
                result = np.asarray(out)
                self.cache.put((m.key, m.digest, m.roi), gen, result)
                # count before fulfilling (see gateway._deliver), rolling
                # back only on a lost race with a client-side cancel
                stats.add(compute_served=1, derived_reply_bytes=result.nbytes)
                self.chain_stats.add(
                    chain.name, served=1, derived_bytes=result.nbytes
                )
                if not _deliver(m, result.copy()):
                    stats.add(
                        compute_served=-1, derived_reply_bytes=-result.nbytes
                    )
                    self.chain_stats.add(
                        chain.name, served=-1, derived_bytes=-result.nbytes
                    )
        except BaseException as e:  # noqa: BLE001 — a kernel failure must
            # answer every still-pending member, not poison the batch
            for m, _, _ in items:
                if m.done():
                    continue
                stats.add(compute_failed=1)
                self.chain_stats.add(chain.name, failed=1)
                if not _deliver_error(m, e):
                    stats.add(compute_failed=-1)
                    self.chain_stats.add(chain.name, failed=-1)
        self.chain_stats.add(
            chain.name, compute_ms=(time.perf_counter() - t0) * 1e3
        )

    def as_dict(self) -> dict:
        return {"chains": self.chain_stats.as_dict(), "cache": self.cache.as_dict()}


def make_ticket(request: ComputeRequest) -> ComputeTicket:
    """Resolve + validate a request into a ticket; raises the typed
    :mod:`repro.kernels.chains` errors *before* anything is queued."""
    chain = resolve_chain(request.chain, request.params)
    chain.check_input_rank(request.roi.rank)
    return ComputeTicket(request, chain)
