"""Hilbert space-filling curve (SFC) used by the DMS distributed hash table.

The paper (S4.1, Fig. 9) maps n-D bounding boxes to a 1-D domain with a
Hilbert SFC, compacts the (possibly non-contiguous) image of the
application domain into a *virtual domain*, and range-partitions that
virtual domain over the storage servers.

We implement the classic iterative 2-D Hilbert transform (Wikipedia /
Warren variant) plus a Morton (Z-order) fallback for ranks != 2.  Both are
bijective on [0, 2^order)^rank -> [0, 2^(rank*order)) and are
property-tested in tests/test_hilbert.py.
"""
from __future__ import annotations

from typing import Sequence


def hilbert_d2xy(order: int, d: int) -> tuple[int, int]:
    """Distance along the curve -> (x, y) on a 2^order x 2^order grid."""
    n = 1 << order
    x = y = 0
    t = d
    s = 1
    while s < n:
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        x, y = _rot(s, x, y, rx, ry)
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert_xy2d(order: int, x: int, y: int) -> int:
    """(x, y) -> distance along the curve."""
    n = 1 << order
    if not (0 <= x < n and 0 <= y < n):
        raise ValueError(f"point ({x},{y}) outside 2^{order} grid")
    d = 0
    s = n // 2
    while s > 0:
        rx = 1 if (x & s) > 0 else 0
        ry = 1 if (y & s) > 0 else 0
        d += s * s * ((3 * rx) ^ ry)
        x, y = _rot(s, x, y, rx, ry)
        s //= 2
    return d


def _rot(s: int, x: int, y: int, rx: int, ry: int) -> tuple[int, int]:
    if ry == 0:
        if rx == 1:
            x = s - 1 - x
            y = s - 1 - y
        x, y = y, x
    return x, y


def morton_encode(order: int, coords: Sequence[int]) -> int:
    """Z-order interleave for arbitrary rank (DMS fallback for rank != 2)."""
    d = 0
    rank = len(coords)
    for bit in range(order):
        for axis, c in enumerate(coords):
            d |= ((c >> bit) & 1) << (bit * rank + axis)
    return d


def morton_decode(order: int, rank: int, d: int) -> tuple[int, ...]:
    coords = [0] * rank
    for bit in range(order):
        for axis in range(rank):
            coords[axis] |= ((d >> (bit * rank + axis)) & 1) << bit
    return tuple(coords)


def sfc_index(order: int, coords: Sequence[int]) -> int:
    """Unified entry point used by the DHT: Hilbert for 2-D, Morton otherwise."""
    if len(coords) == 2:
        return hilbert_xy2d(order, coords[0], coords[1])
    return morton_encode(order, coords)


def sfc_order_for(extent: int) -> int:
    """Smallest order such that 2^order covers ``extent`` grid cells."""
    order = 0
    while (1 << order) < extent:
        order += 1
    return max(order, 1)
