"""Shared rate pacing: the token bucket.

One implementation serves every layer that needs to cap a request or
migration rate — the elastic-fleet rebalancer paces block migration with
it (one token per migrated block, yielding to foreground traffic), and
the serving gateway's per-client throttle paces request admission with
it (one token per submitted request, so a hog client self-limits before
it can monopolize the admission queue).
"""
from __future__ import annotations

import threading
import time


class TokenBucket:
    """Blocking token-bucket pacer.

    ``rate`` tokens refill per second up to ``burst`` (default: one
    second's worth).  :meth:`take` blocks until the requested tokens are
    available and returns the seconds it waited — the rebalance sweep
    pays one token per migrated block, which caps migration throughput
    and leaves the fleet's remaining capacity to foreground traffic; the
    gateway's client throttle pays one token per request, which caps a
    single client's submit rate without touching anyone else's.
    ``clock``/``sleep`` are injectable for deterministic tests.
    """

    def __init__(
        self,
        rate: float,
        burst: float | None = None,
        *,
        clock=time.monotonic,
        sleep=time.sleep,
    ) -> None:
        self.rate = float(rate)
        if self.rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        self.burst = float(burst) if burst is not None else max(self.rate, 1.0)
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.Lock()
        self._tokens = self.burst
        self._last = clock()

    def _refill_locked(self, now: float) -> None:
        self._tokens = min(self.burst, self._tokens + (now - self._last) * self.rate)
        self._last = now

    def take(self, n: float = 1.0) -> float:
        """Consume ``n`` tokens, sleeping as needed; returns the seconds
        spent waiting (0.0 on the fast path)."""
        waited = 0.0
        while True:
            with self._lock:
                self._refill_locked(self._clock())
                if self._tokens >= n:
                    self._tokens -= n
                    return waited
                # clamp to 1us: float dust near the boundary would make
                # the sleep too small to advance any clock (and a real
                # clock would busy-spin instead of sleeping)
                need = max((n - self._tokens) / self.rate, 1e-6)
            # sleep OUTSIDE the lock: other takers must not queue behind
            # this waiter's nap
            self._sleep(need)
            waited += need
