"""Spatio-temporal bounding boxes for region templates.

The paper (S3.3) defines a region template as a container bounded by a
spatial + temporal bounding box; data regions carry their own bounding box
and an ROI (region of interest) restricting what is materialized.  Boxes
here are half-open integer boxes ``[lo, hi)`` over an n-dimensional index
domain, which composes exactly with array slicing.

Ghost cells (S3.4) are handled by ``inflate`` (grow the ROI before reading)
and ``shrink`` (drop the halo before staging).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Iterator, Sequence


@dataclasses.dataclass(frozen=True, order=True)
class BoundingBox:
    """Half-open n-D box ``[lo, hi)`` with an optional time interval."""

    lo: tuple[int, ...]
    hi: tuple[int, ...]
    t_lo: int = 0
    t_hi: int = 1

    def __post_init__(self) -> None:
        if len(self.lo) != len(self.hi):
            raise ValueError(f"rank mismatch: {self.lo} vs {self.hi}")
        if any(h < l for l, h in zip(self.lo, self.hi)):
            raise ValueError(f"inverted box: {self.lo}..{self.hi}")
        if self.t_hi < self.t_lo:
            raise ValueError(f"inverted time interval: {self.t_lo}..{self.t_hi}")

    # -- construction helpers -------------------------------------------------
    @staticmethod
    def from_shape(shape: Sequence[int], t_lo: int = 0, t_hi: int = 1) -> "BoundingBox":
        return BoundingBox(tuple(0 for _ in shape), tuple(int(s) for s in shape), t_lo, t_hi)

    # -- basic geometry --------------------------------------------------------
    @property
    def rank(self) -> int:
        return len(self.lo)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(h - l for l, h in zip(self.lo, self.hi))

    @property
    def volume(self) -> int:
        v = 1
        for s in self.shape:
            v *= s
        return v

    @property
    def is_empty(self) -> bool:
        return self.volume == 0

    def slices(self) -> tuple[slice, ...]:
        """Slices addressing this box inside the global domain."""
        return tuple(slice(l, h) for l, h in zip(self.lo, self.hi))

    def local_slices(self, outer: "BoundingBox") -> tuple[slice, ...]:
        """Slices addressing this box inside an array whose origin is ``outer.lo``."""
        if not outer.contains(self):
            raise ValueError(f"{self} not contained in {outer}")
        return tuple(
            slice(l - ol, h - ol) for l, h, ol in zip(self.lo, self.hi, outer.lo)
        )

    # -- set operations ---------------------------------------------------------
    def contains(self, other: "BoundingBox") -> bool:
        return all(ol >= l for ol, l in zip(other.lo, self.lo)) and all(
            oh <= h for oh, h in zip(other.hi, self.hi)
        )

    def contains_point(self, pt: Sequence[int]) -> bool:
        return all(l <= p < h for p, l, h in zip(pt, self.lo, self.hi))

    def intersects(self, other: "BoundingBox") -> bool:
        return all(
            max(l, ol) < min(h, oh)
            for l, h, ol, oh in zip(self.lo, self.hi, other.lo, other.hi)
        )

    def intersect(self, other: "BoundingBox") -> "BoundingBox":
        lo = tuple(max(l, ol) for l, ol in zip(self.lo, other.lo))
        hi = tuple(max(lo_i, min(h, oh)) for lo_i, h, oh in zip(lo, self.hi, other.hi))
        return BoundingBox(lo, hi, max(self.t_lo, other.t_lo), max(self.t_hi, other.t_hi))

    def union(self, other: "BoundingBox") -> "BoundingBox":
        """Minimum bounding box of both (paper: RT bb grows as regions insert)."""
        lo = tuple(min(l, ol) for l, ol in zip(self.lo, other.lo))
        hi = tuple(max(h, oh) for h, oh in zip(self.hi, other.hi))
        return BoundingBox(lo, hi, min(self.t_lo, other.t_lo), max(self.t_hi, other.t_hi))

    # -- ghost-cell handling ------------------------------------------------------
    def inflate(self, halo: int | Sequence[int], within: "BoundingBox | None" = None) -> "BoundingBox":
        """Grow by ``halo`` per dim (clamped to ``within``): ghost-cell read ROI."""
        h = tuple(halo for _ in self.lo) if isinstance(halo, int) else tuple(halo)
        lo = tuple(l - hh for l, hh in zip(self.lo, h))
        hi = tuple(x + hh for x, hh in zip(self.hi, h))
        box = BoundingBox(lo, hi, self.t_lo, self.t_hi)
        return box.intersect(within) if within is not None else box

    def shrink(self, halo: int | Sequence[int]) -> "BoundingBox":
        """Drop the halo before staging results back (paper S3.4)."""
        h = tuple(halo for _ in self.lo) if isinstance(halo, int) else tuple(halo)
        return BoundingBox(
            tuple(l + hh for l, hh in zip(self.lo, h)),
            tuple(x - hh for x, hh in zip(self.hi, h)),
            self.t_lo,
            self.t_hi,
        )

    # -- partitioning ---------------------------------------------------------------
    def tiles(self, tile_shape: Sequence[int]) -> Iterator["BoundingBox"]:
        """Regular partition (paper Fig. 7 left: 50x50 blocks). Edge tiles clip."""
        ranges = []
        for l, h, t in zip(self.lo, self.hi, tile_shape):
            starts = range(l, h, int(t)) if h > l else []
            ranges.append([(s, min(s + int(t), h)) for s in starts])
        for combo in itertools.product(*ranges):
            lo = tuple(c[0] for c in combo)
            hi = tuple(c[1] for c in combo)
            yield BoundingBox(lo, hi, self.t_lo, self.t_hi)

    def split_weighted(self, weights: Sequence[float], axis: int = 0) -> list["BoundingBox"]:
        """Irregular 1-axis partition for load balance (paper Fig. 7 right)."""
        total = float(sum(weights))
        if total <= 0:
            raise ValueError("weights must sum > 0")
        extent = self.hi[axis] - self.lo[axis]
        cuts = [self.lo[axis]]
        acc = 0.0
        for w in weights[:-1]:
            acc += w / total
            cuts.append(self.lo[axis] + int(round(acc * extent)))
        cuts.append(self.hi[axis])
        out = []
        for a, b in zip(cuts[:-1], cuts[1:]):
            lo = list(self.lo)
            hi = list(self.hi)
            lo[axis], hi[axis] = a, max(a, b)
            out.append(BoundingBox(tuple(lo), tuple(hi), self.t_lo, self.t_hi))
        return out

    # -- misc ----------------------------------------------------------------
    def translate(self, offset: Sequence[int]) -> "BoundingBox":
        return BoundingBox(
            tuple(l + o for l, o in zip(self.lo, offset)),
            tuple(h + o for h, o in zip(self.hi, offset)),
            self.t_lo,
            self.t_hi,
        )

    def at_time(self, t_lo: int, t_hi: int | None = None) -> "BoundingBox":
        return BoundingBox(self.lo, self.hi, t_lo, t_hi if t_hi is not None else t_lo + 1)

    def __repr__(self) -> str:  # compact: <0,0;99,99>@[0,1)
        lo = ",".join(map(str, self.lo))
        hi = ",".join(map(str, self.hi))
        return f"<{lo};{hi}>@[{self.t_lo},{self.t_hi})"


def union_all(boxes: Iterable[BoundingBox]) -> BoundingBox:
    it = iter(boxes)
    try:
        acc = next(it)
    except StopIteration:
        raise ValueError("union_all of no boxes") from None
    for b in it:
        acc = acc.union(b)
    return acc
