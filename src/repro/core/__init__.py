"""Core region-template abstraction (the paper's primary contribution)."""
from repro.core.bbox import BoundingBox, union_all
from repro.core.hilbert import (
    hilbert_d2xy,
    hilbert_xy2d,
    morton_decode,
    morton_encode,
    sfc_index,
    sfc_order_for,
)
from repro.core.pacing import TokenBucket
from repro.core.regions import (
    STORAGE,
    DataRegion,
    ElementType,
    Intent,
    ObjectSetRegion,
    RegionKey,
    RegionKind,
    RegionTemplate,
    StorageBackend,
    StorageRegistry,
)

__all__ = [
    "BoundingBox",
    "union_all",
    "hilbert_d2xy",
    "hilbert_xy2d",
    "morton_encode",
    "morton_decode",
    "sfc_index",
    "sfc_order_for",
    "TokenBucket",
    "STORAGE",
    "DataRegion",
    "ElementType",
    "Intent",
    "ObjectSetRegion",
    "RegionKey",
    "RegionKind",
    "RegionTemplate",
    "StorageBackend",
    "StorageRegistry",
]
