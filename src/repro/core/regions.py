"""Region templates and data regions (paper S3.3, Fig. 6).

A ``RegionTemplate`` is a named container covering a spatio-temporal
bounding box and holding many ``DataRegion``s.  Data regions are the
storage materialization of a data type; they are identified by the tuple

    (namespace::name, element type, timestamp, version)

and carry their own bounding box + ROI.  Applications read/write through
get/insert on the template; *where* the bytes live (host memory, device
memory, the DMS distributed store, the DISK store) is the runtime's
business, not the application's.

Materialization states:
  - metadata-only (lazy): shape/dtype/bb known, no payload   (paper: lazyRead)
  - host:   numpy ndarray on the host
  - device: jax.Array (possibly sharded over a mesh)

The storage backends implement the small ``StorageBackend`` protocol at the
bottom of this file; concrete implementations live in repro.storage.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

import numpy as np

from repro.core.bbox import BoundingBox


class ElementType(enum.IntEnum):
    """Element type of a data region (paper: CHAR, UCHAR, ... extended)."""

    UINT8 = 0
    INT32 = 1
    INT64 = 2
    FLOAT32 = 3
    FLOAT64 = 4
    BFLOAT16 = 5
    BOOL = 6

    def to_dtype(self) -> np.dtype:
        import jax.numpy as jnp

        return {
            ElementType.UINT8: np.dtype(np.uint8),
            ElementType.INT32: np.dtype(np.int32),
            ElementType.INT64: np.dtype(np.int64),
            ElementType.FLOAT32: np.dtype(np.float32),
            ElementType.FLOAT64: np.dtype(np.float64),
            ElementType.BFLOAT16: np.dtype(jnp.bfloat16),
            ElementType.BOOL: np.dtype(np.bool_),
        }[self]

    @staticmethod
    def from_dtype(dtype) -> "ElementType":
        import jax.numpy as jnp

        dt = np.dtype(dtype) if dtype != jnp.bfloat16 else np.dtype(jnp.bfloat16)
        table = {
            np.dtype(np.uint8): ElementType.UINT8,
            np.dtype(np.int32): ElementType.INT32,
            np.dtype(np.int64): ElementType.INT64,
            np.dtype(np.float32): ElementType.FLOAT32,
            np.dtype(np.float64): ElementType.FLOAT64,
            np.dtype(jnp.bfloat16): ElementType.BFLOAT16,
            np.dtype(np.bool_): ElementType.BOOL,
        }
        if dt not in table:
            raise ValueError(f"unsupported dtype {dtype}")
        return table[dt]


class RegionKind(enum.IntEnum):
    """Region type (paper: dense/sparse 1D/2D/3D, polygons, objects)."""

    DENSE = 0
    SPARSE = 1
    POLYGON = 2
    OBJECTSET = 3  # e.g. per-object feature vectors


class Intent(enum.IntEnum):
    """How a stage uses a data region (paper Fig. 8)."""

    INPUT = 0
    OUTPUT = 1
    INPUT_OUTPUT = 2

    @property
    def reads(self) -> bool:
        return self in (Intent.INPUT, Intent.INPUT_OUTPUT)

    @property
    def writes(self) -> bool:
        return self in (Intent.OUTPUT, Intent.INPUT_OUTPUT)


@dataclasses.dataclass(frozen=True, order=True)
class RegionKey:
    """The (namespace::name, type, timestamp, version) tuple identifier."""

    namespace: str
    name: str
    elem_type: ElementType
    timestamp: int = 0
    version: int = 0

    @property
    def qualified(self) -> str:
        return f"{self.namespace}::{self.name}"

    def bump(self) -> "RegionKey":
        return dataclasses.replace(self, version=self.version + 1)

    def at(self, timestamp: int) -> "RegionKey":
        return dataclasses.replace(self, timestamp=timestamp)


# --------------------------------------------------------------------------
# Storage protocol implemented by repro.storage backends
# --------------------------------------------------------------------------
@runtime_checkable
class StorageBackend(Protocol):
    name: str

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None: ...

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray: ...

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]: ...

    def delete(self, key: RegionKey) -> None: ...


class StorageRegistry:
    """Named registry so stages refer to backends by string ("DISK", "DMS")."""

    def __init__(self) -> None:
        self._backends: dict[str, StorageBackend] = {}
        self._lock = threading.Lock()

    def register(self, backend: StorageBackend) -> StorageBackend:
        with self._lock:
            self._backends[backend.name] = backend
        return backend

    def get(self, name: str) -> StorageBackend:
        with self._lock:
            if name not in self._backends:
                raise KeyError(
                    f"storage backend {name!r} not registered (have {sorted(self._backends)})"
                )
            return self._backends[name]

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._backends)

    def locality(self, name: str, key: "RegionKey") -> str | None:
        """Which layer of ``name`` holds ``key``.

        Hierarchical backends (e.g. ``TieredStore``) answer with a tier
        name ("MEM"/"DISK"/"DMS"); flat backends are their own single
        tier, so their backend name is returned (informative for event
        logs; tier pricing tables simply won't list it).  The Manager
        uses this for locality-aware dispatch and per-input events.
        """
        backend = self.get(name)
        loc = getattr(backend, "locality", None)
        if callable(loc):
            return loc(key)
        return backend.name


# A process-global registry; SysEnv (runtime.manager) populates it.
STORAGE = StorageRegistry()


# --------------------------------------------------------------------------
# Data regions
# --------------------------------------------------------------------------
class DataRegion:
    """One storage materialization of a typed region of data.

    Mirrors the paper's abstract DataRegion (Fig. 6b): tuple identifier,
    element/region type, bounding box + ROI, lazy instantiation, and
    pluggable input/output storage.  Concrete payloads are numpy arrays
    (host) or jax.Arrays (device); OBJECTSET payloads are dicts of arrays.
    """

    def __init__(
        self,
        key: RegionKey,
        bb: BoundingBox,
        kind: RegionKind = RegionKind.DENSE,
        *,
        roi: BoundingBox | None = None,
        data: Any | None = None,
        input_storage: str | None = None,
        output_storage: str | None = None,
        lazy: bool = False,
        resolution: int = 0,
    ) -> None:
        self.key = key
        self.kind = kind
        self.bb = bb
        self.roi = roi if roi is not None else bb
        self.input_storage = input_storage
        self.output_storage = output_storage
        self.lazy = lazy
        self.resolution = resolution
        self._data = data
        self._location = "none" if data is None else _infer_location(data)
        self._lock = threading.RLock()
        # async transfer bookkeeping (paper: non-blocking upload/download)
        self._pending: list[Callable[[], None]] = []
        self.stats = {"reads": 0, "writes": 0, "bytes_read": 0, "bytes_written": 0}

    # -- payload state --------------------------------------------------------
    @property
    def location(self) -> str:
        with self._lock:
            return self._location

    def empty(self) -> bool:
        with self._lock:
            return self._data is None

    @property
    def data(self) -> Any:
        # Lock-free fast path: holding _lock across instantiate() would
        # serialize every reader behind a storage fetch.  _data only
        # transitions None -> payload here (instantiate is idempotent),
        # so a stale None costs a redundant fetch, never a wrong answer.
        if self._data is None:  # relint: allow(guarded-attribute) — see above
            if self.lazy and self.input_storage:
                self.instantiate(STORAGE)
            else:
                raise RuntimeError(f"data region {self.key} not materialized")
        return self._data  # relint: allow(guarded-attribute) — monotonic publication

    def set_data(self, array: Any) -> None:
        with self._lock:
            self._data = array
            self._location = _infer_location(array)

    # -- storage interaction (paper: instantiateRegion / write) -----------------
    def instantiate(self, registry: StorageRegistry | None = None) -> Any:
        """Read the ROI from the input storage backend into host memory."""
        registry = registry or STORAGE
        if self.input_storage is None:
            raise RuntimeError(f"{self.key}: no input storage bound")
        backend = registry.get(self.input_storage)
        t0 = time.perf_counter()
        arr = backend.get(self.key, self.roi)
        with self._lock:
            self._data = arr
            self._location = "host"
            self.stats["reads"] += 1
            self.stats["bytes_read"] += int(getattr(arr, "nbytes", 0))
            self.stats["read_s"] = self.stats.get("read_s", 0.0) + time.perf_counter() - t0
        return arr

    def write(self, registry: StorageRegistry | None = None) -> None:
        """Stage the payload (restricted to the ROI) to the output backend."""
        registry = registry or STORAGE
        if self.output_storage is None:
            raise RuntimeError(f"{self.key}: no output storage bound")
        with self._lock:
            if self._data is None:
                raise RuntimeError(f"{self.key}: nothing to write")
        backend = registry.get(self.output_storage)
        arr = self.to_host()
        t0 = time.perf_counter()
        backend.put(self.key, self.roi, arr)
        with self._lock:
            self.stats["writes"] += 1
            self.stats["bytes_written"] += int(getattr(arr, "nbytes", 0))
            self.stats["write_s"] = self.stats.get("write_s", 0.0) + time.perf_counter() - t0

    # -- host/device movement (paper: upload/download, sync or async) -----------
    def to_device(self, device=None, sharding=None, *, blocking: bool = False) -> Any:
        import jax

        with self._lock:
            if self._data is None:
                raise RuntimeError(f"{self.key}: not materialized")
            tgt = sharding if sharding is not None else device
            arr = jax.device_put(self._data, tgt) if tgt is not None else jax.device_put(self._data)
            self._data = arr
            self._location = "device"
        if blocking:
            jax.block_until_ready(arr)
        return arr

    def to_host(self) -> np.ndarray:
        with self._lock:
            if self._location == "device":
                self._data = np.asarray(self._data)
                self._location = "host"
            return self._data

    def ready(self) -> bool:
        """Non-blocking transfer-completion query (paper S3.3)."""
        # A readiness probe must stay non-blocking: taking _lock here
        # would park it behind an in-flight to_device()'s device_put.
        # CPython attribute loads are atomic; a stale answer is the
        # accepted semantics of an asynchronous query.
        if self._location != "device":  # relint: allow(guarded-attribute) — see above
            return self._data is not None  # relint: allow(guarded-attribute) — see above
        try:
            import jax

            # jax arrays expose is_ready on the committed future
            return bool(getattr(self._data, "is_ready", lambda: True)())  # relint: allow(guarded-attribute) — see above
        except Exception:
            return True

    def block_until_ready(self) -> None:
        # snapshot under the lock, then block OUTSIDE it: holding _lock
        # across a device sync would stall every concurrent reader
        with self._lock:
            location, data = self._location, self._data
        if location == "device":
            import jax

            jax.block_until_ready(data)

    # -- misc -------------------------------------------------------------
    @property
    def nbytes(self) -> int:
        with self._lock:
            data = self._data
        if data is None:
            return int(np.prod(self.roi.shape)) * self.key.elem_type.to_dtype().itemsize
        return int(getattr(data, "nbytes", 0))

    def with_roi(self, roi: BoundingBox) -> "DataRegion":
        """Metadata-sharing view with a different ROI (partitioning, S3.4)."""
        return DataRegion(
            self.key,
            self.bb,
            self.kind,
            roi=roi,
            input_storage=self.input_storage,
            output_storage=self.output_storage,
            lazy=True,
        )

    def __repr__(self) -> str:
        return (
            f"DataRegion({self.key.qualified} t={self.key.timestamp} v={self.key.version} "
            f"{self.kind.name} bb={self.bb} roi={self.roi} "
            f"loc={self._location})"  # relint: allow(guarded-attribute) — diagnostic snapshot; repr must not block
        )


def _infer_location(data: Any) -> str:
    try:
        import jax

        if isinstance(data, jax.Array):
            return "device"
    except Exception:
        pass
    return "host"


class ObjectSetRegion(DataRegion):
    """OBJECTSET data region: per-object records (e.g. feature vectors).

    Payload is a dict of equal-length arrays keyed by field name, plus the
    per-object bounding boxes; matches the paper's feature-computation
    output (one 50-100 dim vector per segmented nucleus).
    """

    def __init__(self, key: RegionKey, bb: BoundingBox, **kw: Any) -> None:
        super().__init__(key, bb, RegionKind.OBJECTSET, **kw)

    @property
    def num_objects(self) -> int:
        if self._data is None:
            return 0
        first = next(iter(self._data.values()))
        return int(first.shape[0])


# --------------------------------------------------------------------------
# Region template
# --------------------------------------------------------------------------
class RegionTemplate:
    """Named container of data regions within a minimal bounding box.

    ``insert`` grows the template bb to remain the minimum box containing
    all inserted regions (paper S3.3).  Regions sharing a name are kept in
    a version list and must differ in (elem_type, timestamp, version).
    """

    def __init__(self, name: str, namespace: str = "default") -> None:
        self.name = name
        self.namespace = namespace
        self._regions: dict[str, list[DataRegion]] = {}
        self.bb: BoundingBox | None = None
        self._lock = threading.RLock()

    # -- insertion / lookup ------------------------------------------------------
    def insert(self, region: DataRegion) -> DataRegion:
        with self._lock:
            lst = self._regions.setdefault(region.key.name, [])
            for existing in lst:
                if existing.key == region.key:
                    raise ValueError(
                        f"duplicate data region {region.key} in template {self.name!r}"
                    )
            lst.append(region)
            self.bb = region.bb if self.bb is None else self.bb.union(region.bb)
        return region

    def get(
        self,
        name: str,
        *,
        timestamp: int | None = None,
        version: int | None = None,
        elem_type: ElementType | None = None,
    ) -> DataRegion:
        """Associative lookup; unspecified identifiers resolve to the latest."""
        with self._lock:
            lst = self._regions.get(name)
            if not lst:
                raise KeyError(f"no data region {name!r} in template {self.name!r}")
            cands = [
                r
                for r in lst
                if (timestamp is None or r.key.timestamp == timestamp)
                and (version is None or r.key.version == version)
                and (elem_type is None or r.key.elem_type == elem_type)
            ]
            if not cands:
                raise KeyError(
                    f"no data region {name!r} matching ts={timestamp} v={version} in {self.name!r}"
                )
            # paper: "the system will use the latest staged region"
            return max(cands, key=lambda r: (r.key.timestamp, r.key.version))

    def num_regions(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._regions.values())

    def region_names(self) -> list[str]:
        with self._lock:
            return sorted(self._regions)

    def all_regions(self) -> list[DataRegion]:
        with self._lock:
            return [r for lst in self._regions.values() for r in lst]

    def versions(self, name: str) -> list[RegionKey]:
        with self._lock:
            return sorted(r.key for r in self._regions.get(name, []))

    # -- convenience constructors -----------------------------------------------
    def new_region(
        self,
        name: str,
        bb: BoundingBox,
        dtype,
        *,
        kind: RegionKind = RegionKind.DENSE,
        timestamp: int = 0,
        version: int = 0,
        data: Any | None = None,
        input_storage: str | None = None,
        output_storage: str | None = None,
        lazy: bool = False,
    ) -> DataRegion:
        key = RegionKey(self.namespace, name, ElementType.from_dtype(dtype), timestamp, version)
        cls = ObjectSetRegion if kind == RegionKind.OBJECTSET else DataRegion
        region = cls(
            key,
            bb,
            **({} if kind == RegionKind.OBJECTSET else {"kind": kind}),
            data=data,
            input_storage=input_storage,
            output_storage=output_storage,
            lazy=lazy,
        )
        return self.insert(region)

    # -- partitioning (manager side, paper Fig. 8a) -------------------------------
    def partition(self, tile_shape: Iterable[int]) -> list[BoundingBox]:
        with self._lock:
            if self.bb is None:
                raise RuntimeError("empty region template has no domain to partition")
            return list(self.bb.tiles(tuple(tile_shape)))

    # -- pack/unpack for Manager -> Worker shipping (paper S3.2) -------------------
    def pack(self) -> dict:
        """Metadata-only description; payloads travel through global storage."""
        with self._lock:
            return {
                "name": self.name,
                "namespace": self.namespace,
                "bb": self.bb,
                "regions": [
                    {
                        "key": r.key,
                        "bb": r.bb,
                        "roi": r.roi,
                        "kind": r.kind,
                        "input_storage": r.input_storage,
                        "output_storage": r.output_storage,
                        "lazy": r.lazy,
                    }
                    for r in self.all_regions()
                ],
            }

    @staticmethod
    def unpack(blob: dict) -> "RegionTemplate":
        rt = RegionTemplate(blob["name"], blob["namespace"])
        for rd in blob["regions"]:
            cls = ObjectSetRegion if rd["kind"] == RegionKind.OBJECTSET else DataRegion
            kw = {} if rd["kind"] == RegionKind.OBJECTSET else {"kind": rd["kind"]}
            rt.insert(
                cls(
                    rd["key"],
                    rd["bb"],
                    **kw,
                    roi=rd["roi"],
                    input_storage=rd["input_storage"],
                    output_storage=rd["output_storage"],
                    lazy=True,
                )
            )
        rt.bb = blob["bb"]
        return rt

    def __repr__(self) -> str:
        return (
            f"RegionTemplate({self.namespace}::{self.name} "
            f"bb={self.bb} regions={self.num_regions()})"  # relint: allow(guarded-attribute) — diagnostic snapshot; repr must not block
        )
