"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are validated against in
``tests/test_kernels.py`` (shape/dtype sweeps, ``assert_allclose``) and the
implementations used for CPU execution and for dry-run lowering
(``impl='xla'``).

Notes on fidelity to the paper's operators (S5.1):
  * color deconvolution follows Ruifrok-Johnston optical-density unmixing
    (the paper uses OpenCV/ITK equivalents);
  * morphological reconstruction uses 4-connectivity; the GPU IWPP
    wavefront of [65] is replaced by separable forward/backward scans
    (same fixed point — see DESIGN.md hardware-adaptation notes);
  * connected component labeling is the union-find BWLabel of [50] on the
    host; the device path converges to the identical canonical labeling
    (min flat-index per component);
  * GLCM texture features follow Haralick's definitions.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Color deconvolution (stain unmixing)
# --------------------------------------------------------------------------
# Ruifrok & Johnston H&E+DAB stain matrix (rows: stains, cols: RGB OD).
RUIFROK_HED = np.array(
    [
        [0.650, 0.704, 0.286],  # hematoxylin
        [0.072, 0.990, 0.105],  # eosin
        [0.268, 0.570, 0.776],  # DAB
    ],
    dtype=np.float32,
)


def stain_inverse(stain_matrix: np.ndarray = RUIFROK_HED) -> np.ndarray:
    m = np.asarray(stain_matrix, dtype=np.float64)
    m = m / np.linalg.norm(m, axis=1, keepdims=True)
    return np.linalg.inv(m).astype(np.float32)


def color_deconv_ref(rgb: jax.Array, minv: jax.Array, eps: float = 1e-6) -> jax.Array:
    """(..., 3, H, W) float in [0,1] -> (..., 3, H, W) stain densities."""
    od = -jnp.log10(jnp.clip(rgb, eps, 1.0))
    # channels-first planar: out[s] = sum_c minv[c, s] * od[c]
    return jnp.einsum("...chw,cs->...shw", od, minv)


# --------------------------------------------------------------------------
# Morphological reconstruction by dilation (ReconToNuclei / FillHoles core)
# --------------------------------------------------------------------------
def _recon_scan_1d(marker: jax.Array, mask: jax.Array, axis: int, reverse: bool) -> jax.Array:
    """1-D grayscale reconstruction along ``axis`` via associative scan.

    The sequential recurrence m_j = min(I_j, max(J_j, m_{j-1})) is the
    composition of clamp functions f_j(x) = min(c_j, max(d_j, x)) with
    c=I (mask), d=J (marker); such functions compose closed-form:
      f2.f1 = (c', d') with c' = min(c2, max(d2, c1)), d' = max(d1, d2)
    so the whole row is a log-depth associative scan — the TPU-idiomatic
    replacement for the paper's GPU wavefront queues.
    """

    def combine(a, b):
        c1, d1 = a
        c2, d2 = b
        return jnp.minimum(c2, jnp.maximum(d2, c1)), jnp.maximum(d1, d2)

    axis = axis % marker.ndim  # associative_scan(reverse=) needs axis >= 0
    c, d = jax.lax.associative_scan(combine, (mask, marker), axis=axis, reverse=reverse)
    return jnp.minimum(c, d)


def morph_recon_sweep_ref(marker: jax.Array, mask: jax.Array) -> jax.Array:
    """One 4-direction sweep (down, up, right, left) of reconstruction."""
    j = jnp.minimum(marker, mask)
    j = _recon_scan_1d(j, mask, axis=-2, reverse=False)
    j = _recon_scan_1d(j, mask, axis=-2, reverse=True)
    j = _recon_scan_1d(j, mask, axis=-1, reverse=False)
    j = _recon_scan_1d(j, mask, axis=-1, reverse=True)
    return j


def morph_recon_ref(marker: jax.Array, mask: jax.Array, max_iters: int = 256) -> jax.Array:
    """Grayscale reconstruction by dilation to fixed point (4-connectivity)."""

    def cond(state):
        j, prev, it = state
        return jnp.logical_and(jnp.any(j != prev), it < max_iters)

    def body(state):
        j, _, it = state
        return morph_recon_sweep_ref(j, mask), j, it + 1

    j0 = jnp.minimum(marker, mask)
    j1 = morph_recon_sweep_ref(j0, mask)
    j, _, _ = jax.lax.while_loop(cond, body, (j1, j0, jnp.asarray(1)))
    return j


def fill_holes_ref(mask01: jax.Array) -> jax.Array:
    """Binary fill-holes via border-seeded reconstruction of the complement."""
    inv = 1.0 - mask01
    h, w = mask01.shape[-2], mask01.shape[-1]
    border = jnp.zeros_like(mask01)
    border = border.at[..., 0, :].set(1.0).at[..., h - 1, :].set(1.0)
    border = border.at[..., :, 0].set(1.0).at[..., :, w - 1].set(1.0)
    marker = jnp.minimum(border, inv)
    background = morph_recon_ref(marker, inv)
    return 1.0 - background


# --------------------------------------------------------------------------
# Connected component labeling
# --------------------------------------------------------------------------
def ccl_unionfind_host(mask: np.ndarray) -> np.ndarray:
    """The paper's BWLabel: union-find forest over 4-neighbors (host oracle).

    Returns int32 labels; background = -1; each component labeled by the
    minimum flat index it contains (canonical form).
    """
    mask = np.asarray(mask) != 0
    h, w = mask.shape
    parent = np.arange(h * w, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:  # path compression
            parent[x], x = root, parent[x]
        return root

    def union(a: int, b: int) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            if ra < rb:
                parent[rb] = ra
            else:
                parent[ra] = rb

    for i in range(h):
        for j in range(w):
            if not mask[i, j]:
                continue
            idx = i * w + j
            if i > 0 and mask[i - 1, j]:
                union(idx, idx - w)
            if j > 0 and mask[i, j - 1]:
                union(idx, idx - 1)
    labels = np.full((h, w), -1, dtype=np.int32)
    for i in range(h):
        for j in range(w):
            if mask[i, j]:
                labels[i, j] = find(i * w + j)
    return labels


def _ccl_scan_1d(labels: jax.Array, mask: jax.Array, axis: int, reverse: bool) -> jax.Array:
    """Min-label propagation along one axis within mask runs.

    f_j(x) = min(v_j, x if p_j else +inf); composes closed-form:
      (v', p') = (min(v2, v1 if p2 else inf), p1 & p2)
    """
    big = jnp.asarray(jnp.iinfo(jnp.int32).max, labels.dtype)

    def combine(a, b):
        v1, p1 = a
        v2, p2 = b
        v = jnp.minimum(v2, jnp.where(p2, v1, big))
        return v, jnp.logical_and(p1, p2)

    axis = axis % labels.ndim
    v, _ = jax.lax.associative_scan(combine, (labels, mask), axis=axis, reverse=reverse)
    return jnp.where(mask, jnp.minimum(labels, v), labels)


def ccl_sweep_ref(labels: jax.Array, mask: jax.Array) -> jax.Array:
    l = _ccl_scan_1d(labels, mask, axis=-2, reverse=False)
    l = _ccl_scan_1d(l, mask, axis=-2, reverse=True)
    l = _ccl_scan_1d(l, mask, axis=-1, reverse=False)
    l = _ccl_scan_1d(l, mask, axis=-1, reverse=True)
    return l


def ccl_ref(mask: jax.Array, max_iters: int = 256) -> jax.Array:
    """Min-label propagation to fixed point; canonical (min flat index)."""
    mask_b = mask != 0
    h, w = mask.shape[-2], mask.shape[-1]
    init = jnp.arange(h * w, dtype=jnp.int32).reshape(mask.shape[-2:])
    init = jnp.broadcast_to(init, mask.shape)
    big = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
    labels = jnp.where(mask_b, init, big)

    def cond(state):
        l, prev, it = state
        return jnp.logical_and(jnp.any(l != prev), it < max_iters)

    def body(state):
        l, _, it = state
        return ccl_sweep_ref(l, mask_b), l, it + 1

    l1 = ccl_sweep_ref(labels, mask_b)
    l, _, _ = jax.lax.while_loop(cond, body, (l1, labels, jnp.asarray(1)))
    return jnp.where(mask_b, l, -1)


# --------------------------------------------------------------------------
# GLCM + histogram texture features (feature computation stage)
# --------------------------------------------------------------------------
def quantize_ref(tile: jax.Array, num_bins: int) -> jax.Array:
    """float [0,1] -> int32 bins [0, num_bins)."""
    return jnp.clip((tile * num_bins).astype(jnp.int32), 0, num_bins - 1)


def glcm_ref(bins: jax.Array, num_bins: int) -> jax.Array:
    """Horizontal-neighbor co-occurrence counts: (..., NB, NB) float32.

    Computed as a one-hot matmul (the TPU adaptation: GLCM accumulation
    becomes an MXU contraction instead of scatter-adds).
    """
    left = bins[..., :, :-1]
    right = bins[..., :, 1:]
    lhot = jax.nn.one_hot(left.reshape(*bins.shape[:-2], -1), num_bins, dtype=jnp.float32)
    rhot = jax.nn.one_hot(right.reshape(*bins.shape[:-2], -1), num_bins, dtype=jnp.float32)
    return jnp.einsum("...pa,...pb->...ab", lhot, rhot)


def glcm_features_ref(glcm: jax.Array) -> jax.Array:
    """Haralick features from a GLCM: (contrast, energy, homogeneity,
    entropy, correlation) -> (..., 5)."""
    nb = glcm.shape[-1]
    p = glcm / jnp.clip(glcm.sum(axis=(-2, -1), keepdims=True), 1e-12)
    i = jnp.arange(nb, dtype=jnp.float32)[:, None]
    j = jnp.arange(nb, dtype=jnp.float32)[None, :]
    contrast = (p * (i - j) ** 2).sum(axis=(-2, -1))
    energy = (p**2).sum(axis=(-2, -1))
    homogeneity = (p / (1.0 + jnp.abs(i - j))).sum(axis=(-2, -1))
    entropy = -(p * jnp.log(jnp.clip(p, 1e-12, 1.0))).sum(axis=(-2, -1))
    mu_i = (p * i).sum(axis=(-2, -1))
    mu_j = (p * j).sum(axis=(-2, -1))
    var_i = (p * (i - mu_i[..., None, None]) ** 2).sum(axis=(-2, -1))
    var_j = (p * (j - mu_j[..., None, None]) ** 2).sum(axis=(-2, -1))
    cov = (p * (i - mu_i[..., None, None]) * (j - mu_j[..., None, None])).sum(axis=(-2, -1))
    corr = cov / jnp.clip(jnp.sqrt(var_i * var_j), 1e-12)
    return jnp.stack([contrast, energy, homogeneity, entropy, corr], axis=-1)


def histogram_ref(bins: jax.Array, num_bins: int) -> jax.Array:
    hot = jax.nn.one_hot(bins.reshape(*bins.shape[:-2], -1), num_bins, dtype=jnp.float32)
    return hot.sum(axis=-2)


def histogram_features_ref(hist: jax.Array) -> jax.Array:
    """(mean, std, skewness, kurtosis) of the quantized intensity dist."""
    nb = hist.shape[-1]
    n = jnp.clip(hist.sum(axis=-1, keepdims=True), 1e-12)
    p = hist / n
    x = jnp.arange(nb, dtype=jnp.float32)
    mean = (p * x).sum(axis=-1)
    var = (p * (x - mean[..., None]) ** 2).sum(axis=-1)
    std = jnp.sqrt(jnp.clip(var, 1e-12))
    skew = (p * ((x - mean[..., None]) / std[..., None]) ** 3).sum(axis=-1)
    kurt = (p * ((x - mean[..., None]) / std[..., None]) ** 4).sum(axis=-1)
    return jnp.stack([mean, std, skew, kurt], axis=-1)


# --------------------------------------------------------------------------
# Attention (LM workloads; beyond-paper hot spot)
# --------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference softmax attention with GQA + causal + sliding window.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); returns (B, Hq, Tq, D).
    ``q_offset`` positions queries at absolute index q_offset + arange(Tq)
    (decode: Tq=1, q_offset=cache_len-1).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    logits *= scale
    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((tq, k.shape[2]), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vr.astype(jnp.float32)).astype(q.dtype)


def attention_chunked_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanned over key chunks (flash structure,
    pure XLA).  Never materializes the (Tq, Tk) score matrix — the
    lowerable stand-in for the Pallas flash kernel, used to drive the
    memory roofline term down on train/prefill cells.

    GQA is handled by a grouped einsum (no repeated K/V in memory).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    chunk = min(chunk, tk)
    n_chunks = -(-tk // chunk)
    pad = n_chunks * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, hkv, g, tq, d).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, hkv, n_chunks, chunk, d), 2, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(b, hkv, n_chunks, chunk, d), 2, 0).astype(jnp.float32)
    qpos = q_offset + jnp.arange(tq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kb) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < tk
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window is not None:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgqc,bkcd->bkgqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.where(l > 0, l, 1.0)[..., None]
    return out.reshape(b, hq, tq, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD scan (beyond-paper hot spot for the SSM archs)
# --------------------------------------------------------------------------
def ssd_scan_ref(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)        softplus-ed step sizes
    a: jax.Array,  # (H,)              negative decay rates (A = -exp(a_log))
    b_: jax.Array,  # (B, T, G, N)
    c_: jax.Array,  # (B, T, G, N)
    d_: jax.Array | None = None,  # (H,) skip
    h0: jax.Array | None = None,  # (B, H, N, P) initial state
) -> tuple[jax.Array, jax.Array]:
    """Sequential state-space-duality scan: the oracle for ssd_scan.

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t^T h_t (+ D x).
    Returns (y: (B,T,H,P), h_final: (B,H,N,P)).
    """
    bsz, t, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    rep = h // g
    bh = jnp.repeat(b_, rep, axis=2)  # (B, T, H, N)
    ch = jnp.repeat(c_, rep, axis=2)
    decay = jnp.exp(dt * a[None, None, :])  # (B, T, H)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), dtype=jnp.float32)

    def step(hprev, inputs):
        xt, dtt, dect, bt, ct = inputs  # (B,H,P) (B,H) (B,H) (B,H,N) (B,H,N)
        hnew = (
            dect[..., None, None] * hprev
            + (dtt[..., None] * bt)[..., :, None] * xt[..., None, :]
        )
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hnew)
        return hnew, yt

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(decay.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(ch.astype(jnp.float32), 1, 0),
    )
    hf, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1)
    if d_ is not None:
        y = y + d_[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), hf


def ssd_scan_chunked_ref(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)
    a: jax.Array,  # (H,)
    b_: jax.Array,  # (B, T, G, N)
    c_: jax.Array,  # (B, T, G, N)
    d_: jax.Array | None = None,
    *,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD in pure XLA (the Pallas kernel's algorithm, lowerable).

    Scans over T/chunk blocks instead of T steps: within a chunk the work
    is dense matmuls (segment-decay masked C B^T), and only the (N, P)
    state crosses chunk boundaries — the recurrent-state HBM traffic drops
    by ~chunk x versus the step-by-step scan.  Used for training/prefill
    lowering (the step scan remains the numerical oracle).
    """
    bsz, t, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    rep = h // g
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    f32 = jnp.float32
    xh = jnp.moveaxis(x.astype(f32), 1, 2).reshape(bsz, h, nc, chunk, p)
    dth = jnp.moveaxis(dt.astype(f32), 1, 2).reshape(bsz, h, nc, chunk)
    bh = jnp.repeat(b_.astype(f32), rep, axis=2)
    ch = jnp.repeat(c_.astype(f32), rep, axis=2)
    bh = jnp.moveaxis(bh, 1, 2).reshape(bsz, h, nc, chunk, n)
    ch = jnp.moveaxis(ch, 1, 2).reshape(bsz, h, nc, chunk, n)
    la = dth * a[None, :, None, None]  # (B, H, nc, L) log decay
    cum = jnp.cumsum(la, axis=-1)
    total = cum[..., -1]
    li = jnp.arange(chunk)
    seg = jnp.where(
        li[:, None] >= li[None, :],
        jnp.exp(cum[..., :, None] - cum[..., None, :]),
        0.0,
    )  # (B, H, nc, L, L)
    gmat = (
        jnp.einsum("bhcln,bhcmn->bhclm", ch, bh) * seg * dth[..., None, :]
    )
    y_intra = jnp.einsum("bhclm,bhcmp->bhclp", gmat, xh)
    # inter-chunk state recurrence (scan over nc chunks)
    w = jnp.exp(total[..., None] - cum) * dth  # (B,H,nc,L)
    state_in = jnp.einsum("bhcln,bhclp->bhcnp", bh * w[..., None], xh)

    def carry_fn(hprev, xs):
        tot, s_in = xs  # (B,H), (B,H,N,P)
        hnew = jnp.exp(tot)[..., None, None] * hprev + s_in
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, p), f32)
    hf, hprevs = jax.lax.scan(
        carry_fn,
        h0,
        (jnp.moveaxis(total, 2, 0), jnp.moveaxis(state_in, 2, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 2)  # (B,H,nc,N,P) state entering chunk
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bhcln,bhcnp->bhclp", ch, hprevs)
    y = (y_intra + y_inter).reshape(bsz, h, t, p)
    y = jnp.moveaxis(y, 1, 2)
    if d_ is not None:
        y = y + d_[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), hf
