"""Pallas kernel: Mamba2 SSD (state-space duality) chunked scan.

The sequential recurrence h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T is
restructured into the SSD chunked form (Dao & Gu 2024): within a chunk of
length L everything is dense matmuls (MXU work), and only a (N, P) state
crosses chunk boundaries:

  intra:  Y = ((C B^T) . SegDecay) @ (X)            -- (L,L)@(L,P)
  inter:  Y += exp(cum) * (C @ h_prev)              -- (L,N)@(N,P)
  carry:  h = exp(total) h_prev + (B * w)^T @ X     -- (N,L)@(L,P)

Grid: one program per (batch*head); the chunk loop runs inside the kernel
with the (N, P) state carried in registers/VMEM.  B/C are group-shared
(G groups, H heads): the index map derefs head -> group, no materialized
repeat.  P and N should be multiples of 128 for MXU alignment on real
hardware; tests sweep small shapes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    x_ref,  # (1, T, P)
    dt_ref,  # (1, T)
    a_ref,  # (1,)
    b_ref,  # (1, T, N)
    c_ref,  # (1, T, N)
    d_ref,  # (1,)
    y_ref,  # (1, T, P)
    hout_ref,  # (1, N, P)
    *,
    chunk: int,
    num_chunks: int,
    seq_len: int,
):
    a = a_ref[0]
    d_skip = d_ref[0]
    p = x_ref.shape[-1]
    n = b_ref.shape[-1]

    def body(ci, h):
        sl = pl.dslice(ci * chunk, chunk)
        x = x_ref[0, sl, :].astype(jnp.float32)  # (L, P)
        dt = dt_ref[0, sl].astype(jnp.float32)  # (L,)
        bmat = b_ref[0, sl, :].astype(jnp.float32)  # (L, N)
        cmat = c_ref[0, sl, :].astype(jnp.float32)  # (L, N)
        la = dt * a  # (L,) log-decay per step (<= 0)
        cum = jnp.cumsum(la)  # inclusive
        total = cum[-1]
        # segment decay matrix: exp(cum_i - cum_j) for i >= j else 0
        seg = jnp.exp(cum[:, None] - cum[None, :])
        li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        seg = jnp.where(li >= lj, seg, 0.0)
        g = (
            jax.lax.dot_general(
                cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * seg
            * dt[None, :]
        )  # (L, L)
        y_intra = jax.lax.dot_general(
            g, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
            cmat, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        y = y_intra + y_inter + d_skip * x
        y_ref[0, sl, :] = y.astype(y_ref.dtype)
        # state carry: h' = exp(total) h + sum_j exp(total - cum_j) dt_j B_j x_j^T
        w = jnp.exp(total - cum) * dt  # (L,)
        h_new = jnp.exp(total) * h + jax.lax.dot_general(
            bmat * w[:, None], x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return h_new

    h0 = jnp.zeros((n, p), jnp.float32)
    hf = jax.lax.fori_loop(0, num_chunks, body, h0)
    hout_ref[0] = hf.astype(hout_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)
    a: jax.Array,  # (H,)
    b_: jax.Array,  # (B, T, G, N)
    c_: jax.Array,  # (B, T, G, N)
    d_: jax.Array | None = None,  # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, t, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    rep = h // g
    chunk = min(chunk, t)
    assert t % chunk == 0, f"seq {t} must be a multiple of chunk {chunk}"
    nchunks = t // chunk
    if d_ is None:
        d_ = jnp.zeros((h,), jnp.float32)

    xf = jnp.moveaxis(x, 2, 1).reshape(bsz * h, t, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(bsz * h, t)
    bf = jnp.moveaxis(b_, 2, 1).reshape(bsz * g, t, n)
    cf = jnp.moveaxis(c_, 2, 1).reshape(bsz * g, t, n)

    def bc_index(bh):
        return (bh // h) * g + (bh % h) // rep, 0, 0

    y, hf = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, num_chunks=nchunks, seq_len=t),
        out_shape=(
            jax.ShapeDtypeStruct((bsz * h, t, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, n, p), jnp.float32),
        ),
        grid=(bsz * h,),
        in_specs=[
            pl.BlockSpec((1, t, p), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, t), lambda bh: (bh, 0)),
            pl.BlockSpec((1,), lambda bh: (bh % h,)),
            pl.BlockSpec((1, t, n), bc_index),
            pl.BlockSpec((1, t, n), bc_index),
            pl.BlockSpec((1,), lambda bh: (bh % h,)),
        ],
        out_specs=(
            pl.BlockSpec((1, t, p), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, n, p), lambda bh: (bh, 0, 0)),
        ),
        interpret=interpret,
    )(xf, dtf, a.astype(jnp.float32), bf, cf, d_.astype(jnp.float32))
    y = jnp.moveaxis(y.reshape(bsz, h, t, p), 1, 2)
    hf = hf.reshape(bsz, h, n, p)
    return y, hf
