"""Jit'd public wrappers for every kernel, with implementation dispatch.

``impl`` selects:
  * ``"pallas"``   — the Pallas kernel (compiled on TPU, interpret=True
                     elsewhere so CPU runs execute the same kernel body);
  * ``"xla"``      — the pure-jnp reference (used for dry-run lowering and
                     as the oracle);
  * ``"auto"``     — pallas on TPU, xla elsewhere (the production default:
                     CPU hosts shouldn't pay interpret-mode overhead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ccl import ccl_pallas
from repro.kernels.color_deconv import color_deconv_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.glcm import glcm_pallas
from repro.kernels.morph_recon import morph_recon_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def _interpret() -> bool:
    return not _on_tpu()


# -- color deconvolution ------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl",))
def color_deconv(rgb: jax.Array, minv: jax.Array, impl: str = "auto") -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return color_deconv_pallas(rgb, minv, interpret=_interpret())
    return ref.color_deconv_ref(rgb, minv)


# -- morphological reconstruction ----------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl", "max_iters"))
def morph_recon(
    marker: jax.Array, mask: jax.Array, impl: str = "auto", max_iters: int = 128
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return morph_recon_pallas(marker, mask, max_iters=max_iters, interpret=_interpret())
    return ref.morph_recon_ref(marker, mask, max_iters=max_iters)


@functools.partial(jax.jit, static_argnames=("impl",))
def fill_holes(mask01: jax.Array, impl: str = "auto") -> jax.Array:
    # holes-filling reconstruction is driven from the border; ref covers both
    return ref.fill_holes_ref(mask01)


# -- connected components ----------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl", "max_iters"))
def connected_components(
    mask: jax.Array, impl: str = "auto", max_iters: int = 128
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return ccl_pallas(mask, max_iters=max_iters, interpret=_interpret())
    return ref.ccl_ref(mask, max_iters=max_iters)


# -- GLCM / histogram features -------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_bins", "impl"))
def glcm_histogram(
    bins: jax.Array, num_bins: int, impl: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "pallas":
        return glcm_pallas(bins, num_bins, interpret=_interpret())
    return ref.glcm_ref(bins, num_bins), ref.histogram_ref(bins, num_bins)


@functools.partial(jax.jit, static_argnames=("num_bins", "impl"))
def texture_features(bins: jax.Array, num_bins: int, impl: str = "auto") -> jax.Array:
    """(B, H, W) int bins -> (B, 9) [5 GLCM + 4 histogram] features."""
    g, h = glcm_histogram(bins, num_bins, impl=impl)
    return jnp.concatenate(
        [ref.glcm_features_ref(g), ref.histogram_features_ref(h)], axis=-1
    )


# -- attention -----------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("causal", "window", "impl", "q_offset", "block_q", "block_k")
)
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return flash_attention_pallas(
            q,
            k,
            v,
            causal=causal,
            window=window,
            q_offset=q_offset,
            block_q=block_q,
            block_k=block_k,
            interpret=_interpret(),
        )
    if impl == "chunked":
        return ref.attention_chunked_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset, chunk=block_k * 4
        )
    return ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)


# -- mamba2 SSD ---------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_: jax.Array,
    c_: jax.Array,
    d_: jax.Array | None = None,
    *,
    impl: str = "auto",
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "pallas":
        return ssd_scan_pallas(x, dt, a, b_, c_, d_, chunk=chunk, interpret=_interpret())
    if impl == "chunked":
        return ref.ssd_scan_chunked_ref(x, dt, a, b_, c_, d_, chunk=chunk)
    return ref.ssd_scan_ref(x, dt, a, b_, c_, d_)
