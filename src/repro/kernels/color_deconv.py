"""Pallas kernel: color deconvolution (stain unmixing).

Layout is channels-first planar (3, H, W) so the W axis rides the 128-lane
dimension and H blocks ride sublanes — the (8, 128)-friendly layout for
the VPU.  The 3x3 stain inverse is tiny; it lives in SMEM-like replicated
VMEM and the per-pixel work is a fused -log10 + 3-term FMA.

Block shape: full channel dim (3) x (block_h, block_w) spatial tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rgb_ref, minv_ref, out_ref, *, eps: float):
    rgb = rgb_ref[...]  # (3, bh, bw)
    minv = minv_ref[...]  # (3, 3)
    od = -jnp.log10(jnp.clip(rgb, eps, 1.0))
    # out[s] = sum_c minv[c, s] * od[c]   (3 fused FMAs per output channel)
    for s in range(3):
        out_ref[s, :, :] = (
            minv[0, s] * od[0] + minv[1, s] * od[1] + minv[2, s] * od[2]
        )


def color_deconv_pallas(
    rgb: jax.Array,
    minv: jax.Array,
    *,
    eps: float = 1e-6,
    block_h: int = 128,
    block_w: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(3, H, W) float32 in [0,1] -> (3, H, W) stain densities."""
    c, h, w = rgb.shape
    assert c == 3, rgb.shape
    bh, bw = min(block_h, h), min(block_w, w)
    grid = (pl.cdiv(h, bh), pl.cdiv(w, bw))
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((3, h, w), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, bh, bw), lambda i, j: (0, i, j)),
            pl.BlockSpec((3, 3), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, bh, bw), lambda i, j: (0, i, j)),
        interpret=interpret,
    )(rgb.astype(jnp.float32), minv.astype(jnp.float32))
