"""Named kernel chains: the unit of near-data compute (paper §3, §5.1).

The paper decomposes coarse-grain analysis stages into fine-grain
operations that run next to their data; a *kernel chain* is the wire
name for such a decomposition — a ``|``-separated sequence of registered
stages, e.g. ``"deconv|threshold|ccl"``, that a client ships to the
region gateway instead of pulling raw tiles and computing locally.

Every stage dispatches through :mod:`repro.kernels.ops`, so the same
chain runs the Pallas kernels on TPU and the jnp references elsewhere
(``impl="auto"``); chains therefore inherit the per-kernel ref/Pallas
bit-closeness that ``tests/test_kernels.py`` establishes.

Registry contract:

* a stage declares its parameter schema (name, type, default, check) and
  its input/output ranks; :func:`resolve_chain` validates the whole
  request *before* any data moves — unknown stages raise
  :class:`UnknownChainError`, bad/unknown/ill-typed params and rank
  mismatches raise :class:`ChainParamError` — so a gateway fails fast at
  submit time, never inside a worker;
* device stages compose into one jitted function (fed whole windows
  through ``runtime/prefetch.DevicePipeline``); host stages (terminal
  reductions like ``count``) run on the downloaded result;
* :meth:`Chain.digest` is a stable content hash of the canonical chain
  string plus its fully-defaulted params — the derived-product cache key
  component, so ``"deconv|threshold"`` with ``thr=0.5`` and the same
  chain with ``{"thr": 0.5}`` spelled explicitly share cache entries.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


class ChainError(Exception):
    """Base for chain resolution failures (always raised at submit time)."""


class UnknownChainError(ChainError):
    """The chain names a stage that is not registered."""


class ChainParamError(ChainError):
    """Bad parameter (unknown name, wrong type, failed check) or an
    input whose rank no stage composition can accept."""


@dataclasses.dataclass(frozen=True)
class Param:
    """One stage parameter: declared type, default, optional validator."""

    type: type
    default: Any
    check: Callable[[Any], bool] | None = None
    doc: str = ""

    def coerce(self, stage: str, name: str, value: Any) -> Any:
        if self.type is float and isinstance(value, int) and not isinstance(value, bool):
            value = float(value)
        if not isinstance(value, self.type) or (
            self.type is int and isinstance(value, bool)
        ):
            raise ChainParamError(
                f"stage {stage!r} param {name!r} wants {self.type.__name__}, "
                f"got {type(value).__name__} ({value!r})"
            )
        if self.check is not None and not self.check(value):
            raise ChainParamError(
                f"stage {stage!r} param {name!r} rejected value {value!r} ({self.doc})"
            )
        return value


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One registered stage.

    ``fn(x, params, impl)`` — device stages take/return jax arrays, host
    stages take/return numpy (they run after the pipeline download).
    ``out_rank(in_rank, params)`` lets rank depend on params (``deconv``
    with ``stain=-1`` keeps all 3 stain planes).
    """

    name: str
    fn: Callable[[Any, dict, str], Any]
    in_ranks: tuple[int, ...]
    out_rank: Callable[[int, dict], int]
    params: Mapping[str, Param] = dataclasses.field(default_factory=dict)
    host: bool = False
    reduces: bool = False  # output is a small feature vector, not an image
    doc: str = ""


_STAGES: dict[str, StageSpec] = {}


def register_stage(spec: StageSpec) -> StageSpec:
    if spec.name in _STAGES:
        raise ValueError(f"stage {spec.name!r} already registered")
    if "|" in spec.name or not spec.name:
        raise ValueError(f"bad stage name {spec.name!r}")
    _STAGES[spec.name] = spec
    return spec


def list_stages() -> dict[str, StageSpec]:
    return dict(_STAGES)


# ---------------------------------------------------------------------------
# Built-in stages (the paper's segmentation + feature operators)
# ---------------------------------------------------------------------------
_MINV = ref.stain_inverse()  # Ruifrok-Johnston H&E+DAB unmixing matrix


def _deconv(x, params, impl):
    stains = ops.color_deconv(x.astype(jnp.float32), jnp.asarray(_MINV), impl=impl)
    stain = params["stain"]
    return stains if stain < 0 else stains[stain]


def _threshold(x, params, impl):
    x = x.astype(jnp.float32)
    if params["norm"]:
        lo = jnp.percentile(x, 5.0)
        hi = jnp.percentile(x, 99.5)
        x = jnp.clip((x - lo) / jnp.maximum(hi - lo, 1e-6), 0.0, 1.0)
    # uint8 on purpose: a binary mask is the derived product, and the
    # egress win (vs float32 raw tiles) is the whole point of the chain
    return (x > params["thr"]).astype(jnp.uint8)


def _fill(x, params, impl):
    return (ops.fill_holes(x.astype(jnp.float32), impl=impl) > 0.5).astype(jnp.uint8)


def _ccl(x, params, impl):
    return ops.connected_components((x != 0).astype(jnp.int32), impl=impl)


def _count(x, params, impl):
    labels = np.asarray(x)
    return np.array([np.unique(labels[labels >= 0]).size], dtype=np.int32)


def _glcm(x, params, impl):
    nb = params["num_bins"]
    bins = ref.quantize_ref(x.astype(jnp.float32), nb)
    return ops.texture_features(bins[None], nb, impl=impl)[0]


register_stage(StageSpec(
    "deconv",
    _deconv,
    in_ranks=(3,),
    out_rank=lambda r, p: 3 if p["stain"] < 0 else 2,
    params={
        "stain": Param(int, 0, lambda v: -1 <= v <= 2,
                       "-1=all planes, 0=hematoxylin, 1=eosin, 2=DAB"),
    },
    doc="(3,H,W) RGB in [0,1] -> stain optical densities",
))
register_stage(StageSpec(
    "threshold",
    _threshold,
    in_ranks=(2,),
    out_rank=lambda r, p: 2,
    params={
        "thr": Param(float, 0.5, lambda v: 0.0 < v < 1.0, "in (0,1)"),
        "norm": Param(bool, True, None, "percentile-normalize (5/99.5) first"),
    },
    doc="(H,W) intensity -> (H,W) uint8 binary mask",
))
register_stage(StageSpec(
    "fill",
    _fill,
    in_ranks=(2,),
    out_rank=lambda r, p: 2,
    doc="(H,W) binary mask -> holes filled (border-seeded reconstruction)",
))
register_stage(StageSpec(
    "ccl",
    _ccl,
    in_ranks=(2,),
    out_rank=lambda r, p: 2,
    doc="(H,W) mask -> int32 canonical labels (min flat index; bg=-1)",
))
register_stage(StageSpec(
    "count",
    _count,
    in_ranks=(2,),
    out_rank=lambda r, p: 1,
    host=True,
    reduces=True,
    doc="(H,W) labels -> [n_components] (host reduction)",
))
register_stage(StageSpec(
    "glcm",
    _glcm,
    in_ranks=(2,),
    out_rank=lambda r, p: 1,
    reduces=True,
    params={
        "num_bins": Param(int, 32, lambda v: 2 <= v <= 256, "in [2,256]"),
    },
    doc="(H,W) intensity in [0,1] -> (9,) GLCM+histogram features",
))

# Canonical chains exercised by tests and benchmarks (any |-composition
# of registered stages that type-checks is equally valid on the wire).
STANDARD_CHAINS: tuple[str, ...] = (
    "deconv",
    "deconv|threshold",
    "deconv|threshold|fill",
    "deconv|threshold|ccl",
    "deconv|threshold|ccl|count",
    "threshold|ccl",
    "glcm",
)


# ---------------------------------------------------------------------------
# Chain resolution
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Chain:
    """A validated stage composition with fully-defaulted params."""

    name: str                     # canonical "a|b|c"
    stages: tuple[StageSpec, ...]
    params: tuple[tuple[str, Any], ...]  # sorted, defaults filled
    in_ranks: tuple[int, ...]     # acceptable input ranks
    out_rank: int                 # given the smallest acceptable input
    reduces: bool                 # ends in a feature-vector reduction

    @property
    def params_dict(self) -> dict:
        return dict(self.params)

    def digest(self) -> str:
        """Stable content hash: the derived-cache key component."""
        blob = f"{self.name}::{self.params!r}".encode()
        return hashlib.sha1(blob).hexdigest()[:16]

    def check_input_rank(self, rank: int) -> None:
        if rank not in self.in_ranks:
            raise ChainParamError(
                f"chain {self.name!r} takes rank-{'/'.join(map(str, self.in_ranks))} "
                f"input, got rank-{rank}"
            )

    def device_fn(self, impl: str = "auto") -> Callable[[jax.Array], jax.Array]:
        """The composed device stages as one jitted function."""
        return _jitted_device_fn(self.name, self.params, impl)

    def host_fn(self) -> Callable[[np.ndarray], np.ndarray] | None:
        """The terminal host stages (None when the chain is all-device)."""
        host = [s for s in self.stages if s.host]
        if not host:
            return None
        params = self.params_dict

        def run(x: np.ndarray) -> np.ndarray:
            for s in host:
                x = s.fn(x, params, "xla")
            return x

        return run

    def __call__(self, x, impl: str = "auto") -> np.ndarray:
        """Full local execution (device stages + host reductions) -> numpy.

        This is the reference a gateway ``compute()`` must match
        bit-for-bit on identical input slices.
        """
        arr = np.asarray(x)
        self.check_input_rank(arr.ndim)
        out = np.asarray(self.device_fn(impl)(jnp.asarray(arr)))
        hfn = self.host_fn()
        return hfn(out) if hfn is not None else out


@functools.lru_cache(maxsize=128)
def _jitted_device_fn(name: str, params: tuple, impl: str):
    stages = [_STAGES[s] for s in name.split("|") if not _STAGES[s].host]
    pdict = dict(params)

    def run(x):
        for s in stages:
            x = s.fn(x, pdict, impl)
        return x

    return jax.jit(run)


def resolve_chain(chain: str, params: Mapping[str, Any] | None = None) -> Chain:
    """Parse + validate ``"a|b|c"`` against the registry; fail fast.

    Raises :class:`UnknownChainError` for unregistered stage names and
    :class:`ChainParamError` for unknown/ill-typed/out-of-range params or
    stage compositions whose ranks cannot connect.
    """
    if not isinstance(chain, str) or not chain.strip():
        raise UnknownChainError(f"empty chain {chain!r}")
    names = [s.strip() for s in chain.split("|")]
    specs = []
    for n in names:
        if n not in _STAGES:
            raise UnknownChainError(
                f"unknown stage {n!r} in chain {chain!r} "
                f"(registered: {', '.join(sorted(_STAGES))})"
            )
        specs.append(_STAGES[n])
    # host stages are terminal reductions: nothing device-side may follow
    seen_host = False
    for s in specs:
        if seen_host and not s.host:
            raise ChainParamError(
                f"chain {chain!r}: device stage {s.name!r} cannot follow a "
                f"host reduction stage"
            )
        seen_host = seen_host or s.host
    # validate params: every key must belong to some stage in the chain
    params = dict(params or {})
    known: dict[str, tuple[StageSpec, Param]] = {}
    for s in specs:
        for pname, p in s.params.items():
            known.setdefault(pname, (s, p))
    unknown = set(params) - set(known)
    if unknown:
        raise ChainParamError(
            f"chain {chain!r}: unknown param(s) {sorted(unknown)} "
            f"(accepted: {sorted(known) or 'none'})"
        )
    resolved: dict[str, Any] = {}
    for pname, (s, p) in known.items():
        if pname in params:
            resolved[pname] = p.coerce(s.name, pname, params[pname])
        else:
            resolved[pname] = p.default
    # rank-connect the composition for every acceptable input rank
    in_ranks = []
    out_rank = None
    for r0 in specs[0].in_ranks:
        r = r0
        ok = True
        for s in specs:
            if r not in s.in_ranks:
                ok = False
                break
            r = s.out_rank(r, resolved)
        if ok:
            in_ranks.append(r0)
            out_rank = r if out_rank is None else out_rank
    if not in_ranks:
        raise ChainParamError(
            f"chain {chain!r}: no input rank connects the stage composition "
            f"(e.g. {specs[0].name!r} outputs rank the next stage rejects)"
        )
    return Chain(
        name="|".join(names),
        stages=tuple(specs),
        params=tuple(sorted(resolved.items())),
        in_ranks=tuple(in_ranks),
        out_rank=out_rank,
        reduces=specs[-1].reduces,
    )
