"""Pallas kernel: grayscale morphological reconstruction by dilation.

TPU adaptation of the paper's IWPP (irregular wavefront propagation, [65]):
GPU wavefronts use per-thread work queues — no TPU analogue.  We observe
that the 1-D reconstruction recurrence

    m_j = min(mask_j, max(marker_j, m_{j-1}))

is a composition of clamp functions f(x) = min(c, max(d, x)) which compose
in closed form, so each directional sweep is a *log-depth associative
scan* along sublanes/lanes — fully regular, VPU-friendly.  One kernel call
performs ``n_sweeps`` 4-direction sweeps over its VMEM tile; the ops
wrapper iterates kernel calls to the global fixed point (block-synchronous
relaxation).  Connectivity: 4-neighbor, matching ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine(a, b):
    c1, d1 = a
    c2, d2 = b
    return jnp.minimum(c2, jnp.maximum(d2, c1)), jnp.maximum(d1, d2)


def _scan_dir(j, mask, axis, reverse):
    c, d = jax.lax.associative_scan(_combine, (mask, j), axis=axis, reverse=reverse)
    return jnp.minimum(c, d)


def _kernel(marker_ref, mask_ref, out_ref, *, n_sweeps: int):
    mask = mask_ref[...]
    j = jnp.minimum(marker_ref[...], mask)

    def sweep(_, j):
        j = _scan_dir(j, mask, axis=0, reverse=False)
        j = _scan_dir(j, mask, axis=0, reverse=True)
        j = _scan_dir(j, mask, axis=1, reverse=False)
        j = _scan_dir(j, mask, axis=1, reverse=True)
        return j

    out_ref[...] = jax.lax.fori_loop(0, n_sweeps, sweep, j)


def morph_recon_sweep_pallas(
    marker: jax.Array,
    mask: jax.Array,
    *,
    n_sweeps: int = 2,
    block_h: int = 256,
    block_w: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """One block-relaxation step: n_sweeps 4-dir sweeps per VMEM tile.

    Tiles are processed independently (no halo): the caller's outer
    fixed-point loop propagates information across tile boundaries, since
    every call re-reads the neighbors' updated values.  For a (H, W) image
    the grid is over spatial tiles.
    """
    h, w = marker.shape
    bh, bw = min(block_h, h), min(block_w, w)
    # pad to block multiples (OOB grid padding is undefined in pallas)
    hp, wp = pl.cdiv(h, bh) * bh, pl.cdiv(w, bw) * bw
    marker_p = jnp.pad(marker.astype(jnp.float32), ((0, hp - h), (0, wp - w)))
    mask_p = jnp.pad(mask.astype(jnp.float32), ((0, hp - h), (0, wp - w)))
    grid = (hp // bh, wp // bw)
    out = pl.pallas_call(
        functools.partial(_kernel, n_sweeps=n_sweeps),
        out_shape=jax.ShapeDtypeStruct((hp, wp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        interpret=interpret,
    )(marker_p, mask_p)
    return out[:h, :w]


def morph_recon_pallas(
    marker: jax.Array,
    mask: jax.Array,
    *,
    max_iters: int = 64,
    n_sweeps: int = 2,
    block_h: int = 256,
    block_w: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fixed-point iteration of tile sweeps + cross-tile halo exchange.

    Between kernel calls, a 1-pixel neighborhood max is exchanged across
    the whole array (cheap XLA shifts) so wavefronts cross tile borders;
    the kernel then relaxes interiors at VMEM speed.
    """
    mask_f = mask.astype(jnp.float32)
    j0 = jnp.minimum(marker.astype(jnp.float32), mask_f)
    sweep = functools.partial(
        morph_recon_sweep_pallas,
        n_sweeps=n_sweeps,
        block_h=block_h,
        block_w=block_w,
        interpret=interpret,
    )

    def halo(j):
        # cross-border propagation: 4-neighbor dilation clamped by mask
        up = jnp.pad(j[1:, :], ((0, 1), (0, 0)), constant_values=-jnp.inf)
        dn = jnp.pad(j[:-1, :], ((1, 0), (0, 0)), constant_values=-jnp.inf)
        lf = jnp.pad(j[:, 1:], ((0, 0), (0, 1)), constant_values=-jnp.inf)
        rt = jnp.pad(j[:, :-1], ((0, 0), (1, 0)), constant_values=-jnp.inf)
        neigh = jnp.maximum(jnp.maximum(up, dn), jnp.maximum(lf, rt))
        return jnp.minimum(mask_f, jnp.maximum(j, neigh))

    def cond(state):
        j, prev, it = state
        return jnp.logical_and(jnp.any(j != prev), it < max_iters)

    def body(state):
        j, _, it = state
        return sweep(halo(j), mask_f), j, it + 1

    j1 = sweep(j0, mask_f)
    j, _, _ = jax.lax.while_loop(cond, body, (j1, j0, jnp.asarray(1)))
    return j
