"""Pallas TPU kernels for the pipeline's compute hot spots.

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), validated against
``ref.py`` oracles; ``ops.py`` holds the jit'd dispatching wrappers.
"""
from repro.kernels import chains, ops, ref

__all__ = ["chains", "ops", "ref"]
