"""Pallas kernel: connected component labeling by min-label propagation.

TPU adaptation of the paper's union-find BWLabel ([50]): pointer-chasing
union-find is hostile to the VPU, so the device path instead iterates
min-label propagation within mask runs.  The 1-D recurrence

    m_j = min(v_j, m_{j-1} if pass_j else +inf)

composes closed-form ((v', p') = (min(v2, v1 if p2 else inf), p1 & p2)),
giving log-depth associative scans per direction.  The fixed point labels
every component by its minimum flat index — identical canonical labels to
union-find, verified in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = jnp.iinfo(jnp.int32).max


def _combine(a, b):
    v1, p1 = a
    v2, p2 = b
    v = jnp.minimum(v2, jnp.where(p2, v1, _BIG))
    return v, jnp.logical_and(p1, p2)


def _scan_dir(labels, mask, axis, reverse):
    v, _ = jax.lax.associative_scan(_combine, (labels, mask), axis=axis, reverse=reverse)
    return jnp.where(mask, jnp.minimum(labels, v), labels)


def _kernel(labels_ref, mask_ref, out_ref, *, n_sweeps: int):
    mask = mask_ref[...] != 0
    labels = labels_ref[...]

    def sweep(_, l):
        l = _scan_dir(l, mask, axis=0, reverse=False)
        l = _scan_dir(l, mask, axis=0, reverse=True)
        l = _scan_dir(l, mask, axis=1, reverse=False)
        l = _scan_dir(l, mask, axis=1, reverse=True)
        return l

    out_ref[...] = jax.lax.fori_loop(0, n_sweeps, sweep, labels)


def ccl_sweep_pallas(
    labels: jax.Array,
    mask: jax.Array,
    *,
    n_sweeps: int = 2,
    block_h: int = 256,
    block_w: int = 256,
    interpret: bool = False,
) -> jax.Array:
    h, w = labels.shape
    bh, bw = min(block_h, h), min(block_w, w)
    # pad to block multiples: OOB grid padding is undefined, and garbage
    # mask bits would leak labels across runs
    hp, wp = pl.cdiv(h, bh) * bh, pl.cdiv(w, bw) * bw
    labels_p = jnp.pad(labels, ((0, hp - h), (0, wp - w)), constant_values=_BIG)
    mask_p = jnp.pad(mask.astype(jnp.int32), ((0, hp - h), (0, wp - w)))
    grid = (hp // bh, wp // bw)
    out = pl.pallas_call(
        functools.partial(_kernel, n_sweeps=n_sweeps),
        out_shape=jax.ShapeDtypeStruct((hp, wp), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        interpret=interpret,
    )(labels_p, mask_p)
    return out[:h, :w]


def ccl_pallas(
    mask: jax.Array,
    *,
    max_iters: int = 64,
    n_sweeps: int = 2,
    block_h: int = 256,
    block_w: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Labels: min flat index per 4-connected component; background -1."""
    mask_b = mask != 0
    h, w = mask.shape
    init = jnp.arange(h * w, dtype=jnp.int32).reshape(h, w)
    labels = jnp.where(mask_b, init, _BIG)
    sweep = functools.partial(
        ccl_sweep_pallas,
        n_sweeps=n_sweeps,
        block_h=block_h,
        block_w=block_w,
        interpret=interpret,
    )
    mask_i = mask_b.astype(jnp.int32)

    def halo(l):
        big = jnp.asarray(_BIG, jnp.int32)
        up = jnp.pad(l[1:, :], ((0, 1), (0, 0)), constant_values=big)
        dn = jnp.pad(l[:-1, :], ((1, 0), (0, 0)), constant_values=big)
        lf = jnp.pad(l[:, 1:], ((0, 0), (0, 1)), constant_values=big)
        rt = jnp.pad(l[:, :-1], ((0, 0), (1, 0)), constant_values=big)
        # neighbor labels only propagate into masked pixels from masked pixels
        mup = jnp.pad(mask_b[1:, :], ((0, 1), (0, 0)), constant_values=False)
        mdn = jnp.pad(mask_b[:-1, :], ((1, 0), (0, 0)), constant_values=False)
        mlf = jnp.pad(mask_b[:, 1:], ((0, 0), (0, 1)), constant_values=False)
        mrt = jnp.pad(mask_b[:, :-1], ((0, 0), (1, 0)), constant_values=False)
        neigh = jnp.minimum(
            jnp.minimum(jnp.where(mup, up, big), jnp.where(mdn, dn, big)),
            jnp.minimum(jnp.where(mlf, lf, big), jnp.where(mrt, rt, big)),
        )
        return jnp.where(mask_b, jnp.minimum(l, neigh), l)

    def cond(state):
        l, prev, it = state
        return jnp.logical_and(jnp.any(l != prev), it < max_iters)

    def body(state):
        l, _, it = state
        return sweep(halo(l), mask_i), l, it + 1

    l1 = sweep(labels, mask_i)
    l, _, _ = jax.lax.while_loop(cond, body, (l1, labels, jnp.asarray(1)))
    return jnp.where(mask_b, l, -1)
