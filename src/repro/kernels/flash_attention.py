"""Pallas kernel: FlashAttention (online-softmax tiled attention).

The canonical TPU structure: grid (batch*heads, q_blocks, k_blocks) with
the k dimension innermost/sequential; the output block index is
independent of the k index so the (bq, D) accumulator stays resident in
VMEM across k steps, carried with running-max/denominator scratch.
Supports GQA (kv-head deref through the index map — no materialized
repeat), causal masking, sliding windows, and a query offset for decode.

MXU alignment: choose block_q/block_k multiples of 128 and head_dim a
multiple of 128 in production; tests sweep small off-aligned shapes in
interpret mode to pin numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    q_offset: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0].astype(jnp.float32)  # (bk, D)

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len  # block padding of ragged Tk
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window

    def _compute():
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale + jnp.where(mask, 0.0, _NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    # skip k blocks that are fully masked (block-level causal/window pruning)
    if causal or window is not None:
        q_max = q_offset + qi * block_q + block_q - 1
        k_min = ki * block_k
        live = k_min <= q_max
        if window is not None:
            q_min = q_offset + qi * block_q
            k_max = ki * block_k + block_k - 1
            live = jnp.logical_and(live, k_max > q_min - window)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    nq = pl.cdiv(tq, bq)
    nk = pl.cdiv(tk, bk)
    # pad ragged sequence dims to block multiples (position masks drop pads)
    tq_p, tk_p = nq * bq, nk * bk
    if tq_p != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
    if tk_p != tk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tk_p - tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tk_p - tk), (0, 0)))

    qf = q.reshape(b * hq, tq_p, d)
    kf = k.reshape(b * hkv, tk_p, d)
    vf = v.reshape(b * hkv, tk_p, d)

    def kv_index(bh, qi, ki):
        # GQA deref: (batch, q-head) -> kv row, no repeated kv in memory
        return (bh // hq) * hkv + (bh % hq) // group, ki, 0

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            q_offset=q_offset,
            block_q=bq,
            block_k=bk,
            num_k_blocks=nk,
            kv_len=tk,
        ),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq_p, d), q.dtype),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, tq_p, d)[:, :, :tq, :]
