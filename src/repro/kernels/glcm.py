"""Pallas kernel: GLCM (gray-level co-occurrence) + histogram accumulation.

The paper's feature-computation stage (S5.1) computes per-nucleus
histograms and co-occurrence matrices with one GPU thread-block per
nucleus bounding box.  TPU adaptation: the scatter-add accumulation is
recast as a *one-hot matmul* — for each tile, GLCM = OneHot(left)^T @
OneHot(right) — which runs on the MXU with fully regular access.  The
grid runs one program per object tile (objects padded into fixed-size ROI
batches by the pipeline, replacing dynamic GPU block assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(bins_ref, glcm_ref, hist_ref, *, num_bins: int):
    bins = bins_ref[0]  # (H, W) int32
    h, w = bins.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, num_bins), 1)
    flat = bins.reshape(h * w, 1)
    hot = (flat == iota).astype(jnp.float32)  # (H*W, NB)
    hist_ref[0] = hot.sum(axis=0)
    left = bins[:, : w - 1].reshape(h * (w - 1), 1)
    right = bins[:, 1:].reshape(h * (w - 1), 1)
    lhot = (left == iota).astype(jnp.float32)
    rhot = (right == iota).astype(jnp.float32)
    # MXU contraction: (NB, P) @ (P, NB)
    glcm_ref[0] = jax.lax.dot_general(
        lhot,
        rhot,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def glcm_pallas(
    bins: jax.Array,
    num_bins: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(B, H, W) int32 bins -> (glcm (B, NB, NB), hist (B, NB)) float32.

    One grid program per object tile; whole tile in VMEM (object ROIs are
    small — nuclei are ~64x64 after padding).
    """
    b, h, w = bins.shape
    return pl.pallas_call(
        functools.partial(_kernel, num_bins=num_bins),
        out_shape=(
            jax.ShapeDtypeStruct((b, num_bins, num_bins), jnp.float32),
            jax.ShapeDtypeStruct((b, num_bins), jnp.float32),
        ),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, num_bins, num_bins), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(bins.astype(jnp.int32))
