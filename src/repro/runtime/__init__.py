"""Hierarchical-dataflow runtime (paper S3.2): Manager-Worker + WRM."""
from repro.runtime.dag import (
    DeviceKind,
    RegionBinding,
    Stage,
    StageContext,
    StageState,
    Task,
    TaskCost,
    TaskState,
)
from repro.runtime.manager import Manager, SysEnv, Worker
from repro.runtime.prefetch import DevicePipeline, prefetch_to_device
from repro.runtime.scheduler import (
    Device,
    ReadyQueue,
    SchedulerConfig,
    SimResult,
    SimulatedWRM,
    ThreadedWRM,
    make_devices,
)

__all__ = [
    "DeviceKind",
    "RegionBinding",
    "Stage",
    "StageContext",
    "StageState",
    "Task",
    "TaskCost",
    "TaskState",
    "Manager",
    "SysEnv",
    "Worker",
    "DevicePipeline",
    "prefetch_to_device",
    "Device",
    "ReadyQueue",
    "SchedulerConfig",
    "SimResult",
    "SimulatedWRM",
    "ThreadedWRM",
    "make_devices",
]
