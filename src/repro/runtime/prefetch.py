"""Data prefetching and asynchronous copy (paper S3.2.1, last subsection).

Accelerator work is pipelined through three phases — *upload, processing,
download* — so the upload of task N+1 and the download of task N-1 overlap
the compute of task N.  On TPU/JAX the natural realization is
double/triple-buffered ``jax.device_put`` plus async dispatch; this module
provides

  * :class:`DevicePipeline` — a generic 3-phase pipeline over an iterator
    of host batches: ``put -> fn -> fetch`` with a bounded in-flight
    window (the paper's upload/process/download chain);
  * :func:`prefetch_to_device` — the standard training-loop helper: wraps
    a host-batch iterator and keeps ``depth`` batches resident ahead of
    the consumer.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterable, Iterator

import jax
import numpy as np


def prefetch_to_device(
    it: Iterable[Any],
    depth: int = 2,
    sharding: jax.sharding.Sharding | None = None,
) -> Iterator[Any]:
    """Keep ``depth`` batches device-resident ahead of the consumer.

    Uploads happen on a background thread so host->device copies overlap
    the consumer's compute (async dispatch does the rest).
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    q: collections.deque = collections.deque()
    cv = threading.Condition()
    DONE = object()

    def _put(batch: Any) -> Any:
        tgt = sharding
        return jax.tree.map(
            lambda x: jax.device_put(x, tgt) if tgt is not None else jax.device_put(x),
            batch,
        )

    def _producer() -> None:
        try:
            for batch in it:
                staged = _put(batch)
                with cv:
                    while len(q) >= depth:
                        cv.wait()
                    q.append(staged)
                    cv.notify_all()
        finally:
            with cv:
                q.append(DONE)
                cv.notify_all()

    threading.Thread(target=_producer, daemon=True, name="prefetcher").start()
    while True:
        with cv:
            while not q:
                cv.wait()
            item = q.popleft()
            cv.notify_all()
        if item is DONE:
            return
        yield item


class DevicePipeline:
    """Explicit upload -> compute -> download pipeline (paper's 3 phases).

    ``fn`` must be an async-dispatching function (e.g. jitted); with
    ``window`` outstanding computations the host thread stays ahead of the
    device, so uploads/downloads of neighbours overlap compute.
    """

    def __init__(
        self,
        fn: Callable[..., Any],
        *,
        window: int = 2,
        sharding: jax.sharding.Sharding | None = None,
        host_fn: Callable[[Any], Any] | None = None,
    ) -> None:
        self.fn = fn
        self.window = max(1, window)
        self.sharding = sharding
        # optional terminal host stage applied to each downloaded result
        # (e.g. a kernel chain's host-side reduction): it runs while the
        # next items' device work is still in flight, so host post-
        # processing overlaps compute just like the downloads do
        self.host_fn = host_fn
        self.stats = {"uploaded": 0, "computed": 0, "downloaded": 0}

    def map_tagged(self, tagged: Iterable[tuple]) -> "Iterator[tuple]":
        """Like :meth:`map`, but over ``(tag, batch)`` pairs: the tag
        rides the pipeline untouched — never uploaded, never handed to
        ``fn`` — and is re-paired with its batch's result, yielding
        ``(tag, out)``.  For callers whose per-batch metadata (region
        keys, windows) is not device-puttable; the FIFO pairing
        invariant lives HERE, not in a caller-side side channel."""
        tags: collections.deque = collections.deque()

        def _strip() -> Iterator[Any]:
            for tag, batch in tagged:
                tags.append(tag)
                yield batch

        for out in self.map(_strip()):
            yield tags.popleft(), out

    def map(self, batches: Iterable[Any]) -> Iterator[Any]:
        inflight: collections.deque = collections.deque()
        for host_batch in batches:
            dev_batch = jax.tree.map(
                lambda x: jax.device_put(x, self.sharding)
                if self.sharding is not None
                else jax.device_put(x),
                host_batch,
            )
            self.stats["uploaded"] += 1
            out = self.fn(dev_batch)  # async dispatch: returns immediately
            self.stats["computed"] += 1
            inflight.append(out)
            if len(inflight) >= self.window:
                yield self._download(inflight.popleft())
        while inflight:
            yield self._download(inflight.popleft())

    def _download(self, out: Any) -> Any:
        host = jax.tree.map(np.asarray, out)
        self.stats["downloaded"] += 1
        return self.host_fn(host) if self.host_fn is not None else host
