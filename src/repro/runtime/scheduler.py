"""Worker Resource Manager: fine-grain task scheduling (paper S3.2.1).

Policies
--------
* **FCFS** — first-come first-served.
* **PATS** — the ready queue is kept sorted by estimated accelerator
  speedup; an idle accelerator takes the *max*-speedup ready task, an idle
  CPU core the *min*-speedup one.  Only the *ordering* of estimates
  matters, which is why PATS tolerates large estimate errors (Fig. 17).
* **DL** (orthogonal flag) — data-locality conscious assignment: when a
  device finishes a task, prefer a ready successor that reuses the data
  just produced there.  Under PATS the reuse task is taken iff
  ``S_d >= S_q * (1 - transfer_impact)`` (paper's rule verbatim); under
  FCFS any reuse task wins.  On CPUs the same rule gives NUMA-style
  affinity.
* **Pref** (simulator flag) — prefetch/async-copy: upload of a task's
  inputs overlaps the previous task's compute, so transfer cost only
  contributes ``max(0, transfer - prev_compute)``.

Two engines share the policy code:
  * :class:`ThreadedWRM` — real execution; one thread per (virtual)
    device; used by the live pipelines.
  * :class:`SimulatedWRM` — deterministic virtual-time list scheduler;
    used by the paper-figure benchmarks (no wall-clock sleeps).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from typing import Callable, Iterable

from repro.runtime.dag import DeviceKind, Task, TaskState
from repro.storage.tiers import TIER_BANDWIDTH


@dataclasses.dataclass(frozen=True)
class Device:
    did: int
    kind: DeviceKind

    def __repr__(self) -> str:
        return f"{self.kind.name}{self.did}"


def make_devices(num_cpus: int, num_accels: int) -> list[Device]:
    devs = [Device(i, DeviceKind.CPU) for i in range(num_cpus)]
    devs += [Device(num_cpus + i, DeviceKind.ACCEL) for i in range(num_accels)]
    return devs


@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "PATS"  # PATS | FCFS
    data_locality: bool = False  # DL
    prefetch: bool = False  # Pref (simulator)
    transfer_impact: float = 0.2  # user-provided in the paper
    pcie_bandwidth: float = 8.0e9  # bytes/s, upload/download cost model
    # tier-locality refinement: maps a task's region_key to the storage
    # tier currently holding it (e.g. TieredStore.locality); tier names
    # price the staging transfer.  None = the paper's flat cost model.
    locality_fn: Callable | None = None
    tier_bandwidth: dict = dataclasses.field(
        default_factory=lambda: dict(TIER_BANDWIDTH)
    )

    def staging_cost(self, task: Task) -> float | None:
        """Seconds to stage the task's input from its resident tier, or
        None when locality is unknown (no refinement possible)."""
        if self.locality_fn is None:
            return None
        key = getattr(task, "region_key", None)
        if key is None:
            return None
        tier = self.locality_fn(key)
        bw = self.tier_bandwidth.get(tier) if tier is not None else None
        if bw is None:
            return None
        return task.cost.input_bytes / bw

    def transfer_impact_for(self, task: Task) -> float:
        """DL transfer impact, refined by tier locality when known:
        memory-resident inputs are nearly free to move (impact -> 0),
        DMS/DISK-resident inputs charge the modeled staging cost."""
        staging = self.staging_cost(task)
        if staging is None:
            return self.transfer_impact
        accel_s = task.cost.cpu_s / max(task.cost.speedup, 1e-9)
        return min(0.95, staging / max(staging + accel_s, 1e-12))


class ReadyQueue:
    """Ready tasks, sorted by speedup when PATS is active (paper Fig. 5)."""

    def __init__(self, policy: str) -> None:
        self.policy = policy
        self._tasks: list[Task] = []
        self._seq = 0
        self._arrival: dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._tasks)

    def push(self, task: Task) -> None:
        task.state = TaskState.READY
        self._arrival[task.tid] = self._seq
        self._seq += 1
        self._tasks.append(task)

    def peek_for(self, kind: DeviceKind) -> Task | None:
        cands = [t for t in self._tasks if t.runnable_on(kind)]
        if not cands:
            return None
        return self._best(cands, kind)

    def _best(self, cands: list[Task], kind: DeviceKind) -> Task:
        if self.policy == "FCFS":
            return min(cands, key=lambda t: self._arrival[t.tid])
        # PATS: accelerator takes max speedup, CPU takes min; FIFO tiebreak
        if kind == DeviceKind.ACCEL:
            return max(cands, key=lambda t: (t.speedup, -self._arrival[t.tid]))
        return min(cands, key=lambda t: (t.speedup, self._arrival[t.tid]))

    def pop(self, task: Task) -> Task:
        self._tasks.remove(task)
        self._arrival.pop(task.tid, None)
        return task

    def reuse_candidates(self, finished: Task, kind: DeviceKind) -> list[Task]:
        """Ready successors of ``finished`` (they reuse its output: DL)."""
        ready_ids = {t.tid for t in self._tasks}
        return [
            c
            for c in finished.children
            if c.tid in ready_ids and c.runnable_on(kind)
        ]

    def select(
        self,
        kind: DeviceKind,
        cfg: SchedulerConfig,
        last_finished: Task | None,
    ) -> Task | None:
        """Full policy: PATS/FCFS base + optional DL reuse rule."""
        best = self.peek_for(kind)
        if best is None:
            return None
        if cfg.data_locality and last_finished is not None:
            reuse = self.reuse_candidates(last_finished, kind)
            if reuse:
                best_reuse = self._best(reuse, kind)
                if cfg.policy == "FCFS":
                    return self.pop(best_reuse)
                s_q, s_d = best.speedup, best_reuse.speedup
                # impact of *not* reusing = cost of staging the queue-best
                # task's data (tier-refined when locality is known)
                impact = cfg.transfer_impact_for(best)
                if kind == DeviceKind.ACCEL:
                    if s_d >= s_q * (1.0 - impact):
                        return self.pop(best_reuse)
                else:
                    # CPU mirror: reuse unless it is much *better* on accel
                    if s_d <= s_q / (1.0 - impact):
                        return self.pop(best_reuse)
        return self.pop(best)


class _DepTracker:
    """Pending-task bookkeeping shared by both engines."""

    def __init__(self) -> None:
        self.waiting: dict[int, Task] = {}

    def admit(self, task: Task, ready: ReadyQueue) -> None:
        if all(d.state == TaskState.DONE for d in task.deps):
            ready.push(task)
        else:
            task.state = TaskState.PENDING
            self.waiting[task.tid] = task

    def release(self, finished: Task, ready: ReadyQueue) -> None:
        for child in finished.children:
            if child.tid in self.waiting and all(
                d.state == TaskState.DONE for d in child.deps
            ):
                del self.waiting[child.tid]
                ready.push(child)


# ---------------------------------------------------------------------------
# Real threaded engine
# ---------------------------------------------------------------------------
class ThreadedWRM:
    """One computing thread per device (paper Fig. 5), real execution."""

    def __init__(self, devices: Iterable[Device], cfg: SchedulerConfig | None = None):
        self.devices = list(devices)
        self.cfg = cfg or SchedulerConfig()
        self.ready = ReadyQueue(self.cfg.policy)
        self.deps = _DepTracker()
        self._cv = threading.Condition()
        self._outstanding = 0
        self._shutdown = False
        self._last_on: dict[int, Task | None] = {d.did: None for d in self.devices}
        self.completed: list[Task] = []
        self.profile: dict[str, dict] = {}
        self._threads = [
            threading.Thread(target=self._loop, args=(d,), daemon=True, name=f"wrm-{d}")
            for d in self.devices
        ]
        for t in self._threads:
            t.start()

    def submit(self, task: Task) -> Task:
        with self._cv:
            self._outstanding += 1
            self.deps.admit(task, self.ready)
            self._cv.notify_all()
        return task

    def _loop(self, dev: Device) -> None:
        while True:
            with self._cv:
                task = None
                while task is None:
                    if self._shutdown:
                        return
                    task = self.ready.select(dev.kind, self.cfg, self._last_on[dev.did])
                    if task is None:
                        self._cv.wait(timeout=0.05)
                task.state = TaskState.RUNNING
            import time as _time

            t0 = _time.perf_counter()
            try:
                fn = task.fn_for(dev.kind)
                task.result = fn(*task.args, **task.kwargs) if fn else None
                task.state = TaskState.DONE
            except BaseException as e:  # noqa: BLE001 - surfaced via task.error
                task.error = e
                task.state = TaskState.FAILED
            dt = _time.perf_counter() - t0
            task.ran_on = dev.kind
            with self._cv:
                prof = self.profile.setdefault(
                    task.name, {"cpu_s": 0.0, "accel_s": 0.0, "cpu_n": 0, "accel_n": 0}
                )
                if dev.kind == DeviceKind.CPU:
                    prof["cpu_s"] += dt
                    prof["cpu_n"] += 1
                else:
                    prof["accel_s"] += dt
                    prof["accel_n"] += 1
                self._last_on[dev.did] = task
                self.completed.append(task)
                if task.state == TaskState.DONE:
                    self.deps.release(task, self.ready)
                self._outstanding -= 1
                self._cv.notify_all()

    def measured_speedup(self, name: str) -> float | None:
        """Online EWMA-free estimate: mean cpu time / mean accel time."""
        p = self.profile.get(name)
        if not p or not p["cpu_n"] or not p["accel_n"]:
            return None
        return (p["cpu_s"] / p["cpu_n"]) / max(p["accel_s"] / p["accel_n"], 1e-12)

    def wait_all(self) -> None:
        with self._cv:
            while self._outstanding > 0:
                self._cv.wait(timeout=0.05)
        failed = [t for t in self.completed if t.state == TaskState.FAILED]
        if failed:
            raise RuntimeError(f"{len(failed)} task(s) failed") from failed[0].error

    def shutdown(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Deterministic virtual-time engine (paper-figure benchmarks)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SimResult:
    makespan: float
    per_device_busy: dict[str, float]
    task_log: list[tuple[float, float, str, str]]  # (start, end, task, device)
    accel_task_count: dict[str, int]


class SimulatedWRM:
    """Event-driven list scheduler over virtual time.

    Transfer model: executing on the accelerator charges
    ``input_bytes/pcie_bw`` upload unless DL just reused the producer's
    output on that device, and ``output_bytes/pcie_bw`` download unless a
    successor immediately reuses it there.  With Pref, the upload overlaps
    the device's previous compute.
    """

    def __init__(self, devices: Iterable[Device], cfg: SchedulerConfig | None = None):
        self.devices = list(devices)
        self.cfg = cfg or SchedulerConfig()

    def run(self, tasks: list[Task]) -> SimResult:
        cfg = self.cfg
        ready = ReadyQueue(cfg.policy)
        deps = _DepTracker()
        for t in tasks:
            t.state = TaskState.PENDING
        for t in tasks:
            deps.admit(t, ready)

        free_at = {d.did: 0.0 for d in self.devices}
        busy = {repr(d): 0.0 for d in self.devices}
        last_on: dict[int, Task | None] = {d.did: None for d in self.devices}
        prev_compute: dict[int, float] = {d.did: 0.0 for d in self.devices}
        # where each task's output currently lives (device id) - DL state
        output_home: dict[int, int] = {}
        events: list[tuple[float, int, int]] = []  # (time, seq, device_id)
        seq = 0
        for d in self.devices:
            heapq.heappush(events, (0.0, seq, d.did))
            seq += 1
        running: dict[int, Task | None] = {d.did: None for d in self.devices}
        dev_by_id = {d.did: d for d in self.devices}
        log: list[tuple[float, float, str, str]] = []
        accel_count: dict[str, int] = {}
        done = 0
        makespan = 0.0

        while events:
            now, _, did = heapq.heappop(events)
            dev = dev_by_id[did]
            fin = running[did]
            if fin is not None:
                fin.state = TaskState.DONE
                done += 1
                deps.release(fin, ready)
                last_on[did] = fin
                output_home[fin.tid] = did
                running[did] = None
                makespan = max(makespan, now)
                # a completion may unblock other idle devices
                for od in self.devices:
                    if running[od.did] is None and od.did != did:
                        heapq.heappush(events, (max(now, free_at[od.did]), seq, od.did))
                        seq += 1
            task = ready.select(dev.kind, cfg, last_on[did])
            if task is None:
                continue
            task.state = TaskState.RUNNING
            compute = (
                task.cost.cpu_s
                if dev.kind == DeviceKind.CPU
                else task.cost.cpu_s / max(task.cost.speedup, 1e-9)
            )
            transfer = 0.0
            if dev.kind == DeviceKind.ACCEL:
                inputs_resident = all(
                    output_home.get(d.tid) == did for d in task.deps
                ) and bool(task.deps)
                if not inputs_resident and task.cost.input_bytes:
                    transfer = task.cost.input_bytes / cfg.pcie_bandwidth
                if cfg.prefetch:
                    transfer = max(0.0, transfer - prev_compute[did])
                accel_count[task.name] = accel_count.get(task.name, 0) + 1
            # tier staging: inputs must reach host memory regardless of
            # device; memory-resident data is near-free, DMS/DISK charge
            # the modeled per-tier bandwidth (0.0 when unrefined)
            staging = cfg.staging_cost(task) or 0.0
            duration = compute + transfer + staging
            start = max(now, free_at[did])
            end = start + duration
            free_at[did] = end
            busy[repr(dev)] += duration
            prev_compute[did] = compute
            running[did] = task
            task.ran_on = dev.kind
            log.append((start, end, task.name, repr(dev)))
            heapq.heappush(events, (end, seq, did))
            seq += 1

        if done != len(tasks):
            raise RuntimeError(f"simulation deadlock: {done}/{len(tasks)} completed")
        return SimResult(makespan, busy, log, accel_count)
