"""Manager-Worker execution of the coarse-grain dataflow (paper S3.2, Fig. 4).

The Manager owns the (incrementally growable) stage dependency graph and
hands stage instances to Workers **demand-driven**: workers request work
whenever they have a free slot; assignment granularity is one stage
instance.  Each Worker runs a Worker Coordinator (WCT) that

  1. unpacks the stage's region-template *metadata* (payloads never ride
     the control channel — they go through global storage),
  2. materializes the input data regions from their storage backends
     (overlapping with the compute of other active stage instances),
  3. executes the stage body, whose fine-grain tasks flow through the
     shared per-worker :class:`ThreadedWRM`,
  4. stages output data regions to their global storage backends,
  5. notifies the Manager, which releases dependent stages.

Fault tolerance beyond the paper (needed at 1000+ nodes):
  * heartbeat-based worker failure detection; in-flight stages of a dead
    worker are re-queued (stage writes are idempotent — last staged wins);
  * bounded retry of failed stages on a different worker;
  * speculative re-execution of stragglers once the ready frontier is
    empty and idle workers remain.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any

from repro.core.regions import STORAGE, DataRegion, RegionTemplate, StorageRegistry
from repro.runtime.dag import (
    Stage,
    StageContext,
    StageState,
    Task,
)
from repro.runtime.scheduler import Device, SchedulerConfig, ThreadedWRM, make_devices


class Worker:
    """One compute node: a WCT + a WRM over its devices (paper Fig. 4/5)."""

    def __init__(
        self,
        wid: int,
        manager: "Manager",
        devices: list[Device],
        *,
        max_active: int = 2,
        registry: StorageRegistry | None = None,
        sched: SchedulerConfig | None = None,
    ) -> None:
        self.wid = wid
        self.manager = manager
        self.registry = registry or STORAGE
        self.wrm = ThreadedWRM(devices, sched)
        self.max_active = max_active
        self.inbox: "queue.Queue[Stage | None]" = queue.Queue()
        self._slots = threading.Semaphore(max_active)
        self.alive = True
        self.last_seen = time.monotonic()
        self._wct = threading.Thread(target=self._wct_loop, daemon=True, name=f"wct-{wid}")
        self._wct.start()

    # -- WCT -------------------------------------------------------------------
    def _wct_loop(self) -> None:
        while self.alive:
            self.last_seen = time.monotonic()
            self._slots.acquire()
            if not self.alive:
                return
            self.manager._request_work(self.wid)
            try:
                stage = self.inbox.get(timeout=5.0)
            except queue.Empty:
                self._slots.release()
                continue
            if stage is None:
                self._slots.release()
                return
            threading.Thread(
                target=self._handle_stage,
                args=(stage,),
                daemon=True,
                name=f"stage-{stage.sid}@w{self.wid}",
            ).start()

    def _handle_stage(self, stage: Stage) -> None:
        try:
            if not self.alive:
                return
            stage.state = StageState.RUNNING
            # Worker-local template copies (metadata only, paper S3.2).
            # Copies are bound per-thread: a zombie execution on a dead
            # worker must never leak its (mutated) templates into a retry.
            local_templates = {
                k: RegionTemplate.unpack(v.pack()) for k, v in stage.templates.items()
            }
            stage.bind_thread_templates(local_templates)
            ctx = StageContext(
                stage,
                self,
                submit_task=self.wrm.submit,
                spawn_stage=self.manager.execute_component,
            )
            submitted: list[Task] = []
            orig_submit = ctx._submit_task

            def tracking_submit(task: Task) -> None:
                submitted.append(task)
                orig_submit(task)

            ctx._submit_task = tracking_submit

            # (2) materialize inputs — overlaps other stages' compute
            for b in stage.input_bindings():
                rt = local_templates[b.template]
                try:
                    region = rt.get(b.region)
                except KeyError:
                    # region produced upstream but unknown to this stage's
                    # metadata: associative query against global storage
                    # (paper S3.3: query interface on the tuple identifier)
                    backend = self.registry.get(b.read_storage)
                    cands = backend.query(rt.namespace, b.region)
                    if not cands:
                        raise
                    key, bb = max(cands, key=lambda kv: (kv[0].timestamp, kv[0].version))
                    region = DataRegion(key, bb, input_storage=b.read_storage, lazy=True)
                    rt.insert(region)
                local = region.with_roi(b.roi)
                if b.read_storage:
                    local.input_storage = b.read_storage
                    # record which storage layer serves this input
                    # (observable consumption of the locality query)
                    tier = self.registry.locality(b.read_storage, region.key)
                    with self.manager._lock:
                        self.manager.events.append(
                            ("locality", (stage.sid, b.region, tier))
                        )
                local.instantiate(self.registry)
                ctx.regions[(b.template, b.region)] = local

            # (3) run the body; fine-grain tasks flow through the WRM
            stage.result = stage.run(ctx)
            self._wait_tasks(submitted)

            # (4) stage outputs to global storage
            for b in stage.output_bindings():
                rt = local_templates[b.template]
                region = rt.get(b.region)
                if region.empty():
                    raise RuntimeError(
                        f"stage {stage.name}: output region {b.region!r} never materialized"
                    )
                out = region.with_roi(b.roi)
                out._data = region.to_host()
                out._location = "host"
                out.output_storage = b.storage or region.output_storage
                out.write(self.registry)
            if not self.alive:
                return  # died mid-stage: manager's heartbeat will requeue
            # expose the winning execution's templates for inspection
            stage.templates = local_templates
            self.manager._notify_done(stage, self.wid)
        except BaseException as e:  # noqa: BLE001
            stage.error = e
            if self.alive:
                self.manager._notify_failed(stage, self.wid, e)
        finally:
            stage.unbind_thread_templates()
            self._slots.release()

    def _wait_tasks(self, tasks: list[Task]) -> None:
        from repro.runtime.dag import TaskState

        while True:
            states = [t.state for t in tasks]
            if any(s == TaskState.FAILED for s in states):
                bad = next(t for t in tasks if t.state == TaskState.FAILED)
                raise RuntimeError(f"task {bad.name} failed") from bad.error
            if all(s == TaskState.DONE for s in states):
                return
            time.sleep(0.001)

    def kill(self) -> None:
        """Simulate node failure (tests/benchmarks)."""
        self.alive = False
        self.wrm.shutdown()

    def shutdown(self) -> None:
        self.alive = False
        self.inbox.put(None)
        self.wrm.shutdown()


class Manager:
    """Owns the stage graph; demand-driven dispatch; failure handling."""

    def __init__(
        self,
        *,
        heartbeat_timeout: float = 5.0,
        max_retries: int = 2,
        speculative: bool = False,
        speculation_factor: float = 2.5,
        registry: StorageRegistry | None = None,
    ) -> None:
        self.stages: dict[int, Stage] = {}
        # storage registry for tier-locality-aware dispatch (optional):
        # among equally-ready stages, prefer the one whose inputs sit in
        # the fastest storage tier (cheapest staging transfer)
        self.registry = registry
        from repro.storage.tiers import TIER_BANDWIDTH

        # overridden by SysEnv from SchedulerConfig.tier_bandwidth so
        # dispatch and the WRM price tiers with the same table
        self.tier_bandwidth: dict[str, float] = dict(TIER_BANDWIDTH)
        # sticky: flips true once a hierarchical backend is registered,
        # keeping flat-storage dispatch on the cheap first-ready path
        self._locality_seen = False
        self.heartbeat_timeout = heartbeat_timeout
        self.max_retries = max_retries
        self.speculative = speculative
        self.speculation_factor = speculation_factor
        self.workers: dict[int, Worker] = {}
        self._requests: "queue.Queue[int]" = queue.Queue()
        self._lock = threading.RLock()
        self._done_evt = threading.Event()
        self._inflight: dict[int, tuple[int, float]] = {}  # sid -> (wid, t_start)
        self._speculated: set[int] = set()
        self.events: list[tuple[str, Any]] = []

    # -- graph construction (application Manager code, paper Fig. 8a) -------------
    def execute_component(self, stage: Stage) -> Stage:
        with self._lock:
            self.stages[stage.sid] = stage
            self._done_evt.clear()
        return stage

    def add_worker(self, worker: Worker) -> None:
        with self._lock:
            self.workers[worker.wid] = worker

    # -- worker-facing protocol -----------------------------------------------------
    def _request_work(self, wid: int) -> None:
        self._requests.put(wid)

    def _notify_done(self, stage: Stage, wid: int) -> None:
        with self._lock:
            cur = self.stages.get(stage.sid)
            if cur is not None and cur.state == StageState.DONE:
                return  # speculative duplicate lost the race
            stage.state = StageState.DONE
            self.stages[stage.sid] = stage
            self._inflight.pop(stage.sid, None)
            self.events.append(("done", (stage.sid, wid)))

    def _notify_failed(self, stage: Stage, wid: int, err: BaseException) -> None:
        with self._lock:
            if self.stages.get(stage.sid) and self.stages[stage.sid].state == StageState.DONE:
                return
            stage.attempts += 1
            self._inflight.pop(stage.sid, None)
            self.events.append(("failed", (stage.sid, wid, repr(err))))
            if stage.attempts > self.max_retries:
                stage.state = StageState.FAILED
                self._done_evt.set()  # unrecoverable: surface to run()
            else:
                stage.state = StageState.WAITING  # re-queue elsewhere

    # -- main loop --------------------------------------------------------------------
    def run(self, poll: float = 0.005) -> None:
        """Block until every stage is DONE (or raise on unrecoverable FAIL)."""
        while True:
            with self._lock:
                states = [s.state for s in self.stages.values()]
                if any(s == StageState.FAILED for s in states):
                    bad = next(
                        s for s in self.stages.values() if s.state == StageState.FAILED
                    )
                    raise RuntimeError(
                        f"stage {bad.name}#{bad.sid} failed after {bad.attempts} attempts"
                    ) from bad.error
                if states and all(s == StageState.DONE for s in states):
                    return
                self._check_heartbeats()
            try:
                wid = self._requests.get(timeout=poll)
            except queue.Empty:
                continue
            with self._lock:
                worker = self.workers.get(wid)
                if worker is None or not worker.alive:
                    continue
                stage = self._pick_ready()
                if stage is None and self.speculative:
                    stage = self._pick_straggler()
                if stage is None:
                    # nothing ready: requeue the request (demand persists)
                    threading.Timer(poll, self._requests.put, args=(wid,)).start()
                    continue
                stage.state = StageState.DISPATCHED
                stage.worker = wid
                self._inflight[stage.sid] = (wid, time.monotonic())
                self.events.append(("dispatch", (stage.sid, wid)))
            worker.inbox.put(stage)

    def _pick_ready(self) -> Stage | None:
        ready = [
            s
            for s in self.stages.values()
            if s.state == StageState.WAITING
            and all(d.state == StageState.DONE for d in s.deps)
        ]
        if not ready:
            return None
        if self.registry is None or len(ready) == 1 or not self._locality_available():
            return ready[0]
        # min() is stable: ties keep the original demand-driven order
        return min(ready, key=self._staging_estimate)

    def _locality_available(self) -> bool:
        if self._locality_seen:
            return True
        try:
            names = self.registry.names()
        except Exception:  # noqa: BLE001 - registry shape is caller-defined
            return False
        for name in names:
            if callable(getattr(self.registry.get(name), "locality", None)):
                self._locality_seen = True
                return True
        return False

    def _staging_estimate(self, stage: Stage) -> float:
        """Virtual seconds to stage the stage's inputs, priced per tier.

        Backends without a ``locality`` query contribute 0 (no
        information), so flat-storage runs keep the original order.
        """
        total = 0.0
        for b in stage.input_bindings():
            if not b.read_storage:
                continue
            rt = stage.templates.get(b.template)
            if rt is None:
                continue
            try:
                backend = self.registry.get(b.read_storage)
                region = rt.get(b.region)
            except KeyError:
                continue  # unknown backend / region produced upstream
            # only hierarchical backends carry placement information; a
            # flat backend whose *name* collides with a tier label must
            # not be priced as that tier
            if not callable(getattr(backend, "locality", None)):
                continue
            tier = backend.locality(region.key)
            bw = self.tier_bandwidth.get(tier) if tier is not None else None
            if bw:
                # the stage stages only its bound ROI, not the whole region
                roi_bytes = b.roi.volume * region.key.elem_type.to_dtype().itemsize
                total += roi_bytes / bw
        return total

    def _pick_straggler(self) -> Stage | None:
        """Speculative re-execution: duplicate the longest-running stage."""
        if not self._inflight:
            return None
        durations = [
            (time.monotonic() - t0, sid) for sid, (_, t0) in self._inflight.items()
        ]
        if len(durations) < 1:
            return None
        dur, sid = max(durations)
        med = sorted(d for d, _ in durations)[len(durations) // 2]
        if sid in self._speculated or dur < self.speculation_factor * max(med, 1e-3):
            return None
        self._speculated.add(sid)
        original = self.stages[sid]
        self.events.append(("speculate", (sid,)))
        return original  # idempotent outputs: duplicate is safe

    def _check_heartbeats(self) -> None:
        now = time.monotonic()
        for wid, w in list(self.workers.items()):
            if not w.alive or now - w.last_seen <= self.heartbeat_timeout:
                if not w.alive:
                    self._requeue_from(wid)
                continue
            # stale heartbeat: only declare death if the WCT thread is
            # actually gone — a starved-but-live worker is a straggler,
            # not a failure (speculation handles stragglers)
            if w._wct.is_alive():
                continue
            w.alive = False
            self._requeue_from(wid)
            self.events.append(("worker-dead", (wid,)))

    def _requeue_from(self, wid: int) -> None:
        for sid, (w, _) in list(self._inflight.items()):
            if w == wid:
                stage = self.stages[sid]
                if stage.state in (StageState.DISPATCHED, StageState.RUNNING):
                    stage.state = StageState.WAITING
                    stage.attempts += 1
                self._inflight.pop(sid, None)
                self.events.append(("requeue", (sid, wid)))


class SysEnv:
    """Application facade (paper Fig. 8a): storages + workers + manager."""

    def __init__(
        self,
        *,
        num_workers: int = 1,
        cpus_per_worker: int = 2,
        accels_per_worker: int = 1,
        sched: SchedulerConfig | None = None,
        registry: StorageRegistry | None = None,
        max_active: int = 2,
        speculative: bool = False,
        heartbeat_timeout: float = 5.0,
    ) -> None:
        self.registry = registry or STORAGE
        self.manager = Manager(
            speculative=speculative,
            heartbeat_timeout=heartbeat_timeout,
            registry=self.registry,
        )
        if sched is not None:
            self.manager.tier_bandwidth = dict(sched.tier_bandwidth)
        self.workers = [
            Worker(
                w,
                self.manager,
                make_devices(cpus_per_worker, accels_per_worker),
                max_active=max_active,
                registry=self.registry,
                sched=sched,
            )
            for w in range(num_workers)
        ]
        for w in self.workers:
            self.manager.add_worker(w)

    def register_storage(self, backend) -> Any:
        return self.registry.register(backend)

    def execute_component(self, stage: Stage) -> Stage:
        return self.manager.execute_component(stage)

    def startup_execution(self) -> None:
        self.manager.run()

    def finalize_system(self) -> None:
        for w in self.workers:
            w.shutdown()
