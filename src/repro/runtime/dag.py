"""Hierarchical dataflow representation (paper S3.2, Figs. 3-5).

Two levels (extensible to more):
  * **Stage** — coarse-grain component; what the Manager ships to Workers.
    A stage declares which region-template data regions it reads/writes
    (``bind_region``), may depend on other stages, and its ``run`` body
    emits fine-grain **Task**s.
  * **Task** — fine-grain operation scheduled by the Worker Resource
    Manager onto a CPU core or an accelerator.  A task carries one
    implementation *variant per device kind* plus an estimated accelerator
    speedup (PATS) and the ids of the data it consumes/produces (DL).

The dependency graph is allowed to grow at runtime (a stage may spawn new
stage instances through its context) — the paper calls this incremental
DAG construction and it is what separates this runtime from static-DAG
systems (StarPU/DAGuE, see S6).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
from typing import Any, Callable

from repro.core.bbox import BoundingBox
from repro.core.regions import DataRegion, Intent, RegionTemplate

_ids = itertools.count()


class DeviceKind(enum.IntEnum):
    CPU = 0
    ACCEL = 1  # GPU in the paper; TPU host-offload peer here


class TaskState(enum.IntEnum):
    PENDING = 0  # dependencies unresolved
    READY = 1
    RUNNING = 2
    DONE = 3
    FAILED = 4


@dataclasses.dataclass
class TaskCost:
    """Cost model for the virtual-time simulator (benchmarks) and PATS.

    ``cpu_s`` is the CPU-core execution time; the accelerator time is
    ``cpu_s / speedup``; ``input_bytes``/``output_bytes`` drive transfer
    costs unless the scheduler's DL policy avoids the movement.
    """

    cpu_s: float = 1e-3
    speedup: float = 1.0
    input_bytes: int = 0
    output_bytes: int = 0


class Task:
    """Fine-grain operation with per-device variants."""

    def __init__(
        self,
        name: str,
        *,
        cpu_fn: Callable[..., Any] | None = None,
        accel_fn: Callable[..., Any] | None = None,
        args: tuple = (),
        kwargs: dict | None = None,
        deps: list["Task"] | None = None,
        cost: TaskCost | None = None,
        produces: tuple[str, ...] = (),
        consumes: tuple[str, ...] = (),
        region_key: Any = None,
    ) -> None:
        self.tid = next(_ids)
        self.name = name
        self.variants: dict[DeviceKind, Callable[..., Any]] = {}
        if cpu_fn is not None:
            self.variants[DeviceKind.CPU] = cpu_fn
        if accel_fn is not None:
            self.variants[DeviceKind.ACCEL] = accel_fn
        self.args = args
        self.kwargs = kwargs or {}
        self.deps: list[Task] = list(deps or [])
        self.children: list[Task] = []
        for d in self.deps:
            d.children.append(self)
        self.cost = cost or TaskCost()
        self.produces = produces  # data ids this task outputs (DL)
        self.consumes = consumes  # data ids this task reads (DL)
        # RegionKey of the input data region (tier-locality transfer costs)
        self.region_key = region_key
        self.state = TaskState.PENDING
        self.result: Any = None
        self.error: BaseException | None = None
        self.ran_on: DeviceKind | None = None
        # PATS schedules on the *estimate*; execution cost uses the truth
        # (cost.speedup).  None = estimate equals truth (Fig. 17 baseline).
        self.est_speedup: float | None = None

    @property
    def speedup(self) -> float:
        return self.est_speedup if self.est_speedup is not None else self.cost.speedup

    def runnable_on(self, kind: DeviceKind) -> bool:
        return kind in self.variants or not self.variants

    def fn_for(self, kind: DeviceKind) -> Callable[..., Any] | None:
        if not self.variants:
            return None
        if kind in self.variants:
            return self.variants[kind]
        # fall back to the other variant (a CPU can always emulate)
        return next(iter(self.variants.values()))

    def __repr__(self) -> str:
        return f"Task#{self.tid}({self.name} state={self.state.name} S={self.speedup:.1f})"


@dataclasses.dataclass
class RegionBinding:
    """A stage's declared use of one data region (paper Fig. 8)."""

    template: str
    region: str
    roi: BoundingBox
    intent: Intent
    storage: str | None = None  # backend name for the *write* side
    read_storage: str | None = None


class StageState(enum.IntEnum):
    WAITING = 0
    DISPATCHED = 1
    RUNNING = 2
    DONE = 3
    FAILED = 4


class Stage:
    """Coarse-grain component; subclass and implement :meth:`run`."""

    def __init__(self, name: str | None = None) -> None:
        self.sid = next(_ids)
        self.name = name or type(self).__name__
        self.bindings: list[RegionBinding] = []
        self.deps: list[Stage] = []
        self.state = StageState.WAITING
        self.templates: dict[str, RegionTemplate] = {}
        self.attempts = 0
        self.worker: int | None = None
        self.result: Any = None
        self.error: BaseException | None = None
        self._lock = threading.Lock()
        # per-executing-thread template copies: retries may overlap with a
        # zombie execution on a dead worker; each must see its own copy
        self._templates_by_thread: dict[int, dict[str, RegionTemplate]] = {}

    # -- wiring (manager side, paper Fig. 8a) ------------------------------------
    def add_region_template(
        self,
        rt: RegionTemplate,
        region: str,
        roi: BoundingBox,
        intent: Intent,
        storage: str | None = None,
        read_storage: str | None = None,
    ) -> None:
        self.templates[rt.name] = rt
        self.bindings.append(
            RegionBinding(rt.name, region, roi, intent, storage, read_storage)
        )

    def add_dependency(self, other: "Stage") -> None:
        self.deps.append(other)

    def get_region_template(self, name: str) -> RegionTemplate:
        local = self._templates_by_thread.get(threading.get_ident())
        if local is not None:
            return local[name]
        return self.templates[name]

    def bind_thread_templates(self, templates: dict[str, RegionTemplate]) -> None:
        self._templates_by_thread[threading.get_ident()] = templates

    def unbind_thread_templates(self) -> None:
        self._templates_by_thread.pop(threading.get_ident(), None)

    # -- worker side -----------------------------------------------------------------
    def input_bindings(self) -> list[RegionBinding]:
        return [b for b in self.bindings if b.intent.reads]

    def output_bindings(self) -> list[RegionBinding]:
        return [b for b in self.bindings if b.intent.writes]

    def run(self, ctx: "StageContext") -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- Manager<->Worker shipping (metadata only; payloads ride global storage) ----
    def pack(self) -> dict:
        return {
            "cls": type(self),
            "sid": self.sid,
            "name": self.name,
            "bindings": self.bindings,
            "templates": {k: v.pack() for k, v in self.templates.items()},
            "state": dict(self.__dict__.get("config", {})),
        }

    def __repr__(self) -> str:
        return f"Stage#{self.sid}({self.name} state={self.state.name})"


class StageContext:
    """What a running stage sees: its data regions, a task submitter, and
    the ability to spawn further stage instances (incremental DAG)."""

    def __init__(self, stage: Stage, worker: Any, submit_task, spawn_stage) -> None:
        self.stage = stage
        self.worker = worker
        self._submit_task = submit_task
        self._spawn_stage = spawn_stage
        self.regions: dict[tuple[str, str], DataRegion] = {}

    def region(self, template: str, name: str) -> DataRegion:
        return self.regions[(template, name)]

    def submit(self, task: Task) -> Task:
        self._submit_task(task)
        return task

    def spawn_stage(self, stage: Stage, deps: list[Stage] | None = None) -> Stage:
        for d in deps or []:
            stage.add_dependency(d)
        self._spawn_stage(stage)
        return stage


def toposort_ready(stages: list[Stage]) -> list[Stage]:
    """Stages whose dependencies are all DONE (demand-driven frontier)."""
    return [
        s
        for s in stages
        if s.state == StageState.WAITING and all(d.state == StageState.DONE for d in s.deps)
    ]
