"""Cell builder: (arch x shape x mesh) -> jittable step + abstract inputs.

A *cell* is one dry-run unit: the step function (train_step for ``train``
shapes, prefill/decode serve steps for inference shapes), abstract
ShapeDtypeStruct inputs, and the in/out shardings over the given mesh.
The same builder powers the real drivers (launch/train.py, serve.py) and
the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, cell_supported, get_config
from repro.models import registry
from repro.models.config import ModelConfig
from repro.models.spec import (
    DEFAULT_RULES,
    ParamSpec,
    named_shardings,
)
from repro.serve import abstract_cache, cache_shardings, make_decode_step, make_prefill_step
from repro.train import AdamW, AdamWConfig, abstract_state, make_train_step, state_shardings

ENC_LEN_STUB = 4096  # encoder frames for enc-dec decode cells (audio stub)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    meta: dict
    rules: dict | None = None


def _batch_shardings(cfg: ModelConfig, mesh, batch: int, *, rules=None) -> Any:
    rules = rules or DEFAULT_RULES
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as np

    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    b = (dp if len(dp) > 1 else dp[0]) if (dp and batch % total == 0) else None
    def sh(spec):
        return NamedSharding(mesh, spec)

    out = {"tokens": sh(P(b)), "labels": sh(P(b))}
    if cfg.family == "encdec":
        out["frames"] = sh(P(b))
    if cfg.frontend:
        out["prefix"] = sh(P(b))
    return out


def _abstract_batch(cfg: ModelConfig, shape: ShapeSpec, *, with_labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    tok_len = s - cfg.frontend_len if cfg.frontend else s
    batch: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, tok_len), jnp.int32),
    }
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((b, tok_len), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.frontend:
        batch["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
        )
    return batch


def _abstract_params(cfg: ModelConfig) -> Any:
    spec_tree = registry.abstract_params(cfg)
    return jax.tree_util.tree_map(
        lambda sp: jax.ShapeDtypeStruct(sp.shape, sp.dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def build_cell(
    arch: str,
    shape_name: str,
    mesh: jax.sharding.Mesh,
    *,
    zero1: bool = False,
    rules: dict | None = None,
    optim=None,
    cfg_overrides: dict | None = None,
    seq_shard_cache: bool = False,
) -> Cell:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) unsupported: {why}")
    if rules is None:
        from repro.models.spec import seq_shard_rules

        rules = seq_shard_rules() if seq_shard_cache else DEFAULT_RULES

    if shape.kind == "train":
        optim = optim or AdamW(AdamWConfig())
        fn = make_train_step(cfg, optim)
        state = abstract_state(cfg, optim)
        batch = _abstract_batch(cfg, shape, with_labels=True)
        st_sh = state_shardings(cfg, mesh, optim, zero1=zero1, rules=rules)
        b_sh = _batch_shardings(cfg, mesh, shape.global_batch, rules=rules)
        return Cell(
            arch, shape, cfg, fn, (state, batch),
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
            meta={"kind": "train", "tokens": shape.global_batch * shape.seq_len},
            rules=rules,
        )

    params = _abstract_params(cfg)
    p_sh = named_shardings(registry.abstract_params(cfg), mesh, rules)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch = _abstract_batch(cfg, shape, with_labels=False)
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, enc_len=shape.seq_len)
        c_sh = cache_shardings(cfg, cache, mesh, rules, seq_shard=seq_shard_cache)
        b_sh = _batch_shardings(cfg, mesh, shape.global_batch, rules=rules)
        b_sh.pop("labels", None)
        return Cell(
            arch, shape, cfg, fn, (params, batch, cache),
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(2,),
            meta={"kind": "prefill", "tokens": shape.global_batch * shape.seq_len},
            rules=rules,
        )

    # decode: one new token against a cache of seq_len
    fn = make_decode_step(cfg)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, enc_len=ENC_LEN_STUB)
    c_sh = cache_shardings(cfg, cache, mesh, rules, seq_shard=seq_shard_cache)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as np

    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    b = (dp if len(dp) > 1 else dp[0]) if (dp and shape.global_batch % total == 0) else None
    t_sh = NamedSharding(mesh, P(b, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    return Cell(
        arch, shape, cfg, fn, (params, tokens, cache, pos),
        in_shardings=(p_sh, t_sh, c_sh, pos_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
        meta={"kind": "decode", "tokens": shape.global_batch},
        rules=rules,
    )


def lower_cell(cell: Cell, mesh: jax.sharding.Mesh):
    """jit + lower (+ the caller compiles)."""
    from repro.models.spec import activation_sharding

    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    with activation_sharding(mesh, cell.rules):
        lowered = jitted.lower(*cell.args)
    return lowered
