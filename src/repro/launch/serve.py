"""Batched serving driver: prefill + decode over a request queue.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.spec import materialize
from repro.models import registry
from repro.serve import generate


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down(vocab=512)
    print(f"[serve] arch={cfg.name} family={cfg.family}")
    params = materialize(jax.random.key(args.seed), registry.abstract_params(cfg))
    rng = np.random.default_rng(args.seed)

    done = 0
    total_tokens = 0
    t0 = time.time()
    outputs = []
    while done < args.requests:
        bs = min(args.batch, args.requests - done)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (bs, args.prompt_len)), jnp.int32
        )
        kw = {}
        if cfg.family == "encdec":
            kw["frames"] = jnp.asarray(
                rng.standard_normal((bs, 64, cfg.d_model)).astype(np.float32) * 0.1
            )
        if cfg.frontend:
            kw["prefix"] = jnp.asarray(
                rng.standard_normal((bs, cfg.frontend_len, cfg.d_model)).astype(np.float32)
                * 0.1
            )
        out = generate(
            params, cfg, prompts, max_new=args.max_new,
            temperature=args.temperature, key=jax.random.key(done), **kw,
        )
        outputs.append(np.asarray(out))
        done += bs
        total_tokens += bs * args.max_new
    dt = time.time() - t0
    print(f"[serve] {done} requests, {total_tokens} new tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    return {"outputs": outputs, "tok_per_s": total_tokens / dt}


if __name__ == "__main__":
    main()
