import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and emit roofline artifacts.

The two lines above MUST run before any other import (jax locks the device
count at first init); smoke tests and benches never import this module, so
they see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --list

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, (built-in + multiplicity-corrected) cost analysis,
collective byte breakdown, and the three roofline terms.
"""
import argparse
import json
import time
import traceback


from repro.analysis import hlo as hlo_analysis
from repro.analysis.roofline import compute_terms, model_flops
from repro.configs import SHAPES, all_cells, cell_supported, get_config
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models import registry as model_registry


def _memory_analysis_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # noqa: BLE001
        out["error"] = repr(e)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    outdir: str,
    *,
    zero1: bool = False,
    skip_hlo: bool = False,
    cfg_overrides: dict | None = None,
    seq_shard_cache: bool = False,
    seq_parallel: bool = False,
    tag: str = "",
) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "zero1": zero1,
        "status": "ok",
        "tag": tag,
        "cfg_overrides": {k: str(v) for k, v in (cfg_overrides or {}).items()},
        "seq_shard_cache": seq_shard_cache,
        "seq_parallel": seq_parallel,
    }
    t0 = time.time()
    try:
        from repro.models.spec import seq_parallel_rules

        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        cell = build_cell(
            arch, shape_name, mesh, zero1=zero1, cfg_overrides=cfg_overrides,
            seq_shard_cache=seq_shard_cache,
            rules=seq_parallel_rules() if seq_parallel else None,
        )
        record["chips"] = int(chips)
        record["mesh_shape"] = {k: int(v) for k, v in mesh.shape.items()}
        record["kind"] = cell.meta["kind"]
        record["tokens_per_step"] = int(cell.meta["tokens"])

        lowered = lower_cell(cell, mesh)
        record["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)
        record["memory_analysis"] = _memory_analysis_dict(compiled)

        ca = hlo_analysis.xla_cost_analysis(compiled)
        record["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }

        if not skip_hlo:
            t2 = time.time()
            text = compiled.as_text()
            record["hlo_bytes_len"] = len(text)
            cost = hlo_analysis.analyze(text)
            record["hlo_analysis_s"] = round(time.time() - t2, 2)
            record["hlo"] = cost.as_dict()

            cfg = cell.cfg
            n_active = model_registry.count_active_params(cfg)
            training = cell.meta["kind"] == "train"
            mf = model_flops(n_active, cell.meta["tokens"], training=training)
            terms = compute_terms(
                flops_per_chip=cost.flops,
                bytes_per_chip=cost.bytes,
                collective_bytes_per_chip=cost.collective_bytes,
                chips=chips,
                model_flops_total=mf,
            )
            record["roofline"] = terms.as_dict()
            record["n_params"] = model_registry.count_params(cfg)
            record["n_active_params"] = n_active
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = repr(e)
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 2)

    os.makedirs(outdir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    status = record["status"]
    extra = ""
    if status == "ok" and "roofline" in record:
        r = record["roofline"]
        extra = (
            f" compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"coll={r['collective_s']:.4f}s bottleneck={r['bottleneck']}"
        )
    print(f"[{status}] {arch} x {shape_name} x {mesh_name}{suffix} "
          f"({record['total_s']}s){extra}", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every supported cell")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--skip-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    # perf-iteration knobs (see EXPERIMENTS.md SPerf)
    ap.add_argument("--attn-impl", default=None, choices=["xla", "chunked"])
    ap.add_argument("--remat", default=None, choices=["none", "dots", "full"])
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--seq-shard-cache", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-style sequence-parallel residual activations")
    args = ap.parse_args()

    overrides: dict = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.remat:
        overrides["remat"] = args.remat
    if args.moe_groups:
        overrides["moe_groups"] = args.moe_groups
    if args.ssm_chunk:
        overrides["ssm_chunk"] = args.ssm_chunk
    if args.capacity_factor:
        overrides["capacity_factor"] = args.capacity_factor

    if args.list:
        for arch, shape, ok, why in all_cells():
            print(f"{arch:24s} {shape:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells() if ok]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all/--list")
        ok, why = cell_supported(get_config(args.arch), args.shape)
        if not ok:
            print(f"SKIP {args.arch} x {args.shape}: {why}")
            return
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(
                arch, shape, mp, args.out, zero1=args.zero1,
                skip_hlo=args.skip_hlo, tag=args.tag,
                cfg_overrides=overrides or None,
                seq_shard_cache=args.seq_shard_cache,
                seq_parallel=args.seq_parallel,
            )
            failures += rec["status"] != "ok"
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
