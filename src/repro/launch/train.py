"""End-to-end training driver.

Wires every substrate together: synthetic data -> region-template loader
(DMS staging + device prefetch) -> jitted train step (mesh-sharded) ->
async region-template checkpoints (DISK engine, I/O groups) with restart
and elastic resharding.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128

Production shapes lower through the same code path on the real mesh.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BoundingBox
from repro.data import RegionTemplateLoader, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models.spec import activation_sharding
from repro.storage import CheckpointManager, DiskStorage, DistributedMemoryStorage
from repro.train import AdamW, AdamWConfig, cosine_lr, init_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.scaled_down(vocab=args.vocab)
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"layers={cfg.num_layers} d={cfg.d_model} vocab={cfg.vocab}")

    mesh = make_host_mesh(data=1, model=1)
    optim = AdamW(AdamWConfig(lr=args.lr))
    sched = lambda s: cosine_lr(s, base=args.lr, warmup=10, total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, optim, lr_schedule=sched), donate_argnums=0)

    # --- data: synthetic stream staged through DMS data regions ---
    source = SyntheticTokens(cfg.vocab, args.seq, args.batch, seed=args.seed,
                             num_steps=args.steps + 1)
    dms = DistributedMemoryStorage(
        BoundingBox((0, 0), (args.batch, args.seq)),
        (args.batch, args.seq),
        num_servers=2,
        name="DATA_DMS",
    )
    loader = RegionTemplateLoader(source, dms)

    # --- checkpointing through the DISK engine ---
    os.makedirs(args.ckpt_dir, exist_ok=True)
    store = DiskStorage(args.ckpt_dir, transport="aggregated", io_group_size=2,
                        queue_threshold=8)
    ckpt = CheckpointManager(store, keep=2)

    state = init_state(jax.random.key(args.seed), cfg, optim)
    start_step = 0
    if args.restore and ckpt.latest_step() is not None:
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), state
        )
        state = ckpt.restore(target)
        start_step = int(np.asarray(state["step"]))
        print(f"[train] restored checkpoint at step {start_step}")

    losses = []
    t0 = time.time()
    with activation_sharding(mesh):
        for i, batch in enumerate(loader):
            step = start_step + i
            if step >= args.steps:
                break
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                toks = args.batch * args.seq
                dt = (time.time() - t0) / max(len(losses), 1)
                print(f"  step {step:5d} loss {loss:7.4f} lr {float(metrics.get('lr', 0)):.2e} "
                      f"{toks/dt:8.0f} tok/s")
            if args.ckpt_every and step > 0 and step % args.ckpt_every == 0:
                ckpt.save(step, state, blocking=False)
    ckpt.wait()
    ckpt.save(start_step + len(losses), state)
    loader.close()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} steps, {time.time()-t0:.1f}s)")
    return {"losses": losses, "state": state, "ckpt": ckpt}


if __name__ == "__main__":
    main()
