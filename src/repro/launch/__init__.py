"""Launchers: production mesh, dry-run, train/serve drivers.

NOTE: repro.launch.dryrun must be executed as __main__ (it sets XLA_FLAGS
before importing jax); do not import it from library code.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
