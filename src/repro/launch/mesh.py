"""Production meshes (defined as functions: importing this module never
touches jax device state).

Single pod : (16, 16)      axes (data, model)        = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes (pod, data, model)   = 512 chips

The ``pod`` axis rides DCN (slow), ``data``/``model`` ride ICI — the
gradient-compression and ZeRO machinery in repro.train keys off these
names.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (``axis_types=`` and ``jax.sharding.AxisType`` only
    exist from jax 0.5; Auto is already the default on older versions)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1) -> jax.sharding.Mesh:
    """Small mesh over host devices (tests / smoke runs)."""
    if pod > 1:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
