"""Production meshes (defined as functions: importing this module never
touches jax device state).

Single pod : (16, 16)      axes (data, model)        = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes (pod, data, model)   = 512 chips

The ``pod`` axis rides DCN (slow), ``data``/``model`` ride ICI — the
gradient-compression and ZeRO machinery in repro.train keys off these
names.
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1) -> jax.sharding.Mesh:
    """Small mesh over host devices (tests / smoke runs)."""
    if pod > 1:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"), axis_types=_auto(3)
        )
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
