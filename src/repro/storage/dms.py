"""Distributed memory storage (DMS) — the DataSpaces-backed store of S4.1.

Faithful mechanics:
  * the application domain is gridded into fixed blocks;
  * each block's coordinates are mapped to a 1-D key by a Hilbert SFC
    (Morton for rank != 2);
  * the (possibly sparse) set of SFC keys is *compacted into a virtual
    domain* (rank among sorted keys) which is range-partitioned across the
    storage servers (paper Fig. 9);
  * a put stores payload blocks on their home servers and propagates only
    *metadata* to every server's directory (paper: "data stored on a single
    server, metadata propagated" — this is why inserts are cheap and reads
    may move data);
  * a get routes per-block requests to home servers and assembles the ROI.

Every server interaction goes through the message-based :class:`Transport`
protocol (``store``/``fetch``/``put_meta``/``lookup``/``keys``/``drop``),
so the same routing logic rides either

  * :class:`InProcTransport` — thread-safe in-process shards plus a
    virtual-time bandwidth model (reproduces the paper's throughput
    experiments without wall-clock sleeps), or
  * :class:`repro.storage.net.SocketTransport` — length-prefixed frames
    over TCP to :class:`repro.storage.net.ServerProcess` hosts, the real
    multi-host deployment.

Every byte moved is accounted (puts, gets, metadata) for the benchmark
suite in both cases.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.hilbert import sfc_index, sfc_order_for
from repro.core.regions import RegionKey


@dataclasses.dataclass
class TransportStats:
    puts: int = 0
    gets: int = 0
    meta_msgs: int = 0
    bytes_put: int = 0
    bytes_get: int = 0
    bytes_meta: int = 0

    def reset(self) -> None:
        self.puts = self.gets = self.meta_msgs = 0
        self.bytes_put = self.bytes_get = self.bytes_meta = 0


@runtime_checkable
class Transport(Protocol):
    """Message API between a DMS client and its storage servers.

    One method per wire message; ``server`` is the global server id
    (0..num_servers).  Implementations route the message however they
    like (direct call, TCP frame, RDMA verb) but must preserve these
    semantics:

      * ``fetch``/``fetch_many``/``lookup`` raise ``KeyError`` when the
        server does not hold the requested data;
      * ``fetch_many`` is scatter-gather: N blocks move in ONE round-trip
        (``stats.gets`` counts round-trips, not blocks);
      * arrays round-trip bit-exact with dtype and shape preserved;
      * ``stats`` accounts every byte moved.
    """

    num_servers: int
    stats: TransportStats

    def store(
        self, server: int, key: RegionKey, block_coord: tuple, box: BoundingBox, payload: np.ndarray
    ) -> None: ...

    def fetch(self, server: int, key: RegionKey, block_coord: tuple) -> np.ndarray: ...

    def fetch_many(
        self, server: int, requests: list[tuple[RegionKey, tuple]]
    ) -> list[np.ndarray]: ...

    def put_meta(
        self, server: int, key: RegionKey, block_coord: tuple, box: BoundingBox, home: int
    ) -> None: ...

    def put_meta_batch(
        self, server: int, entries: list[tuple[RegionKey, tuple, BoundingBox, int]]
    ) -> None: ...

    def lookup(self, server: int, key: RegionKey) -> dict[tuple, tuple[BoundingBox, int]]: ...

    def keys(self, server: int) -> list[RegionKey]: ...

    def drop(self, server: int, key: RegionKey) -> None: ...

    def payload_bytes(self, server: int) -> int: ...

    def virtual_time(self) -> float: ...

    def close(self) -> None: ...


class _Server:
    """One storage server: payload blocks + a replicated metadata directory."""

    def __init__(self, sid: int) -> None:
        self.sid = sid
        self._blocks: dict[tuple, np.ndarray] = {}
        self._meta: dict[RegionKey, dict[tuple, tuple[BoundingBox, int]]] = {}
        self._lock = threading.Lock()

    def store(self, key: RegionKey, block_coord: tuple, box: BoundingBox, payload: np.ndarray) -> None:
        with self._lock:
            self._blocks[(key, block_coord)] = payload

    def fetch(self, key: RegionKey, block_coord: tuple) -> np.ndarray:
        with self._lock:
            return self._blocks[(key, block_coord)]

    def put_meta(self, key: RegionKey, block_coord: tuple, box: BoundingBox, home: int) -> None:
        with self._lock:
            self._meta.setdefault(key, {})[block_coord] = (box, home)

    def lookup(self, key: RegionKey) -> dict[tuple, tuple[BoundingBox, int]]:
        with self._lock:
            return dict(self._meta.get(key, {}))

    def keys(self) -> list[RegionKey]:
        with self._lock:
            return list(self._meta)

    def drop(self, key: RegionKey) -> None:
        with self._lock:
            self._meta.pop(key, None)
            for bk in [bk for bk in self._blocks if bk[0] == key]:
                self._blocks.pop(bk, None)

    @property
    def payload_bytes(self) -> int:
        with self._lock:
            return sum(b.nbytes for b in self._blocks.values())


# Directory entries are small fixed-size records (key hash, coords, box,
# home id); both transports charge this nominal size per metadata message.
META_MSG_BYTES = 64


class InProcTransport:
    """In-process Transport: local ``_Server`` shards + byte accounting.

    The RDMA stand-in.  ``link_bandwidth`` (bytes/s) and ``latency`` (s)
    feed a *virtual time* model used by benchmarks (no sleeping): each
    message advances a per-endpoint clock, and aggregate throughput is
    bytes / max(clock).
    """

    def __init__(self, num_servers: int, link_bandwidth: float = 6.0e9, latency: float = 2e-6):
        self.num_servers = int(num_servers)
        self.stats = TransportStats()
        self.link_bandwidth = link_bandwidth
        self.latency = latency
        self.servers = [_Server(i) for i in range(self.num_servers)]
        self._clock = [0.0] * self.num_servers
        self._lock = threading.Lock()

    # -- accounting ---------------------------------------------------------------
    def _account(self, server: int, nbytes: int, op: str) -> None:
        with self._lock:
            if op == "put":
                self.stats.puts += 1
                self.stats.bytes_put += nbytes
            elif op == "get":
                self.stats.gets += 1
                self.stats.bytes_get += nbytes
            else:
                self.stats.meta_msgs += 1
                self.stats.bytes_meta += nbytes
            self._clock[server] += self.latency + nbytes / self.link_bandwidth

    # -- Transport message API -----------------------------------------------------
    def store(self, server, key, block_coord, box, payload) -> None:
        self.servers[server].store(key, block_coord, box, payload)
        self._account(server, payload.nbytes, "put")

    def fetch(self, server, key, block_coord) -> np.ndarray:
        block = self.servers[server].fetch(key, block_coord)
        self._account(server, block.nbytes, "get")
        return block

    def fetch_many(self, server, requests) -> list[np.ndarray]:
        if not requests:
            return []
        shard = self.servers[server]
        blocks = [shard.fetch(key, coord) for key, coord in requests]
        # one message: one latency charge, one round-trip in the stats
        self._account(server, sum(b.nbytes for b in blocks), "get")
        return blocks

    def put_meta(self, server, key, block_coord, box, home) -> None:
        self.servers[server].put_meta(key, block_coord, box, home)
        if server != home:  # the home server learns the entry for free
            self._account(server, META_MSG_BYTES, "meta")

    def put_meta_batch(self, server, entries) -> None:
        for key, block_coord, box, home in entries:
            self.put_meta(server, key, block_coord, box, home)

    def lookup(self, server, key) -> dict[tuple, tuple[BoundingBox, int]]:
        return self.servers[server].lookup(key)

    def keys(self, server) -> list[RegionKey]:
        return self.servers[server].keys()

    def drop(self, server, key) -> None:
        self.servers[server].drop(key)

    def payload_bytes(self, server) -> int:
        return self.servers[server].payload_bytes

    # -- virtual time ---------------------------------------------------------------
    def virtual_time(self) -> float:
        with self._lock:
            return max(self._clock) if self._clock else 0.0

    def reset(self) -> None:
        with self._lock:
            self.stats.reset()
            self._clock = [0.0] * len(self._clock)

    def close(self) -> None:
        pass


class DistributedMemoryStorage:
    """The ``DMS`` global storage backend (StorageBackend protocol)."""

    def __init__(
        self,
        domain: BoundingBox,
        block_shape: Iterable[int],
        num_servers: int | None = None,
        *,
        name: str = "DMS",
        transport: Transport | None = None,
    ) -> None:
        self.name = name
        self.domain = domain
        self.block_shape = tuple(int(b) for b in block_shape)
        if len(self.block_shape) != domain.rank:
            raise ValueError("block_shape rank != domain rank")
        # num_servers defaults from the transport (or to 4 without one);
        # an *explicit* count must agree with the transport's fleet size
        self.transport: Transport = transport or InProcTransport(
            4 if num_servers is None else int(num_servers)
        )
        self.num_servers = self.transport.num_servers
        if (
            transport is not None
            and num_servers is not None
            and int(num_servers) != self.num_servers
        ):
            raise ValueError(
                f"num_servers={num_servers} != transport.num_servers={self.num_servers}"
            )
        # --- virtual-domain construction (paper Fig. 9) ---
        self._grid = tuple(
            -(-s // b) for s, b in zip(domain.shape, self.block_shape)
        )  # ceil-div block counts per dim
        order = sfc_order_for(max(self._grid))
        keys = sorted(
            sfc_index(order, coord) for coord in np.ndindex(*self._grid)
        )
        self._sfc_order = order
        # compaction: sfc key -> contiguous virtual rank
        self._virtual_rank = {k: i for i, k in enumerate(keys)}
        self._virtual_size = len(keys)

    @property
    def _servers(self) -> list[_Server]:
        """Local shard objects — only meaningful for in-process transports
        (tests and white-box introspection; network transports have no
        local servers)."""
        servers = getattr(self.transport, "servers", None)
        if servers is None:
            raise AttributeError(
                f"{self.name}: transport {type(self.transport).__name__} has no local servers"
            )
        return servers

    # -- routing ------------------------------------------------------------------
    def _block_coord(self, point: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(
            (p - l) // b for p, l, b in zip(point, self.domain.lo, self.block_shape)
        )

    def home_server(self, block_coord: tuple[int, ...]) -> int:
        """SFC key -> virtual rank -> range partition over servers."""
        k = sfc_index(self._sfc_order, block_coord)
        rank = self._virtual_rank[k]
        return (rank * self.num_servers) // self._virtual_size

    def _blocks_overlapping(self, box: BoundingBox) -> list[tuple[tuple[int, ...], BoundingBox]]:
        box = box.intersect(self.domain)
        lo_blk = self._block_coord(tuple(box.lo))
        hi_blk = self._block_coord(tuple(c - 1 for c in box.hi)) if not box.is_empty else lo_blk
        out = []
        for coord in np.ndindex(*[h - l + 1 for l, h in zip(lo_blk, hi_blk)]):
            bc = tuple(l + c for l, c in zip(lo_blk, coord))
            blo = tuple(
                dl + c * b for dl, c, b in zip(self.domain.lo, bc, self.block_shape)
            )
            bhi = tuple(
                min(dl + (c + 1) * b, dh)
                for dl, dh, c, b in zip(self.domain.lo, self.domain.hi, bc, self.block_shape)
            )
            blk_box = BoundingBox(blo, bhi, box.t_lo, box.t_hi)
            if blk_box.intersects(box):
                out.append((bc, blk_box))
        return out

    # -- StorageBackend protocol -----------------------------------------------------
    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        array = np.asarray(array)
        if tuple(array.shape)[: bb.rank] != bb.shape:
            raise ValueError(f"payload shape {array.shape} != bb shape {bb.shape}")
        meta: list[tuple[RegionKey, tuple, BoundingBox, int]] = []
        for bc, blk_box in self._blocks_overlapping(bb):
            part = blk_box.intersect(bb)
            if part.is_empty:
                continue
            payload = np.ascontiguousarray(array[part.local_slices(bb)])
            home = self.home_server(bc)
            self.transport.store(home, key, bc, part, payload)
            meta.append((key, bc, part, home))
        # metadata propagation to every server (cheap, paper S5.4) —
        # batched: one message per server per put, not per block, so a
        # socket transport pays N round-trips instead of blocks x N
        if meta:
            for sid in range(self.num_servers):
                self.transport.put_meta_batch(sid, meta)

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        from repro.storage.tiers import _assemble

        # any server's directory can answer the lookup; use server 0
        directory = self.transport.lookup(0, key)
        if not directory:
            raise KeyError(f"DMS: no data for {key}")
        by_home: dict[int, list[tuple[tuple, BoundingBox]]] = {}
        for bc, (box, home) in directory.items():
            if box.intersects(roi):
                by_home.setdefault(home, []).append((bc, box))
        # scatter-gather: every server's blocks move in one fetch_many
        # round-trip instead of one fetch per block (single-block reads
        # keep the plain fetch; third-party transports without fetch_many
        # also fall back to it)
        fetch_many = getattr(self.transport, "fetch_many", None)
        pieces = []
        for home in sorted(by_home):
            items = by_home[home]
            if fetch_many is not None and len(items) > 1:
                blocks = fetch_many(home, [(key, bc) for bc, _ in items])
                pieces.extend((box, blk) for (_, box), blk in zip(items, blocks))
            else:
                pieces.extend(
                    (box, self.transport.fetch(home, key, bc)) for bc, box in items
                )
        out, covered = _assemble(pieces, roi)
        if out is None:
            raise KeyError(f"DMS: {key} has no blocks intersecting {roi}")
        if not covered.all():
            raise KeyError(
                f"DMS: {key} covers only {int(covered.sum())}/{roi.volume} cells of {roi}"
            )
        return out

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        seen: dict[RegionKey, BoundingBox] = {}
        for key in self.transport.keys(0):
            if key.namespace == namespace and key.name == name:
                for box, _ in self.transport.lookup(0, key).values():
                    seen[key] = box if key not in seen else seen[key].union(box)
        return sorted(seen.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        for sid in range(self.num_servers):
            self.transport.drop(sid, key)

    def close(self) -> None:
        """Release transport resources (sockets); in-proc is a no-op."""
        self.transport.close()

    # -- stats -----------------------------------------------------------------
    def server_load(self) -> list[int]:
        """Payload bytes per server — balance check for the SFC partition."""
        return [self.transport.payload_bytes(s) for s in range(self.num_servers)]

    def aggregate_throughput(self) -> float:
        """bytes moved / transport time (paper Fig. 14 reports GB/s).

        In-proc transports answer in virtual time (the paper's modeled
        links); socket transports answer in measured wall time.
        """
        t = self.transport.virtual_time()
        total = self.transport.stats.bytes_put + self.transport.stats.bytes_get
        return total / t if t > 0 else 0.0
