"""Distributed memory storage (DMS) — the DataSpaces-backed store of S4.1.

Faithful mechanics:
  * the application domain is gridded into fixed blocks;
  * each block's coordinates are mapped to a 1-D key by a Hilbert SFC
    (Morton for rank != 2);
  * the (possibly sparse) set of SFC keys is *compacted into a virtual
    domain* (rank among sorted keys) which is range-partitioned across the
    storage servers (paper Fig. 9);
  * a put stores payload blocks on their home servers and propagates only
    *metadata* to every server's directory (paper: "data stored on a single
    server, metadata propagated" — this is why inserts are cheap and reads
    may move data);
  * a get routes per-block requests to home servers and assembles the ROI.

Servers here are thread-safe in-process shards behind a swappable
``Transport`` so the same logic can ride a real network layer on a pod.
Every byte moved is accounted (puts, gets, metadata) for the benchmark
suite; an optional virtual-time bandwidth model reproduces the paper's
throughput experiments without wall-clock sleeps.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.hilbert import sfc_index, sfc_order_for
from repro.core.regions import RegionKey


@dataclasses.dataclass
class TransportStats:
    puts: int = 0
    gets: int = 0
    meta_msgs: int = 0
    bytes_put: int = 0
    bytes_get: int = 0
    bytes_meta: int = 0

    def reset(self) -> None:
        self.puts = self.gets = self.meta_msgs = 0
        self.bytes_put = self.bytes_get = self.bytes_meta = 0


class InProcTransport:
    """In-process stand-in for the RDMA layer; counts every byte moved.

    ``link_bandwidth`` (bytes/s) and ``latency`` (s) feed a *virtual time*
    model used by benchmarks (no sleeping): each message advances a
    per-endpoint clock, and aggregate throughput is bytes / max(clock).
    """

    def __init__(self, num_servers: int, link_bandwidth: float = 6.0e9, latency: float = 2e-6):
        self.stats = TransportStats()
        self.link_bandwidth = link_bandwidth
        self.latency = latency
        self._clock = [0.0] * num_servers
        self._lock = threading.Lock()

    def account(self, server: int, nbytes: int, op: str) -> None:
        with self._lock:
            if op == "put":
                self.stats.puts += 1
                self.stats.bytes_put += nbytes
            elif op == "get":
                self.stats.gets += 1
                self.stats.bytes_get += nbytes
            else:
                self.stats.meta_msgs += 1
                self.stats.bytes_meta += nbytes
            self._clock[server] += self.latency + nbytes / self.link_bandwidth

    def virtual_time(self) -> float:
        with self._lock:
            return max(self._clock) if self._clock else 0.0

    def reset(self) -> None:
        with self._lock:
            self.stats.reset()
            self._clock = [0.0] * len(self._clock)


class _Server:
    """One storage server: payload blocks + a replicated metadata directory."""

    def __init__(self, sid: int) -> None:
        self.sid = sid
        self._blocks: dict[tuple, np.ndarray] = {}
        self._meta: dict[RegionKey, dict[tuple, tuple[BoundingBox, int]]] = {}
        self._lock = threading.Lock()

    def store(self, key: RegionKey, block_coord: tuple, box: BoundingBox, payload: np.ndarray) -> None:
        with self._lock:
            self._blocks[(key, block_coord)] = payload

    def fetch(self, key: RegionKey, block_coord: tuple) -> np.ndarray:
        with self._lock:
            return self._blocks[(key, block_coord)]

    def put_meta(self, key: RegionKey, block_coord: tuple, box: BoundingBox, home: int) -> None:
        with self._lock:
            self._meta.setdefault(key, {})[block_coord] = (box, home)

    def lookup(self, key: RegionKey) -> dict[tuple, tuple[BoundingBox, int]]:
        with self._lock:
            return dict(self._meta.get(key, {}))

    def keys(self) -> list[RegionKey]:
        with self._lock:
            return list(self._meta)

    def drop(self, key: RegionKey) -> None:
        with self._lock:
            self._meta.pop(key, None)
            for bk in [bk for bk in self._blocks if bk[0] == key]:
                self._blocks.pop(bk, None)

    @property
    def payload_bytes(self) -> int:
        with self._lock:
            return sum(b.nbytes for b in self._blocks.values())


class DistributedMemoryStorage:
    """The ``DMS`` global storage backend (StorageBackend protocol)."""

    def __init__(
        self,
        domain: BoundingBox,
        block_shape: Iterable[int],
        num_servers: int = 4,
        *,
        name: str = "DMS",
        transport: InProcTransport | None = None,
    ) -> None:
        self.name = name
        self.domain = domain
        self.block_shape = tuple(int(b) for b in block_shape)
        if len(self.block_shape) != domain.rank:
            raise ValueError("block_shape rank != domain rank")
        self.num_servers = int(num_servers)
        self.transport = transport or InProcTransport(self.num_servers)
        self._servers = [_Server(i) for i in range(self.num_servers)]
        # --- virtual-domain construction (paper Fig. 9) ---
        self._grid = tuple(
            -(-s // b) for s, b in zip(domain.shape, self.block_shape)
        )  # ceil-div block counts per dim
        order = sfc_order_for(max(self._grid))
        keys = sorted(
            sfc_index(order, coord) for coord in np.ndindex(*self._grid)
        )
        self._sfc_order = order
        # compaction: sfc key -> contiguous virtual rank
        self._virtual_rank = {k: i for i, k in enumerate(keys)}
        self._virtual_size = len(keys)

    # -- routing ------------------------------------------------------------------
    def _block_coord(self, point: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(
            (p - l) // b for p, l, b in zip(point, self.domain.lo, self.block_shape)
        )

    def home_server(self, block_coord: tuple[int, ...]) -> int:
        """SFC key -> virtual rank -> range partition over servers."""
        k = sfc_index(self._sfc_order, block_coord)
        rank = self._virtual_rank[k]
        return (rank * self.num_servers) // self._virtual_size

    def _blocks_overlapping(self, box: BoundingBox) -> list[tuple[tuple[int, ...], BoundingBox]]:
        box = box.intersect(self.domain)
        lo_blk = self._block_coord(tuple(box.lo))
        hi_blk = self._block_coord(tuple(c - 1 for c in box.hi)) if not box.is_empty else lo_blk
        out = []
        for coord in np.ndindex(*[h - l + 1 for l, h in zip(lo_blk, hi_blk)]):
            bc = tuple(l + c for l, c in zip(lo_blk, coord))
            blo = tuple(
                dl + c * b for dl, c, b in zip(self.domain.lo, bc, self.block_shape)
            )
            bhi = tuple(
                min(dl + (c + 1) * b, dh)
                for dl, dh, c, b in zip(self.domain.lo, self.domain.hi, bc, self.block_shape)
            )
            blk_box = BoundingBox(blo, bhi, box.t_lo, box.t_hi)
            if blk_box.intersects(box):
                out.append((bc, blk_box))
        return out

    # -- StorageBackend protocol -----------------------------------------------------
    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        array = np.asarray(array)
        if tuple(array.shape)[: bb.rank] != bb.shape:
            raise ValueError(f"payload shape {array.shape} != bb shape {bb.shape}")
        for bc, blk_box in self._blocks_overlapping(bb):
            part = blk_box.intersect(bb)
            if part.is_empty:
                continue
            payload = np.ascontiguousarray(array[part.local_slices(bb)])
            home = self.home_server(bc)
            self._servers[home].store(key, bc, part, payload)
            self.transport.account(home, payload.nbytes, "put")
            # metadata propagation to every server (cheap, paper S5.4)
            meta_bytes = 64
            for srv in self._servers:
                srv.put_meta(key, bc, part, home)
                if srv.sid != home:
                    self.transport.account(srv.sid, meta_bytes, "meta")

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        # any server's directory can answer the lookup; use server 0
        directory = self._servers[0].lookup(key)
        if not directory:
            raise KeyError(f"DMS: no data for {key}")
        sample = None
        out = None
        covered = 0
        for bc, (box, home) in directory.items():
            part = box.intersect(roi)
            if part.is_empty:
                continue
            block = self._servers[home].fetch(key, bc)
            self.transport.account(home, block.nbytes, "get")
            if out is None:
                sample = block
                trailing = block.shape[box.rank:]
                out = np.zeros(roi.shape + trailing, dtype=block.dtype)
            src = part.local_slices(box)
            dst = part.local_slices(roi)
            out[dst] = block[src]
            covered += part.volume
        if out is None:
            raise KeyError(f"DMS: {key} has no blocks intersecting {roi}")
        if covered < roi.volume:
            raise KeyError(
                f"DMS: {key} covers only {covered}/{roi.volume} cells of {roi}"
            )
        return out

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        seen: dict[RegionKey, BoundingBox] = {}
        for key in self._servers[0].keys():
            if key.namespace == namespace and key.name == name:
                for box, _ in self._servers[0].lookup(key).values():
                    seen[key] = box if key not in seen else seen[key].union(box)
        return sorted(seen.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        for srv in self._servers:
            srv.drop(key)

    # -- stats -----------------------------------------------------------------
    def server_load(self) -> list[int]:
        """Payload bytes per server — balance check for the SFC partition."""
        return [s.payload_bytes for s in self._servers]

    def aggregate_throughput(self) -> float:
        """bytes moved / virtual time (paper Fig. 14 reports GB/s)."""
        t = self.transport.virtual_time()
        total = self.transport.stats.bytes_put + self.transport.stats.bytes_get
        return total / t if t > 0 else 0.0
