"""Distributed memory storage (DMS) — the DataSpaces-backed store of S4.1.

Faithful mechanics:
  * the application domain is gridded into fixed blocks;
  * each block's coordinates are mapped to a 1-D key by a Hilbert SFC
    (Morton for rank != 2);
  * the (possibly sparse) set of SFC keys is *compacted into a virtual
    domain* (rank among sorted keys) which is range-partitioned across the
    storage servers (paper Fig. 9);
  * a put stores payload blocks on their home servers and propagates only
    *metadata* to every server's directory (paper: "data stored on a single
    server, metadata propagated" — this is why inserts are cheap and reads
    may move data);
  * a get routes per-block requests to home servers and assembles the ROI.

Availability (beyond the paper's single-home placement):
  * ``replication=R`` writes every payload block to its home server AND
    the next ``R-1`` servers along the SFC virtual-domain ring, skipping
    servers co-located with an already-chosen replica (shards sharing a
    process share its fate); the directory entry records the full
    replica list (``homes``), with single-``home`` entries still
    decoding (backward compatible, and the R=1 wire format is
    byte-for-byte today's);
  * directory lookups rotate over the servers instead of pinning server 0
    (every directory is a replica, so any one answers);
  * a ``TransportError`` mid-read regroups the failed server's blocks onto
    surviving replicas — with R >= 2, one dead server causes zero failed
    reads; ``delete`` best-effort-drops on every replica;
  * a ``TransportError`` mid-WRITE re-homes the block onto the next live
    server along the ring (and a failed put rolls its partial blocks
    back), so one dead server causes zero failed puts too;
  * healthy reads rotate over live replicas (``read_balance``) so a hot
    key's fetch load spreads instead of pinning its primary;
  * ``repair()`` — the anti-entropy sweep — re-replicates under-covered
    blocks and re-fills the directory of a server that rejoined empty,
    so a crash + restart converges back to R live copies of everything.

Every server interaction goes through the message-based :class:`Transport`
protocol (``store``/``fetch``/``put_meta``/``lookup``/``keys``/``drop``/
``drop_block``),
so the same routing logic rides either

  * :class:`InProcTransport` — thread-safe in-process shards plus a
    virtual-time bandwidth model (reproduces the paper's throughput
    experiments without wall-clock sleeps), or
  * :class:`repro.storage.net.SocketTransport` — length-prefixed frames
    over TCP to :class:`repro.storage.net.ServerProcess` hosts, the real
    multi-host deployment.

Every byte moved is accounted (puts, gets, metadata) for the benchmark
suite in both cases.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import threading
from typing import Iterable, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.hilbert import sfc_index, sfc_order_for
from repro.core.regions import RegionKey
from repro.storage.membership import RingView, TokenBucket, adopt_newer


class TransportError(ConnectionError):
    """A wire-level failure (server down, connection reset, bad frame).

    Lives here (not :mod:`repro.storage.net`) because the routing layer
    catches it to fail over between replicas; ``net`` re-exports it.
    """


def encode_homes(homes: Iterable[int]):
    """Directory ``homes`` field: a bare int for a single home (today's
    wire format, byte-for-byte) or a list for R-way replica sets."""
    homes = [int(s) for s in homes]
    return homes[0] if len(homes) == 1 else homes


def decode_homes(home) -> tuple[int, ...]:
    """Backward-compatible decode: single-``home`` int entries and
    ``homes`` replica lists both come back as a tuple of server ids."""
    if isinstance(home, (int, np.integer)):
        return (int(home),)
    return tuple(int(s) for s in home)


class TransportStats:
    """Per-transport traffic accounting: counters behind ONE lock.

    ``bytes_put``/``bytes_get`` count WIRE bytes — what actually crossed
    the link (compressed payloads, or just the control frame for a
    shared-memory fetch).  ``bytes_put_raw``/``bytes_get_raw`` count the
    decoded array bytes the application moved.  On a plain transport the
    two are equal; the gap is the data-plane saving, surfaced by
    ``storage_stats()``.  ``shm_gets`` counts blocks served by shared-
    memory reference instead of a socket payload.

    Same discipline as ``GatewayStats``: writers bump related counters
    together through :meth:`add` (one atomic multi-counter step), and
    snapshot readers use :meth:`as_dict` so a concurrent bump can never
    produce a torn cross-counter view (e.g. ``puts`` without its
    ``bytes_put``).  Plain attribute reads of a single counter remain
    lock-free.
    """

    _FIELDS = (
        "puts",
        "gets",
        "meta_msgs",
        "bytes_put",
        "bytes_get",
        "bytes_meta",
        "bytes_put_raw",
        "bytes_get_raw",
        "shm_gets",
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for f in self._FIELDS:
            setattr(self, f, 0)

    def add(self, **deltas: int) -> None:
        """Atomically bump several counters (one lock acquisition)."""
        with self._lock:
            for name, delta in deltas.items():
                if name not in self._FIELDS:
                    raise AttributeError(f"unknown transport counter {name!r}")
                setattr(self, name, getattr(self, name) + delta)

    def reset(self) -> None:
        with self._lock:
            for f in self._FIELDS:
                setattr(self, f, 0)

    def as_dict(self) -> dict:
        """Consistent snapshot of every counter (taken under the lock)."""
        with self._lock:
            return {f: getattr(self, f) for f in self._FIELDS}


@runtime_checkable
class Transport(Protocol):
    """Message API between a DMS client and its storage servers.

    One method per wire message; ``server`` is the global server id
    (0..num_servers).  Implementations route the message however they
    like (direct call, TCP frame, RDMA verb) but must preserve these
    semantics:

      * ``fetch``/``fetch_many``/``lookup`` raise ``KeyError`` when the
        server does not hold the requested data;
      * ``fetch_many`` is scatter-gather: N blocks move in ONE round-trip
        (``stats.gets`` counts round-trips, not blocks);
      * arrays round-trip bit-exact with dtype and shape preserved;
      * the ``home`` field of ``put_meta``/``lookup`` entries is either a
        bare server id (single home, the legacy format) or a sequence of
        replica ids — round-tripped as given, decoded via
        :func:`decode_homes`;
      * unreachable servers surface as :class:`TransportError` (never a
        hang longer than the transport's op timeout);
      * ``stats`` accounts every byte moved.
    """

    num_servers: int
    stats: TransportStats

    def store(
        self, server: int, key: RegionKey, block_coord: tuple, box: BoundingBox, payload: np.ndarray
    ) -> None: ...

    def fetch(self, server: int, key: RegionKey, block_coord: tuple) -> np.ndarray: ...

    def fetch_many(
        self, server: int, requests: list[tuple[RegionKey, tuple]]
    ) -> list[np.ndarray]: ...

    def put_meta(
        self,
        server: int,
        key: RegionKey,
        block_coord: tuple,
        box: BoundingBox,
        home: int | Sequence[int],
    ) -> None: ...

    def put_meta_batch(
        self,
        server: int,
        entries: list[tuple[RegionKey, tuple, BoundingBox, int | Sequence[int]]],
    ) -> "list[tuple] | None":
        """Returns the block coords that ALREADY had a directory entry
        on this server before the batch (the pre-image a failed put's
        rollback needs to avoid destroying an earlier incarnation), or
        None when the implementation cannot tell."""
        ...

    def lookup(
        self, server: int, key: RegionKey
    ) -> dict[tuple, tuple[BoundingBox, "int | Sequence[int]"]]: ...

    def keys(self, server: int) -> list[RegionKey]: ...

    def drop(self, server: int, key: RegionKey) -> None: ...

    def drop_block(self, server: int, key: RegionKey, block_coord: tuple) -> None: ...

    def payload_bytes(self, server: int) -> int: ...

    def join(self, server: int, sid: int, view: dict) -> "dict | None":
        """Announce to ``server`` that global shard ``sid`` joined the
        fleet under the given :class:`~repro.storage.membership.RingView`
        JSON; the server adopts the view when its epoch is newer and
        returns the view it now holds."""
        ...

    def leave(self, server: int, sid: int, view: dict, purge: bool = False) -> "dict | None":
        """Announce that ``sid`` left the fleet.  ``purge=True`` (sent
        after the rebalance sweep drained it) additionally drops the
        departed shard's remaining payload, directory, and arena slots
        when ``server`` hosts it."""
        ...

    def epoch(self, server: int) -> "dict | None":
        """The fleet view ``server`` currently holds (RingView JSON), or
        None when it has never been told one — lets a fresh client (or a
        rebalance resuming after a crash) rediscover the current epoch
        from any live server."""
        ...

    def gen(self, server: int, bump=None, want=None) -> dict:
        """Write-generation gossip (the response-cache invalidation
        signal, piggybacked on the membership plumbing): each opaque key
        token in ``bump`` increments ``server``'s per-key counter, each
        token in ``want`` reads it (missing -> 0).  Returns the touched
        tokens' current counts.  Gateways push a bump to every ring
        member on put/delete and pull the fleet max to validate cached
        responses, so any gateway's write invalidates every gateway's
        response cache."""
        ...

    def virtual_time(self) -> float: ...

    def close(self) -> None: ...


class _Server:
    """One storage server: payload blocks + a replicated metadata directory.

    Resident blocks are read-only ndarrays, or ``codec.Encoded`` blobs
    when the hosting process runs with at-rest compression.  When the
    socket server attaches a shared-memory ``arena``, ndarray blocks
    live inside it (copied in at store time, or promoted on first shm
    fetch) so co-located clients can read them without a socket payload.
    """

    def __init__(self, sid: int) -> None:
        self.sid = sid
        self._blocks: dict[tuple, object] = {}  # ndarray | codec.Encoded
        self._meta: dict[RegionKey, dict[tuple, tuple[BoundingBox, object]]] = {}
        self._lock = threading.Lock()
        self.arena = None  # optional shm.ShmArena, set by the socket server
        # blocks whose resident ndarray is an arena view: reads go through
        # _current_locked so a block the arena evicted under pressure is
        # re-homed onto the heap from the arena's saved copy (never lost,
        # never read through a recycled slot)
        self._in_arena: set[tuple] = set()
        # fleet-wide write-generation table (opaque key token -> count),
        # gossiped by the ``gen`` transport op: gateways bump it on every
        # put/delete and response caches validate against the fleet max,
        # so one gateway's write invalidates every gateway's cache.  It
        # survives clear() deliberately — a purged shard must not roll
        # a key's generation back below what clients already observed.
        self._gens: dict[str, int] = {}

    def gen(self, bump=None, want=None) -> dict[str, int]:
        """Bump-and-read the write-generation table: each token in
        ``bump`` increments, each in ``want`` reads (missing -> 0);
        returns the current count for every touched token."""
        with self._lock:
            out: dict[str, int] = {}
            for token in bump or ():
                self._gens[token] = self._gens.get(token, 0) + 1
                out[token] = self._gens[token]
            for token in want or ():
                out.setdefault(token, self._gens.get(token, 0))
            return out

    def store(
        self,
        key: RegionKey,
        block_coord: tuple,
        box: BoundingBox,
        payload,
        *,
        owned: bool = False,
    ) -> None:
        # copy on store: the caller may mutate (or have aliased) its
        # buffer after the put — resident blocks must never share memory
        # with client arrays.  ``owned=True`` skips the copy when the
        # caller hands over a private buffer (the socket server decodes
        # each frame into one; copying it again would double the memory
        # traffic of every replicated put).
        if isinstance(payload, np.ndarray):
            if not owned:
                payload = np.array(payload, copy=True)
            payload.setflags(write=False)
        with self._lock:
            bk = (key, block_coord)
            if self.arena is not None:
                handle = (self.sid, key, block_coord)
                self.arena.release(handle)  # overwrite frees the old slot
                self._in_arena.discard(bk)
                if isinstance(payload, np.ndarray) and payload.nbytes:
                    adopted = self.arena.place(handle, payload)
                    if adopted is not None:
                        payload = adopted  # arena-resident read-only view
                        self._in_arena.add(bk)
            self._blocks[bk] = payload

    def _current_locked(self, bk: tuple):
        """The live resident object for ``bk``, reclaiming it from the
        arena's eviction ledger first: an LRU-evicted block's bytes were
        copied to the heap by the arena before its slot was recycled, and
        the first read after eviction adopts that copy (the stale arena
        view must never be served once the slot can be reused).  Touches
        the arena's fetch-recency clock otherwise."""
        block = self._blocks[bk]
        if self.arena is not None and bk in self._in_arena:
            raw = self.arena.claim_or_touch((self.sid, bk[0], bk[1]))
            if raw is not None:
                if isinstance(block, np.ndarray):
                    fresh = np.frombuffer(raw, dtype=block.dtype.base, count=block.size)
                    block = fresh.reshape(block.shape)
                self._blocks[bk] = block
                self._in_arena.discard(bk)
        return block

    def fetch(self, key: RegionKey, block_coord: tuple) -> np.ndarray:
        with self._lock:
            block = self._current_locked((key, block_coord))
        if not isinstance(block, np.ndarray):
            return block.decode()  # at-rest Encoded: read-only (frombuffer over bytes)
        # read-only view: in-process clients cannot mutate the store
        # through the returned array (its base is non-writable, so even
        # setflags cannot re-enable writes)
        return block.view()

    def fetch_resident(self, key: RegionKey, block_coord: tuple):
        """The resident object itself (ndarray or ``Encoded``) — lets the
        socket server pass an at-rest blob to a codec-capable client
        without a decode/re-encode round."""
        with self._lock:
            return self._current_locked((key, block_coord))

    def arena_ref(self, key: RegionKey, block_coord: tuple):
        """``(array header, offset, nbytes)`` of the block's arena slot,
        promoting a heap-resident ndarray into the arena on first shm
        fetch.  ``None`` when the block cannot be shm-served (no arena,
        arena full, empty block, or at-rest ``Encoded``) — the caller
        falls back to a socket payload.  Raises ``KeyError`` for a
        missing block, matching ``fetch``."""
        if self.arena is None:
            return None
        with self._lock:
            bk = (key, block_coord)
            block = self._current_locked(bk)
            if not isinstance(block, np.ndarray) or block.nbytes == 0:
                return None
            handle = (self.sid, key, block_coord)
            slot = self.arena.locate(handle)
            if slot is None:
                adopted = self.arena.place(handle, block)
                if adopted is None:
                    return None
                self._blocks[bk] = adopted
                self._in_arena.add(bk)
                slot = self.arena.locate(handle)
            meta = {"shape": list(block.shape), "dtype": str(block.dtype)}
            return meta, slot[0], slot[1]

    def put_meta(
        self, key: RegionKey, block_coord: tuple, box: BoundingBox, home: int | Sequence[int]
    ) -> None:
        with self._lock:
            self._meta.setdefault(key, {})[block_coord] = (box, home)

    def lookup(self, key: RegionKey) -> dict[tuple, tuple[BoundingBox, object]]:
        with self._lock:
            return dict(self._meta.get(key, {}))

    def keys(self) -> list[RegionKey]:
        with self._lock:
            return list(self._meta)

    def drop(self, key: RegionKey) -> None:
        with self._lock:
            self._meta.pop(key, None)
            for bk in [bk for bk in self._blocks if bk[0] == key]:
                self._blocks.pop(bk, None)
                self._in_arena.discard(bk)
                if self.arena is not None:
                    self.arena.release((self.sid, bk[0], bk[1]))

    def drop_block(self, key: RegionKey, block_coord: tuple) -> None:
        """Remove ONE block's payload and directory entry (put rollback:
        a failed put must not leave orphaned bytes or phantom entries)."""
        with self._lock:
            self._blocks.pop((key, block_coord), None)
            self._in_arena.discard((key, block_coord))
            if self.arena is not None:
                self.arena.release((self.sid, key, block_coord))
            meta = self._meta.get(key)
            if meta is not None:
                meta.pop(block_coord, None)
                if not meta:
                    self._meta.pop(key, None)

    def clear(self) -> None:
        """Purge everything this shard holds — the terminal step of a
        fleet ``leave`` after the rebalance sweep drained it (payload,
        directory, and arena slots all go; the shard object stays usable
        in case the same sid later rejoins)."""
        with self._lock:
            if self.arena is not None:
                for bk in self._blocks:
                    self.arena.release((self.sid, bk[0], bk[1]))
            self._blocks.clear()
            self._meta.clear()
            self._in_arena.clear()

    @property
    def payload_bytes(self) -> int:
        with self._lock:
            return sum(b.nbytes for b in self._blocks.values())


# Directory entries are small fixed-size records (key hash, coords, box,
# home id); both transports charge this nominal size per metadata message.
META_MSG_BYTES = 64


class InProcTransport:
    """In-process Transport: local ``_Server`` shards + byte accounting.

    The RDMA stand-in.  ``link_bandwidth`` (bytes/s) and ``latency`` (s)
    feed a *virtual time* model used by benchmarks (no sleeping): each
    message advances a per-endpoint clock, and aggregate throughput is
    bytes / max(clock).
    """

    def __init__(self, num_servers: int, link_bandwidth: float = 6.0e9, latency: float = 2e-6):
        self.num_servers = int(num_servers)
        self.stats = TransportStats()
        self.link_bandwidth = link_bandwidth
        self.latency = latency
        self.servers = [_Server(i) for i in range(self.num_servers)]
        self._clock = [0.0] * self.num_servers
        self._lock = threading.Lock()
        self._removed: set[int] = set()  # sids that left the fleet
        self._view: dict | None = None  # adopted RingView JSON (highest epoch)

    # -- elastic membership --------------------------------------------------------
    def _check_removed(self, server: int) -> None:
        with self._lock:
            gone = server in self._removed
        if gone:
            raise TransportError(f"server {server} has left the fleet")

    def add_endpoint(self, endpoint=None, *, sid: "int | None" = None) -> int:
        """Grow the fleet by one shard (``endpoint`` is ignored in-proc;
        it mirrors the socket transport's signature).  Reviving a
        previously-removed ``sid`` reuses its shard object."""
        with self._lock:
            if sid is not None and sid in self._removed:
                self._removed.discard(sid)
                return sid
            if sid is None:
                sid = len(self.servers)
            while len(self.servers) <= sid:
                self.servers.append(_Server(len(self.servers)))
                self._clock.append(0.0)
            self.num_servers = len(self.servers)
            self._removed.discard(sid)
            return sid

    def remove_endpoint(self, sid: int) -> None:
        """Mark ``sid`` unreachable (the in-proc stand-in for tearing
        down a connection): subsequent ops raise TransportError."""
        with self._lock:
            self._removed.add(sid)

    def reset_liveness(self, server: int) -> None:
        """Forget any cached unreachability for ``server`` (probe-on-
        epoch-bump: a rejoining sid must not be served stale answers)."""
        with self._lock:
            self._removed.discard(server)

    def known_servers(self) -> list[int]:
        """Every sid a message could still reach — ring members AND
        draining (departed-but-unpurged) shards."""
        with self._lock:
            return [i for i in range(len(self.servers)) if i not in self._removed]

    def alive(self, server: int) -> bool:
        with self._lock:
            return server not in self._removed

    def _adopt_view(self, view: "dict | None") -> "dict | None":
        with self._lock:
            if view is not None and (
                self._view is None or int(view["epoch"]) > int(self._view["epoch"])
            ):
                self._view = dict(view)
            return None if self._view is None else dict(self._view)

    def join(self, server: int, sid: int, view: dict) -> "dict | None":
        self._check_removed(server)
        self._account(server, META_MSG_BYTES, "meta")
        return self._adopt_view(view)

    def leave(self, server: int, sid: int, view: dict, purge: bool = False) -> "dict | None":
        self._check_removed(server)
        self._account(server, META_MSG_BYTES, "meta")
        out = self._adopt_view(view)
        if purge and 0 <= sid < len(self.servers):
            self.servers[sid].clear()
        return out

    def epoch(self, server: int) -> "dict | None":
        self._check_removed(server)
        return self._adopt_view(None)

    def gen(self, server: int, bump=None, want=None) -> dict:
        self._check_removed(server)
        self._account(server, META_MSG_BYTES, "meta")
        return self.servers[server].gen(bump, want)

    # -- accounting ---------------------------------------------------------------
    def _account(self, server: int, nbytes: int, op: str) -> None:
        # in-process moves are never compressed: wire bytes == raw bytes
        if op == "put":
            self.stats.add(puts=1, bytes_put=nbytes, bytes_put_raw=nbytes)
        elif op == "get":
            self.stats.add(gets=1, bytes_get=nbytes, bytes_get_raw=nbytes)
        else:
            self.stats.add(meta_msgs=1, bytes_meta=nbytes)
        with self._lock:  # _lock guards the virtual clock, stats guard themselves
            self._clock[server] += self.latency + nbytes / self.link_bandwidth

    # -- Transport message API -----------------------------------------------------
    def store(self, server, key, block_coord, box, payload) -> None:
        self._check_removed(server)
        self.servers[server].store(key, block_coord, box, payload)
        self._account(server, payload.nbytes, "put")

    def fetch(self, server, key, block_coord) -> np.ndarray:
        self._check_removed(server)
        block = self.servers[server].fetch(key, block_coord)
        self._account(server, block.nbytes, "get")
        return block

    def fetch_many(self, server, requests) -> list[np.ndarray]:
        self._check_removed(server)
        if not requests:
            return []
        shard = self.servers[server]
        blocks = [shard.fetch(key, coord) for key, coord in requests]
        # one message: one latency charge, one round-trip in the stats
        self._account(server, sum(b.nbytes for b in blocks), "get")
        return blocks

    def put_meta(self, server, key, block_coord, box, home) -> None:
        self.servers[server].put_meta(key, block_coord, box, home)
        if server not in decode_homes(home):
            # servers holding the payload learn the entry for free
            self._account(server, META_MSG_BYTES, "meta")

    def put_meta_batch(self, server, entries) -> list[tuple]:
        shard = self.servers[server]
        existing: dict[RegionKey, dict] = {}
        had: list[tuple] = []
        for key, block_coord, box, home in entries:
            if key not in existing:
                existing[key] = shard.lookup(key)
            if tuple(block_coord) in existing[key]:
                had.append(tuple(block_coord))
            self.put_meta(server, key, block_coord, box, home)
        return had

    def lookup(self, server, key) -> dict[tuple, tuple[BoundingBox, int]]:
        self._check_removed(server)
        return self.servers[server].lookup(key)

    def keys(self, server) -> list[RegionKey]:
        self._check_removed(server)
        return self.servers[server].keys()

    def drop(self, server, key) -> None:
        self.servers[server].drop(key)

    def drop_block(self, server, key, block_coord) -> None:
        self.servers[server].drop_block(key, block_coord)
        self._account(server, META_MSG_BYTES, "meta")

    def payload_bytes(self, server) -> int:
        return self.servers[server].payload_bytes

    # -- virtual time ---------------------------------------------------------------
    def virtual_time(self) -> float:
        with self._lock:
            return max(self._clock) if self._clock else 0.0

    def reset(self) -> None:
        with self._lock:
            self.stats.reset()
            self._clock = [0.0] * len(self._clock)

    def close(self) -> None:
        pass


class DMSStats:
    """Availability accounting for the replicated routing layer.

    Lock-guarded like :class:`TransportStats`/``GatewayStats``: gateway
    workers bump these concurrently, and ``storage_stats()`` snapshots
    them through :meth:`as_dict` under the same internal lock.
    """

    _FIELDS = (
        "failover_fetches",   # blocks served by a non-primary replica (fault-driven)
        "balanced_fetches",   # blocks served by a non-primary replica (load rotation)
        "failed_servers",     # TransportErrors that rerouted a fetch group / put replica
        "empty_reroutes",     # blocks rerouted past a reachable-but-dataless replica
        "directory_retries",  # directory lookups retried past a dead/empty server
        "directory_repairs",  # coverage holes healed by a cross-directory union
        "meta_broadcast_skips",  # put_meta broadcasts dropped (dead server, R > 1)
        "delete_skips",       # best-effort drops skipped on unreachable servers
        "put_failovers",      # blocks re-homed off their ideal replica ring on put
        "put_rollbacks",      # blocks dropped by a failed put's best-effort rollback
        "repaired_blocks",    # payload copies re-replicated by repair() sweeps
        "repair_meta_fixes",  # directories re-filled by repair() sweeps
        "lost_blocks",        # repair() found blocks with no surviving replica
        "rebalanced_blocks",  # blocks migrated onto their ideal epoch-N slot
        "rebalance_copies",   # payload copies added by rebalance() sweeps
        "rebalance_trims",    # stale off-slot copies dropped by rebalance()
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for f in self._FIELDS:
            setattr(self, f, 0)

    def add(self, **deltas: int) -> None:
        """Atomically bump several counters (one lock acquisition)."""
        with self._lock:
            for name, delta in deltas.items():
                if name not in self._FIELDS:
                    raise AttributeError(f"unknown DMS counter {name!r}")
                setattr(self, name, getattr(self, name) + delta)

    def reset(self) -> None:
        with self._lock:
            for f in self._FIELDS:
                setattr(self, f, 0)

    def as_dict(self) -> dict:
        """Consistent snapshot of every counter (taken under the lock)."""
        with self._lock:
            return {f: getattr(self, f) for f in self._FIELDS}


class DistributedMemoryStorage:
    """The ``DMS`` global storage backend (StorageBackend protocol).

    ``replication=R`` (default 1) writes every payload block to its home
    server and the next ``R-1`` servers along the SFC virtual-domain
    ring; reads fail over between replicas on :class:`TransportError`, so
    any ``R-1`` simultaneous server deaths cause zero failed reads.
    Writes fail over too: a put skips unreachable replicas (the
    transport's liveness cache fails fast) and re-homes each block onto
    the next live servers along the ring, so every block still lands on
    R *distinct live* processes while any server is up — a put only
    raises when NO replica of some block can be written, and a failed
    put best-effort drops the blocks it already stored (no orphaned
    payload bytes, no phantom directory entries).  A degraded write
    (fewer than R live failure domains) is healed by :meth:`repair`, the
    anti-entropy sweep that re-replicates under-covered blocks and
    re-fills the directory of a server that rejoined empty —
    :meth:`start_auto_repair` runs it on a background interval.  Healthy
    reads rotate over live replicas (``read_balance``, on by default) so
    a hot key's fetch load spreads instead of pinning its primary.
    ``self.stats`` (:class:`DMSStats`) accounts all of it.
    """

    def __init__(
        self,
        domain: BoundingBox,
        block_shape: Iterable[int],
        num_servers: int | None = None,
        *,
        name: str = "DMS",
        transport: Transport | None = None,
        replication: int = 1,
        read_balance: bool = True,
        membership: RingView | None = None,
    ) -> None:
        self.name = name
        self.domain = domain
        self.block_shape = tuple(int(b) for b in block_shape)
        if len(self.block_shape) != domain.rank:
            raise ValueError("block_shape rank != domain rank")
        # num_servers defaults from the transport (or to 4 without one);
        # an *explicit* count must agree with the transport's fleet size
        self.transport: Transport = transport or InProcTransport(
            4 if num_servers is None else int(num_servers)
        )
        if (
            transport is not None
            and num_servers is not None
            and int(num_servers) != self.transport.num_servers
        ):
            raise ValueError(
                f"num_servers={num_servers} != transport.num_servers="
                f"{self.transport.num_servers}"
            )
        # the epoch'd ring is the single source of placement truth: the
        # genesis view reproduces the legacy frozen range partition
        # bit-exactly, so a never-resized fleet sees zero change.  The
        # reference is swapped whole on every membership change (readers
        # snapshot it once per operation; no lock needed).
        self._ring: RingView = membership or RingView.genesis(self.transport.num_servers)
        self.replication = int(replication)
        if not 1 <= self.replication <= len(self._ring.servers):
            raise ValueError(
                f"replication={replication} must be in [1, num_servers="
                f"{len(self._ring.servers)}]"
            )
        self.read_balance = bool(read_balance)
        self.stats = DMSStats()
        self._dir_rotor = itertools.count()  # rotating directory start
        self._read_rotor = itertools.count()  # per-block replica rotation
        self._repair_thread: threading.Thread | None = None
        self._repair_stop = threading.Event()
        self.rebalancing = False  # a paced sweep is in flight
        self._last_rebalance: dict | None = None
        # --- virtual-domain construction (paper Fig. 9) ---
        self._grid = tuple(
            -(-s // b) for s, b in zip(domain.shape, self.block_shape)
        )  # ceil-div block counts per dim
        order = sfc_order_for(max(self._grid))
        keys = sorted(
            sfc_index(order, coord) for coord in np.ndindex(*self._grid)
        )
        self._sfc_order = order
        # compaction: sfc key -> contiguous virtual rank
        self._virtual_rank = {k: i for i, k in enumerate(keys)}
        self._virtual_size = len(keys)

    @property
    def num_servers(self) -> int:
        """Live fleet size under the CURRENT epoch (elastic — grows on
        :meth:`add_server`, shrinks on :meth:`remove_server`)."""
        return len(self._ring.servers)

    @property
    def membership(self) -> RingView:
        """The current epoch'd ring view (immutable snapshot)."""
        return self._ring

    @property
    def epoch(self) -> int:
        return self._ring.epoch

    @property
    def _servers(self) -> list[_Server]:
        """Local shard objects — only meaningful for in-process transports
        (tests and white-box introspection; network transports have no
        local servers)."""
        servers = getattr(self.transport, "servers", None)
        if servers is None:
            raise AttributeError(
                f"{self.name}: transport {type(self.transport).__name__} has no local servers"
            )
        return servers

    # -- routing ------------------------------------------------------------------
    def _block_coord(self, point: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(
            (p - l) // b for p, l, b in zip(point, self.domain.lo, self.block_shape)
        )

    def _rank_of(self, block_coord: tuple[int, ...]) -> int:
        return self._virtual_rank[sfc_index(self._sfc_order, block_coord)]

    def home_server(self, block_coord: tuple[int, ...]) -> int:
        """SFC key -> virtual rank -> owning arc of the current ring
        epoch (the genesis epoch is bit-identical to the legacy
        ``(rank * N) // V`` range partition)."""
        return self._ring.owner(self._rank_of(block_coord), self._virtual_size)

    def replica_servers(self, block_coord: tuple[int, ...]) -> tuple[int, ...]:
        """The block's home plus the next ``replication - 1`` servers
        along the SFC virtual-domain ring (primary first), skipping
        servers co-located with an already-chosen replica.

        Co-location is read off the transport's endpoint table when it
        has one (shards packed onto one process share its fate — R-way
        replication must survive R-1 HOST deaths, not merely R-1 shard
        ids); transports without endpoints treat every server as its own
        failure domain.  When there are fewer distinct domains than R,
        the remainder fills in plain ring order (better a co-located
        replica than none).
        """
        home = self.home_server(block_coord)
        if self.replication == 1:
            return (home,)
        return tuple(self._fill_ring(block_coord, [], lambda sid: True))

    def _fill_ring(self, block_coord: tuple, chosen: list[int], take) -> list[int]:
        """THE replica placement walk, shared by ideal placement
        (:meth:`replica_servers`), write failover and repair: extend
        ``chosen`` along the SFC ring from the block's home until
        ``replication`` members — servers in distinct failure domains
        first, co-located fill-ins second (better a co-located replica
        than none).  ``take(sid)`` attempts to claim a candidate (e.g.
        actually storing the payload there) and returns success."""
        used = {self._failure_domain(s) for s in chosen}
        for colocate_ok in (False, True):
            for sid in self._ring_order(block_coord):
                if len(chosen) >= self.replication:
                    return chosen
                if sid in chosen:
                    continue
                if not colocate_ok and self._failure_domain(sid) in used:
                    continue
                if take(sid):
                    chosen.append(sid)
                    used.add(self._failure_domain(sid))
        return chosen

    def _failure_domain(self, sid: int):
        """Servers sharing an endpoint (one process hosting several
        shards) share its fate; transports without an endpoint table
        treat every server as its own failure domain."""
        endpoints = getattr(self.transport, "endpoints", None)
        return sid if endpoints is None else endpoints[sid]

    def _ring_order(self, block_coord: tuple[int, ...]) -> list[int]:
        return self._ring.walk(self._rank_of(block_coord), self._virtual_size)

    def _scan_ids(self) -> list[int]:
        """Sids worth scanning in repair/rebalance sweeps: the current
        ring members PLUS any still-reachable departed shards (a leave
        is drained by rebalance before its endpoint is torn down, so
        departed servers keep serving their blocks until migrated)."""
        ids = list(self._ring.servers)
        known = getattr(self.transport, "known_servers", None)
        if known is not None:
            have = set(ids)
            ids.extend(s for s in known() if s not in have)
        return ids

    # -- availability helpers -------------------------------------------------------
    def _alive(self, server: int) -> bool:
        """Transport liveness-cache answer; optimistic without one."""
        alive = getattr(self.transport, "alive", None)
        return True if alive is None else bool(alive(server))

    def _directory_order(self) -> list[int]:
        """Every server id, start rotated per call (directory load
        spreads over the everywhere-replicated directories, and no single
        server — least of all server 0 — is a read SPOF), with
        liveness-cached-dead servers tried last (the cache may be stale,
        so they are never skipped outright)."""
        servers = self._ring.servers
        n = len(servers)
        start = next(self._dir_rotor) % n
        order = [servers[(start + i) % n] for i in range(n)]
        return sorted(order, key=lambda s: not self._alive(s))  # stable

    def _count(self, field: str, n: int = 1) -> None:
        self.stats.add(**{field: n})

    def _lookup_any(self, key: RegionKey) -> dict[tuple, tuple[BoundingBox, object]]:
        """First NON-EMPTY directory answer over the rotated order.

        An empty answer is only trusted once a SECOND reachable server
        confirms it: a crashed server restarted on the same port rejoins
        with an empty directory, and its answer must not shadow the full
        directories the healthy servers still hold.  (Two simultaneous
        empty rejoins exceed the single-fault model; truly-missing keys
        pay 2 lookups instead of 1 — the miss path, not the hot path.)
        At replication=1 a single empty answer suffices: the meta
        broadcast is all-or-fail there, so every directory is strictly
        consistent and the store was never asked for availability —
        misses keep their exact single-lookup cost.
        """
        want_empty = 2 if self.replication > 1 else 1
        last: TransportError | None = None
        empties = 0
        empty = None
        for sid in self._directory_order():
            try:
                found = self.transport.lookup(sid, key)
            except TransportError as e:
                self._count("directory_retries")
                last = e
                continue
            if found:
                return found
            empties += 1
            empty = found
            if empties >= want_empty:
                return empty
        if empty is not None:
            return empty  # every reachable directory agrees: truly empty
        raise TransportError(
            f"{self.name}: no directory server reachable for {key} "
            f"(all {self.num_servers} down)"
        ) from last

    def _union2(self, fn, merge, what: str) -> None:
        """Merge ``fn(sid)`` answers from TWO reachable directories.

        One stale (rejoined) server's partial answer can neither hide
        entries nor shrink extents, because the second (healthy)
        directory contributes the full set — the same single-fault model
        the replica failover defends.  At replication=1 a single answer
        suffices (today's cost: the store was never asked for
        availability, and every directory is strictly consistent because
        the meta broadcast is all-or-fail).  Raises
        :class:`TransportError` when no directory is reachable at all.
        """
        want = 2 if self.replication > 1 else 1
        last: TransportError | None = None
        reachable = 0
        for sid in self._directory_order():
            try:
                found = fn(sid)
            except TransportError as e:
                self._count("directory_retries")
                last = e
                continue
            merge(found)
            reachable += 1
            if reachable >= want:
                return
        if not reachable:
            raise TransportError(
                f"{self.name}: no directory server reachable{what} "
                f"(all {self.num_servers} down)"
            ) from last

    def _broadcast(self, fn, skip_stat: str, what: str) -> None:
        """Run ``fn(sid)`` on EVERY server (writes: meta broadcast,
        drops).  At replication=1 any failure propagates — today's
        semantics; with replication a dead server is skipped (counted in
        ``skip_stat``) as long as some server acknowledged."""
        acked = 0
        last: TransportError | None = None
        servers = self._ring.servers
        for sid in servers:
            try:
                fn(sid)
                acked += 1
            except TransportError as e:
                if self.replication == 1:
                    raise
                self._count(skip_stat)
                last = e
        if not acked:
            raise TransportError(
                f"{self.name}: {what} reached no server "
                f"(all {len(servers)} down)"
            ) from last

    def _keys_any(self) -> list[RegionKey]:
        seen: dict[RegionKey, None] = {}

        def merge(found: list[RegionKey]) -> None:
            for k in found:
                seen.setdefault(k, None)

        self._union2(lambda sid: self.transport.keys(sid), merge, "")
        return list(seen)

    def _lookup_union2(self, key: RegionKey) -> dict[tuple, tuple[BoundingBox, object]]:
        union: dict[tuple, tuple[BoundingBox, object]] = {}
        self._union2(
            lambda sid: self.transport.lookup(sid, key), union.update, f" for {key}"
        )
        return union

    def _blocks_overlapping(self, box: BoundingBox) -> list[tuple[tuple[int, ...], BoundingBox]]:
        box = box.intersect(self.domain)
        lo_blk = self._block_coord(tuple(box.lo))
        hi_blk = self._block_coord(tuple(c - 1 for c in box.hi)) if not box.is_empty else lo_blk
        out = []
        for coord in np.ndindex(*[h - l + 1 for l, h in zip(lo_blk, hi_blk)]):
            bc = tuple(l + c for l, c in zip(lo_blk, coord))
            blo = tuple(
                dl + c * b for dl, c, b in zip(self.domain.lo, bc, self.block_shape)
            )
            bhi = tuple(
                min(dl + (c + 1) * b, dh)
                for dl, dh, c, b in zip(self.domain.lo, self.domain.hi, bc, self.block_shape)
            )
            blk_box = BoundingBox(blo, bhi, box.t_lo, box.t_hi)
            if blk_box.intersects(box):
                out.append((bc, blk_box))
        return out

    # -- StorageBackend protocol -----------------------------------------------------
    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        """Store the payload with write-path failover.

        Each block is stored on its ideal replica ring when every member
        is live; unreachable replicas (liveness-cache fast path, or a
        :class:`TransportError` on the store itself) are skipped and the
        block is re-homed onto the next live servers along the SFC ring,
        so it still lands on ``R`` *distinct live* failure domains while
        the fleet has that many.  The directory ``homes`` entry records
        the ACTUAL placement.  The put raises only when some block can
        be written to no replica at all (or, at replication=1, when the
        strictly-consistent metadata broadcast fails) — and then it
        best-effort drops the blocks and directory entries it INTRODUCED
        (never an existing key's previous incarnation), so a failed put
        never leaks orphaned payload bytes.
        """
        array = np.asarray(array)
        if tuple(array.shape)[: bb.rank] != bb.shape:
            raise ValueError(f"payload shape {array.shape} != bb shape {bb.shape}")
        meta: list[tuple[RegionKey, tuple, BoundingBox, object]] = []
        placed: list[tuple[int, tuple]] = []  # (server, coord) payload stored
        meta_acked: list[int] = []            # servers whose directory has the batch
        pre_image: list = []                  # coords that pre-existed (1st ack's answer)
        dead: set[int] = set()                # discovered unreachable this put
        try:
            for bc, blk_box in self._blocks_overlapping(bb):
                part = blk_box.intersect(bb)
                if part.is_empty:
                    continue
                payload = np.ascontiguousarray(array[part.local_slices(bb)])
                homes = self._store_replicated(key, bc, part, payload, dead, placed)
                meta.append((key, bc, part, encode_homes(homes)))
            # metadata propagation to every server (cheap, paper S5.4) —
            # batched: one message per server per put, not per block, so a
            # socket transport pays N round-trips instead of blocks x N.
            # With replication the broadcast tolerates dead servers (their
            # directory copy dies with them; any surviving directory
            # answers reads) as long as at least one server acknowledged.
            if meta:
                self._broadcast_meta(key, meta, meta_acked, pre_image)
        except TransportError:
            self._rollback_put(key, placed, meta_acked, [m[1] for m in meta], pre_image)
            raise

    def _try_store(
        self,
        sid: int,
        key: RegionKey,
        bc: tuple,
        part: BoundingBox,
        payload: np.ndarray,
        dead: set[int],
        placed: list[tuple[int, tuple]],
    ) -> bool:
        try:
            self.transport.store(sid, key, bc, part, payload)
        except TransportError:
            dead.add(sid)
            self._count("failed_servers")
            return False
        placed.append((sid, bc))
        return True

    def _store_replicated(
        self,
        key: RegionKey,
        bc: tuple,
        part: BoundingBox,
        payload: np.ndarray,
        dead: set[int],
        placed: list[tuple[int, tuple]],
    ) -> tuple[int, ...]:
        """Store one block on ``replication`` live servers, re-homing
        along the SFC ring past unreachable replicas.  Returns the actual
        homes (ring order, primary first when the primary is live)."""
        ideal = self.replica_servers(bc)
        stored: list[int] = []
        cache_dead: set[int] = set()

        def take(sid: int) -> bool:
            if sid in dead:
                return False
            if not self._alive(sid):
                # liveness-cache fast path: a recently-failed server is
                # skipped without paying a probe or timeout
                cache_dead.add(sid)
                return False
            return self._try_store(sid, key, bc, part, payload, dead, placed)

        self._fill_ring(bc, stored, take)
        if not stored and cache_dead:
            # the cache may be stale for EVERY replica (one blip touched
            # all endpoints): before failing the put, try the cache-dead
            # servers for real — the mirror of the read path's `or live`
            self._fill_ring(
                bc,
                stored,
                lambda sid: sid in cache_dead
                and sid not in dead
                and self._try_store(sid, key, bc, part, payload, dead, placed),
            )
        if not stored:
            raise TransportError(
                f"{self.name}: block {bc} of {key} could not be written to "
                f"ANY server (all {self.num_servers} unreachable)"
            )
        ring_pos = {s: i for i, s in enumerate(self._ring_order(bc))}
        stored.sort(key=ring_pos.__getitem__)  # same order repair() emits
        if tuple(stored) != ideal:
            self._count("put_failovers")
        return tuple(stored)

    def _broadcast_meta(
        self,
        key: RegionKey,
        meta: list[tuple[RegionKey, tuple, BoundingBox, object]],
        acked: list[int],
        pre_image: list,
    ) -> None:
        """put_meta_batch to every server, recording who acked (the
        rollback set) and the FIRST ack's pre-image (which coords already
        had entries — every directory agrees pre-put, so one answer
        stands for all).  Same tolerance as :meth:`_broadcast`:
        all-or-fail at replication=1, best-effort past dead servers
        otherwise."""
        last: TransportError | None = None
        servers = self._ring.servers
        for sid in servers:
            try:
                had = self.transport.put_meta_batch(sid, meta)
            except TransportError as e:
                if self.replication == 1:
                    raise
                self._count("meta_broadcast_skips")
                last = e
                continue
            if not acked:
                pre_image.append(
                    None if had is None else {tuple(c) for c in had}
                )
            acked.append(sid)
        if not acked:
            raise TransportError(
                f"{self.name}: metadata broadcast for {key} reached no server "
                f"(all {len(servers)} down)"
            ) from last

    def _rollback_put(
        self,
        key: RegionKey,
        placed: list[tuple[int, tuple]],
        meta_acked: list[int],
        coords: list[tuple],
        pre_image: list,
    ) -> None:
        """Best-effort undo of a failed put — but ONLY of what this put
        introduced.  Coords the key already had before the put are left
        alone: their old payload may already be overwritten and their
        directory entries replaced on acked servers, so dropping them
        would destroy the previous incarnation — a torn-but-readable key
        beats a destroyed one.  Fresh coords (the common case, and every
        coord of a brand-new key) are dropped wherever this put wrote
        payload or directory entries, so the servers return to their
        pre-put byte counts: no orphaned payloads invisible to the
        directory, no phantom entries pointing at dropped blocks.  When
        the pre-put state is unknowable (transport without a
        ``put_meta_batch`` pre-image and directories already modified),
        nothing is dropped: leak, never destroy."""
        drop_block = getattr(self.transport, "drop_block", None)
        if drop_block is None:
            return  # third-party transport without per-block drop
        if meta_acked:
            # directories were modified: only the broadcast's own
            # pre-image can tell fresh coords from pre-existing ones
            pre = pre_image[0] if pre_image else None
            if pre is None:
                return
        else:
            try:
                pre = set(self._lookup_any(key))  # directories untouched
            except TransportError:
                return
        targets = {(sid, bc) for sid, bc in placed if bc not in pre}
        for sid in meta_acked:
            for bc in coords:
                if bc not in pre:
                    targets.add((sid, bc))
        dropped = 0
        for sid, bc in sorted(targets):
            try:
                drop_block(sid, key, bc)
                dropped += 1
            except (TransportError, KeyError):
                pass  # best-effort: an unreachable server's copy dies with it
        if dropped:
            self._count("put_rollbacks", dropped)

    def _fetch_blocks(
        self, key: RegionKey, blocks: list[tuple[tuple, BoundingBox, tuple[int, ...]]]
    ) -> list[tuple[BoundingBox, np.ndarray]]:
        """Fetch every (coord, box, homes) block with replica failover.

        Scatter-gather: every server's blocks move in one fetch_many
        round-trip instead of one fetch per block (single-block reads
        keep the plain fetch; third-party transports without fetch_many
        also fall back to it).  A TransportError regroups the failed
        server's blocks onto their surviving replicas and retries, so a
        server dying mid-read never fails the read while any replica of
        each block is still up.  A remote KeyError (the server is up but
        the block is gone — a crashed host restarted empty on the same
        port) reroutes per BLOCK, so blocks the server does hold still
        serve from it.

        With ``read_balance`` (the default) the target rotates over the
        LIVE replicas per block instead of pinning ``homes[0]``, so a hot
        key's read load spreads across its replica set; non-primary
        serves on a healthy replica count as ``balanced_fetches``,
        fault-driven ones as ``failover_fetches``.  ``read_balance=False``
        restores strict primary preference.
        """
        fetch_many = getattr(self.transport, "fetch_many", None)
        pieces: list[tuple[BoundingBox, np.ndarray]] = []
        pending = list(blocks)
        dead: set[int] = set()  # TransportError: host unreachable
        missing: set[tuple[int, tuple]] = set()  # (server, coord): data gone there
        while pending:
            groups: dict[int, list[tuple[tuple, BoundingBox, tuple[int, ...]]]] = {}
            for item in pending:
                bc, _, homes = item
                live = [
                    s for s in homes if s not in dead and (s, bc) not in missing
                ]
                if not live:
                    if any((s, bc) in missing for s in homes):
                        # some replica answered and lacked the block:
                        # the data is gone, not merely unreachable
                        raise KeyError(
                            f"{self.name}: block {bc} of {key} missing from "
                            f"every reachable replica {list(homes)} (a crashed "
                            f"server rejoined empty?)"
                        )
                    raise TransportError(
                        f"{self.name}: block {bc} of {key} unreachable — every "
                        f"replica {list(homes)} failed (replication="
                        f"{self.replication}; raise it to survive more faults)"
                    )
                # the transport's liveness cache routes around known-dead
                # hosts without paying a probe; among the cache-live
                # replicas the per-block rotor spreads hot-key load (or
                # sticks to the primary with read_balance=False)
                healthy = [s for s in live if self._alive(s)] or live
                if self.read_balance and len(healthy) > 1:
                    target = healthy[next(self._read_rotor) % len(healthy)]
                else:
                    target = healthy[0]
                groups.setdefault(target, []).append(item)
            pending = []
            for server in sorted(groups):
                items = groups[server]
                try:
                    fetched: list | None = None
                    if fetch_many is not None and len(items) > 1:
                        try:
                            fetched = list(
                                fetch_many(server, [(key, bc) for bc, _, _ in items])
                            )
                        except KeyError:
                            # one absent member poisons the whole gather:
                            # degrade to per-block fetches so only the
                            # genuinely missing blocks fail over
                            fetched = None
                    if fetched is None:
                        fetched = []
                        for bc, _, _ in items:
                            try:
                                fetched.append(self.transport.fetch(server, key, bc))
                            except KeyError:
                                fetched.append(None)
                                missing.add((server, bc))
                                self._count("empty_reroutes")
                except TransportError:
                    dead.add(server)
                    self._count("failed_servers")
                    pending.extend(items)  # pieces not yet appended: no dupes
                    continue
                for (bc, box, homes), blk in zip(items, fetched):
                    if blk is None:
                        pending.append((bc, box, homes))
                    else:
                        if server != homes[0]:
                            # non-primary serve: fault failover when the
                            # primary is dead/dataless, balance rotation
                            # when it was healthy and we spread anyway
                            if (
                                homes[0] in dead
                                or (homes[0], bc) in missing
                                or not self._alive(homes[0])
                            ):
                                self._count("failover_fetches")
                            else:
                                self._count("balanced_fetches")
                        pieces.append((box, blk))
        return pieces

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        from repro.storage.tiers import _assemble

        # any server's directory can answer the lookup: rotate + fail
        # over instead of pinning server 0 (the old single point of
        # failure for every read on a real fleet)
        directory = self._lookup_any(key)
        if not directory:
            raise KeyError(f"DMS: no data for {key}")
        blocks = [
            (bc, box, decode_homes(homes))
            for bc, (box, homes) in directory.items()
            if box.intersects(roi)
        ]
        pieces = self._fetch_blocks(key, blocks)
        out, covered = _assemble(pieces, roi)
        if (out is None or not covered.all()) and self.replication > 1:
            # the answering directory may have been a rejoined server's
            # partial one (it received only post-rejoin broadcasts):
            # before failing, corroborate with a two-directory union —
            # under the single-fault model at most one directory is
            # stale, so two reachable answers recover the full entry set
            # — and fetch only what the fast lookup missed (the pieces
            # already in hand stay: no double transfer).  Gated on
            # replication > 1: at R=1 an under-covered read keeps
            # today's exact cost (the gateway's window-hole fallback and
            # TieredStore's cross-tier probes raise KeyError routinely
            # and must not pay extra round-trips for availability the
            # store was never asked for)
            union = self._lookup_union2(key)
            have = {bc for bc, _, _ in blocks}
            extra = [
                (bc, box, decode_homes(homes))
                for bc, (box, homes) in union.items()
                if bc not in have and box.intersects(roi)
            ]
            if extra:
                self._count("directory_repairs")
                pieces.extend(self._fetch_blocks(key, extra))
                out, covered = _assemble(pieces, roi)
        if out is None:
            raise KeyError(f"DMS: {key} has no blocks intersecting {roi}")
        if not covered.all():
            raise KeyError(
                f"DMS: {key} covers only {int(covered.sum())}/{roi.volume} cells of {roi}"
            )
        return out

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        # directories are everywhere-replicated: any reachable server
        # answers.  Both the key list and the per-key extents union two
        # directories, so a rejoined server's partial directory can
        # neither hide a key nor shrink its reported box (callers like
        # TieredStore._assemble_across_tiers size their reads off it)
        seen: dict[RegionKey, BoundingBox] = {}
        for key in self._keys_any():
            if key.namespace == namespace and key.name == name:
                for box, _ in self._lookup_union2(key).values():
                    seen[key] = box if key not in seen else seen[key].union(box)
        return sorted(seen.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        # with replication, best-effort on every server (an unreachable
        # server's copies usually die with it, and a restarted server
        # comes back empty) as long as SOME server acked; at R=1 a failed
        # drop propagates — today's behavior, and silently leaving the
        # only copy behind would resurrect the key once the server heals
        self._broadcast(
            lambda sid: self.transport.drop(sid, key),
            "delete_skips",
            f"delete of {key}",
        )

    # -- anti-entropy repair ---------------------------------------------------------
    def repair(self) -> dict:
        """One anti-entropy sweep: converge every block back to ``R``
        live copies and every reachable directory back to the full entry
        set.

        Walks the union directory over every reachable server.  A
        recorded replica "holds" a block iff its OWN directory still has
        the entry (payload and directory die together on a crash, and a
        server that rejoined empty on the same port has neither) — so an
        under-replicated block is fetched once from a surviving holder
        and re-stored onto the next live servers along the SFC ring
        (distinct failure domains first) until ``R`` copies exist again.
        Directories that lost entries (the rejoined server's) are
        re-filled with one ``put_meta_batch`` per key per server.  All
        best-effort: a concurrent put wins any race at the directory (at
        worst the next sweep re-converges), and a block with NO surviving
        holder is counted ``lost_blocks`` — replication is availability,
        not durability.

        Returns a report dict: ``scanned`` (block entries examined),
        ``repaired`` (payload copies added), ``meta_fixes`` (per-server
        directory entries re-sent), ``lost`` (blocks beyond healing),
        ``unreachable`` (servers skipped).
        """
        scan = self._scan_ids()
        members = set(self._ring.servers)
        reachable: list[int] = []
        dirs: dict[int, dict[RegionKey, dict]] = {}
        keys: set[RegionKey] = set()
        for sid in scan:
            try:
                ks = self.transport.keys(sid)
            except TransportError:
                continue
            reachable.append(sid)
            dirs[sid] = {}
            keys.update(ks)
        report = {
            "scanned": 0,
            "repaired": 0,
            "meta_fixes": 0,
            "lost": 0,
            "unreachable": len(scan) - len(reachable),
        }
        dead: set[int] = set()
        for key in sorted(keys):
            # union directory for this key over every reachable server
            entries: dict[tuple, tuple[BoundingBox, set[int]]] = {}
            for sid in reachable:
                try:
                    found = self.transport.lookup(sid, key)
                except TransportError:
                    dead.add(sid)
                    continue
                dirs[sid][key] = found
                for bc, (box, h) in found.items():
                    prev = entries.get(bc)
                    homes = prev[1] if prev else set()
                    homes.update(decode_homes(h))
                    entries[bc] = (box, homes)
            final: dict[tuple, tuple[BoundingBox, tuple[int, ...]]] = {}
            for bc, (box, candidates) in sorted(entries.items()):
                report["scanned"] += 1
                ring_pos = {s: i for i, s in enumerate(self._ring_order(bc))}
                # departed-but-draining holders sort after every ring
                # member (they are valid fetch sources, never targets)
                rank_of = lambda s: ring_pos.get(s, len(ring_pos) + s)  # noqa: E731
                holders = sorted(
                    (
                        s
                        for s in candidates
                        if s in dirs and s not in dead and bc in dirs[s].get(key, {})
                    ),
                    key=rank_of,
                )
                homes = list(holders)
                if len(holders) < self.replication and holders:
                    payload = None
                    for src in list(holders):
                        try:
                            payload = self.transport.fetch(src, key, bc)
                            break
                        except (TransportError, KeyError):
                            homes.remove(src)
                    if payload is not None:
                        homes = self._restore_copies(
                            key, bc, box, payload, homes, dead, report
                        )
                if not homes:
                    report["lost"] += 1
                    self._count("lost_blocks")
                    continue
                final[bc] = (box, tuple(sorted(homes, key=rank_of)))
            # directory convergence: re-send the full entry set to every
            # reachable ring member that is missing entries or has stale
            # homes (draining departed servers keep their old directory)
            for sid in reachable:
                if sid in dead or sid not in members:
                    continue
                have = dirs[sid].get(key, {})
                batch = [
                    (key, bc, box, encode_homes(homes))
                    for bc, (box, homes) in sorted(final.items())
                    if bc not in have or decode_homes(have[bc][1]) != homes
                ]
                if not batch:
                    continue
                try:
                    self.transport.put_meta_batch(sid, batch)
                except TransportError:
                    dead.add(sid)
                    continue
                report["meta_fixes"] += len(batch)
        if report["repaired"]:
            self._count("repaired_blocks", report["repaired"])
        if report["meta_fixes"]:
            self._count("repair_meta_fixes", report["meta_fixes"])
        return report

    def _restore_copies(
        self,
        key: RegionKey,
        bc: tuple,
        box: BoundingBox,
        payload: np.ndarray,
        homes: list[int],
        dead: set[int],
        report: dict,
    ) -> list[int]:
        """Store the fetched payload on live non-holders along the ring
        until ``replication`` copies exist (distinct domains first).  A
        liveness-cache-dead candidate is simply skipped — unlike the put
        path there is no try-anyway fallback, because the sweep is
        periodic: a stale cache costs one interval, not a failed op."""

        def take(sid: int) -> bool:
            if sid in dead or not self._alive(sid):
                return False
            try:
                self.transport.store(sid, key, bc, box, payload)
            except TransportError:
                dead.add(sid)
                return False
            report["repaired"] += 1
            return True

        return self._fill_ring(bc, homes, take)

    def start_auto_repair(self, interval: float) -> None:
        """Run :meth:`repair` every ``interval`` seconds on a daemon
        thread until :meth:`stop_auto_repair` / :meth:`close`.  A sweep
        that finds the whole fleet unreachable just waits for the next
        tick."""
        if interval <= 0:
            raise ValueError(f"repair interval must be positive, got {interval}")
        if self._repair_thread is not None:
            raise RuntimeError(f"{self.name}: auto-repair already running")
        self._repair_stop = threading.Event()

        def loop() -> None:
            while not self._repair_stop.wait(interval):
                try:
                    self.repair()
                except TransportError:
                    pass  # fleet-wide outage: retry on the next tick

        self._repair_thread = threading.Thread(
            target=loop, daemon=True, name=f"{self.name}-repair"
        )
        self._repair_thread.start()

    def stop_auto_repair(self) -> None:
        thread = self._repair_thread
        if thread is None:
            return
        self._repair_stop.set()
        thread.join(timeout=10.0)
        self._repair_thread = None

    def close(self) -> None:
        """Stop the repair thread and release transport resources
        (sockets); in-proc transports are a no-op."""
        self.stop_auto_repair()
        self.transport.close()

    # -- elastic membership & rebalancing ---------------------------------------
    def _announce(self, op: str, sid: int, view: dict) -> None:
        """Best-effort push of a new epoch to every ring member: a
        membership change must never block on a dead listener —
        stragglers catch up from any peer via ``epoch`` + adopt-newer."""
        for target in self._ring.servers:
            try:
                if op == "join":
                    self.transport.join(target, sid, view)
                else:
                    self.transport.leave(target, sid, view, False)
            except TransportError:
                continue

    def sync_membership(self) -> RingView:
        """Adopt the newest epoch any reachable ring member holds (a
        fresh client, or a rebalance resuming after a crash, rediscovers
        the fleet from any live server)."""
        best = self._ring
        for sid in list(best.servers):
            try:
                got = self.transport.epoch(sid)
            except TransportError:
                continue
            if got is not None:
                best = adopt_newer(best, RingView.from_json(got))
        self._ring = best
        return best

    @staticmethod
    def _gen_token(key: RegionKey) -> str:
        """Opaque wire token for a key's fleet generation counter."""
        return "\x1f".join(
            (
                key.namespace,
                key.name,
                getattr(key.elem_type, "name", str(key.elem_type)),
                str(key.timestamp),
                str(key.version),
            )
        )

    def push_generation(self, key: RegionKey) -> int:
        """Bump ``key``'s fleet write-generation on every reachable ring
        member (best-effort, like :meth:`_announce`: a write must never
        block on a dead listener) and return the highest count any
        member now holds.  Called by a gateway after a put/delete so
        every *other* gateway's response cache sees the key move."""
        token = self._gen_token(key)
        best = 0
        for sid in self._ring.servers:
            try:
                got = self.transport.gen(sid, bump=[token])
            except TransportError:
                continue
            best = max(best, int(got.get(token, 0)))
        return best

    def pull_generation(self, key: RegionKey) -> int:
        """The fleet-wide write generation of ``key``: the max over every
        reachable ring member (members can lag — a bump may have missed
        a then-dead server — but the member holding the max is also
        bumped by every push, so the max is monotone per write)."""
        token = self._gen_token(key)
        best = 0
        for sid in self._ring.servers:
            try:
                got = self.transport.gen(sid, want=[token])
            except TransportError:
                continue
            best = max(best, int(got.get(token, 0)))
        return best

    def add_server(self, endpoint=None, *, sid: "int | None" = None) -> int:
        """Grow the fleet live: register the endpoint with the
        transport, bump the ring epoch (every incumbent donates an equal
        arc slice to the newcomer — minimal remap), clear any stale-dead
        liveness answer for the sid (a leave/rejoin on the same port
        within the backoff window must be probed, not assumed dead), and
        announce the new view fleet-wide.  Blocks the newcomer now owns
        migrate on the next :meth:`rebalance`; reads keep following the
        directory's recorded homes meanwhile, so nothing fails in
        between.  Returns the new server id."""
        add_ep = getattr(self.transport, "add_endpoint", None)
        if add_ep is not None:
            sid = add_ep(endpoint, sid=sid)
        elif sid is None:
            raise ValueError(
                f"{self.name}: transport {type(self.transport).__name__} cannot "
                f"add endpoints; pass sid= explicitly"
            )
        ring = self._ring.join(sid)
        self._ring = ring  # atomic whole-object swap; readers snapshot per-op
        reset = getattr(self.transport, "reset_liveness", None)
        if reset is not None:
            reset(sid)
        self._announce("join", sid, ring.to_json())
        return int(sid)

    def remove_server(
        self,
        sid: int,
        *,
        rebalance: bool = True,
        pacer: "TokenBucket | None" = None,
        purge: bool = True,
    ) -> dict:
        """Shrink the fleet live.  The sid leaves the ring first (no new
        writes land on it), the new epoch is announced, and a rebalance
        sweep drains its blocks onto the survivors — the departed server
        keeps serving reads for blocks the directory still homes on it
        until each one has migrated, so a paced drain loses no ops.
        Its payload is purged and its endpoint torn down only after a
        CLEAN drain: the sweep completed without losing a block AND no
        reachable directory still homes anything on the sid.  A partial
        migration (an ideal target down mid-sweep) deliberately keeps
        the departed copy recorded so redundancy never shrinks — the
        purge then defers rather than destroy a copy the directory still
        points at; ``report["purged"]`` says which way it went, and
        calling :meth:`remove_server` again (idempotent once the sid has
        left the ring) finishes a deferred drain.  ``rebalance=False``
        defers the whole drain (run :meth:`rebalance` later; the purge
        is skipped too so the data survives).  Shrinking the ring below
        ``replication`` servers is refused — it would silently degrade
        every block below R copies.  Returns the rebalance report."""
        sid = int(sid)
        if sid in self._ring.servers:
            if len(self._ring.servers) - 1 < self.replication:
                raise ValueError(
                    f"{self.name}: removing server {sid} would leave "
                    f"{len(self._ring.servers) - 1} servers for "
                    f"replication={self.replication}; lower replication first"
                )
            ring = self._ring.leave(sid)
            self._ring = ring
            self._announce("leave", sid, ring.to_json())
        # else: the sid already left — a retry finishing a deferred purge
        report: dict = {}
        if rebalance:
            report = self.rebalance(pacer=pacer)
            drained = (
                bool(report["complete"])
                and report["lost"] == 0
                and not self._departed_still_homed(sid)
            )
            report["drained"] = drained
            report["purged"] = False
            if purge and drained:
                try:
                    self.transport.leave(sid, sid, self._ring.to_json(), True)
                except TransportError:
                    pass  # already dead: its bytes died with it
                rm = getattr(self.transport, "remove_endpoint", None)
                if rm is not None:
                    rm(sid)
                report["purged"] = True
        return report

    def _departed_still_homed(self, sid: int) -> bool:
        """True while any reachable directory (the departed shard's own
        included) still records ``sid`` as a home: some block's payload
        may live only there, so purging would destroy the last copy (at
        R=1) or silently drop redundancy below R.  The references clear
        on a later :meth:`rebalance` once the blocked targets return."""
        for src in dict.fromkeys([sid, *self._ring.servers]):
            try:
                for key in self.transport.keys(src):
                    for _bc, (_box, h) in self.transport.lookup(src, key).items():
                        if sid in decode_homes(h):
                            return True
            except TransportError:
                continue
        return False

    def rebalance(
        self,
        *,
        pacer: "TokenBucket | None" = None,
        max_blocks: "int | None" = None,
    ) -> dict:
        """One paced rebalance sweep: migrate every block whose ideal
        placement changed since it was written onto its ideal ring slot
        under the CURRENT epoch.

        Built on the repair() machinery: the union directory is walked,
        a recorded replica "holds" a block iff its own directory still
        has the entry, and per block the sweep (1) stores the payload on
        the ideal servers that lack it, (2) re-broadcasts the directory
        entry with ``homes`` = the ideal set to every ring member, and
        only then (3) trims the now-off-slot copies — so a read at ANY
        point mid-sweep finds directory homes whose servers still hold
        payload (zero failed ops during a drain).  SFC arc donation
        makes the migration minimal: only blocks whose owning arc
        changed hands move, ~K/N per membership change.

        ``pacer`` (a :class:`TokenBucket`) charges one token per
        migrated block, yielding to foreground traffic; ``max_blocks``
        bounds one call (``complete=False`` in the report — call again
        to resume; the sweep is idempotent, so a crash mid-sweep costs
        nothing but re-scanning).  Stale copies are trimmed only once
        the full ideal set holds the block; a partial migration keeps
        the old holders recorded and lets the next sweep finish.
        """
        ring = self._ring
        report = {
            "epoch": ring.epoch,
            "ring_checksum": ring.checksum(),
            "scanned": 0,
            "migrated": 0,
            "copies_added": 0,
            "trimmed": 0,
            "lost": 0,
            "unreachable": 0,
            "paced_wait_s": 0.0,
            "complete": True,
        }
        self.rebalancing = True
        try:
            scan = self._scan_ids()
            members = list(ring.servers)
            member_set = set(members)
            reachable: list[int] = []
            dirs: dict[int, dict[RegionKey, dict]] = {}
            keys: set[RegionKey] = set()
            for sid in scan:
                try:
                    ks = self.transport.keys(sid)
                except TransportError:
                    continue
                reachable.append(sid)
                dirs[sid] = {}
                keys.update(ks)
            report["unreachable"] = len(scan) - len(reachable)
            dead: set[int] = set()
            budget = None if max_blocks is None else int(max_blocks)
            for key in sorted(keys):
                entries: dict[tuple, tuple[BoundingBox, set[int]]] = {}
                for sid in reachable:
                    try:
                        found = self.transport.lookup(sid, key)
                    except TransportError:
                        dead.add(sid)
                        continue
                    dirs[sid][key] = found
                    for bc, (box, h) in found.items():
                        prev = entries.get(bc)
                        homes = prev[1] if prev else set()
                        homes.update(decode_homes(h))
                        entries[bc] = (box, homes)
                changed: list[tuple[tuple, BoundingBox, tuple[int, ...]]] = []
                trims: list[tuple[int, tuple, BoundingBox, tuple[int, ...]]] = []
                for bc, (box, candidates) in sorted(entries.items()):
                    report["scanned"] += 1
                    ideal = self.replica_servers(bc)
                    holders = [
                        s
                        for s in candidates
                        if s in dirs and s not in dead and bc in dirs[s].get(key, {})
                    ]
                    need = [s for s in ideal if s not in holders]
                    stale = [s for s in holders if s not in ideal]
                    if not need and not stale:
                        # payload already ideal; converge any member
                        # directory still recording pre-epoch homes
                        for sid in members:
                            have = dirs.get(sid, {}).get(key, {})
                            if sid in dead or sid not in dirs:
                                continue
                            if bc not in have or decode_homes(have[bc][1]) != ideal:
                                changed.append((bc, box, ideal))
                                break
                        continue
                    if budget is not None and report["migrated"] >= budget:
                        report["complete"] = False
                        continue
                    if not holders:
                        report["lost"] += 1
                        self._count("lost_blocks")
                        continue
                    if pacer is not None:
                        report["paced_wait_s"] += pacer.take(1.0)
                    payload = None
                    sources = [s for s in ideal if s in holders] + [
                        s for s in holders if s not in ideal
                    ]
                    for src in sources:
                        try:
                            payload = self.transport.fetch(src, key, bc)
                            break
                        except (TransportError, KeyError):
                            continue
                    if payload is None:
                        report["lost"] += 1
                        self._count("lost_blocks")
                        continue
                    placed = [s for s in ideal if s in holders]
                    added = 0
                    for dst in need:
                        if dst in dead:
                            continue
                        try:
                            self.transport.store(dst, key, bc, box, payload)
                            placed.append(dst)
                            added += 1
                        except TransportError:
                            dead.add(dst)
                    final = tuple(s for s in ideal if s in placed)
                    report["migrated"] += 1
                    report["copies_added"] += added
                    if len(final) == len(ideal):
                        changed.append((bc, box, final))
                        trims.extend((s, bc, box, final) for s in stale)
                    else:
                        # partial migration (some ideal target is down):
                        # keep every live holder recorded so redundancy
                        # never shrinks; the next sweep finishes the move
                        keep = tuple(dict.fromkeys(list(final) + stale))
                        changed.append((bc, box, keep or tuple(holders)))
                # (2) directory convergence BEFORE any trim: every member
                # must point at servers that hold payload at all times
                if changed:
                    batch = [
                        (key, bc, box, encode_homes(h)) for bc, box, h in changed
                    ]
                    for sid in members:
                        if sid in dead or sid not in dirs:
                            continue
                        try:
                            self.transport.put_meta_batch(sid, batch)
                        except TransportError:
                            dead.add(sid)
                # (3) trim the off-slot copies; drop_block also removes
                # that server's directory entry, so ring members get the
                # entry re-sent (directories stay complete everywhere)
                for s, bc, box, h in trims:
                    try:
                        self.transport.drop_block(s, key, bc)
                        report["trimmed"] += 1
                    except (TransportError, KeyError):
                        continue
                    if s in member_set:
                        try:
                            self.transport.put_meta(s, key, bc, box, encode_homes(h))
                        except TransportError:
                            dead.add(s)
            if report["migrated"] or report["trimmed"]:
                self.stats.add(
                    rebalanced_blocks=report["migrated"],
                    rebalance_copies=report["copies_added"],
                    rebalance_trims=report["trimmed"],
                )
            report["directory_checksums"] = self.directory_checksums()
            agreeing = {
                c for c in report["directory_checksums"].values() if c is not None
            }
            report["directories_agree"] = len(agreeing) <= 1
            self._last_rebalance = report
        finally:
            self.rebalancing = False
        return report

    def directory_checksums(self) -> dict:
        """Canonical digest of each ring member's directory (keys,
        block coords, extents, homes).  When every member answers the
        same checksum the directories agree byte-for-byte — the
        payload/directory-divergence tripwire the rebalance report and
        operator dashboards read."""
        out: dict[int, "str | None"] = {}
        for sid in self._ring.servers:
            try:
                entries = []
                for key in sorted(self.transport.keys(sid)):
                    found = self.transport.lookup(sid, key)
                    for bc, (box, h) in sorted(found.items()):
                        entries.append(
                            [
                                str(key),
                                [int(c) for c in bc],
                                [int(c) for c in box.lo],
                                [int(c) for c in box.hi],
                                list(decode_homes(h)),
                            ]
                        )
                blob = json.dumps(entries, separators=(",", ":"))
                out[sid] = hashlib.sha256(blob.encode()).hexdigest()[:12]
            except TransportError:
                out[sid] = None
        return out

    def rebalance_stats(self) -> dict:
        """Operator snapshot for ``storage_stats()["rebalance"]``: the
        current epoch + ring checksum, whether a sweep is in flight, and
        the last sweep's full report (incl. per-member directory
        checksums captured at its end)."""
        ring = self._ring
        return {
            "epoch": ring.epoch,
            "servers": list(ring.servers),
            "ring_checksum": ring.checksum(),
            "rebalancing": self.rebalancing,
            "last_sweep": self._last_rebalance,
        }

    # -- stats -----------------------------------------------------------------
    def server_load(self, *, by_role: bool = False) -> "list[int] | dict":
        """Payload bytes per server.

        The plain list is PHYSICAL bytes — at ``replication=R`` it
        includes every replica copy, so it measures capacity use (and the
        ~R× write amplification), not SFC partition balance.  With
        ``by_role=True`` the physical bytes are split by directory role:
        ``{"total", "primary", "replica"}`` lists, attributing each
        server's bytes proportionally to the block VOLUMES the union
        directory records it as primary (``homes[0]``) vs replica for —
        exact whenever a server's blocks share one element size (the
        usual case).  Balance checks for the SFC range partition must use
        the ``primary`` view at R > 1.
        """
        ring = self._ring
        cap = max(ring.servers) + 1  # lists stay sid-indexed (sparse after a leave)
        total = [0] * cap
        for s in ring.servers:
            try:
                total[s] = self.transport.payload_bytes(s)
            except TransportError:
                total[s] = 0
        if not by_role:
            return total
        prim_vol = [0] * cap
        repl_vol = [0] * cap
        for key in self._keys_any():
            for bc, (box, h) in self._lookup_union2(key).items():
                homes = decode_homes(h)
                if homes[0] < cap:
                    prim_vol[homes[0]] += box.volume
                for sid in homes[1:]:
                    if sid < cap:
                        repl_vol[sid] += box.volume
        primary = []
        for sid in range(cap):
            vol = prim_vol[sid] + repl_vol[sid]
            primary.append(total[sid] * prim_vol[sid] // vol if vol else 0)
        return {
            "total": total,
            "primary": primary,
            "replica": [t - p for t, p in zip(total, primary)],
        }

    def aggregate_throughput(self) -> float:
        """bytes moved / transport time (paper Fig. 14 reports GB/s).

        In-proc transports answer in virtual time (the paper's modeled
        links); socket transports answer in measured wall time.
        """
        t = self.transport.virtual_time()
        total = self.transport.stats.bytes_put + self.transport.stats.bytes_get
        return total / t if t > 0 else 0.0
