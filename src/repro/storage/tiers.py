"""Tiered staging: policy-driven RAM -> DISK -> DMS storage hierarchy.

The paper's container "enables different data management strategies and
data I/O implementations, while providing a homogeneous, unified
interface" (§4, Fig. 8); the hierarchical-pipelines companion work
(arXiv:1209.3332) shows that staging data in the right memory layer
dominates end-to-end throughput.  :class:`TieredStore` composes the
existing siloed backends into one automatic hierarchy behind the same
``StorageBackend`` protocol, so any pipeline swaps it in through
``STORAGE.register(...)`` with zero call-site changes.

Mechanics
---------
* **Read-through + promotion** — a ``get`` is served from the fastest
  tier holding the key; repeated reads (``promote_after``) promote the
  region one tier up (towards RAM).
* **Capacity-triggered demotion** — when a bounded tier fills up, LRU
  victims are *spilled* to the next tier down (optionally re-blocked at
  ROI granularity via the placement policy), never dropped.
* **Write policies** — ``write_through`` copies every put to the bottom
  (durable) tier synchronously; ``write_back`` acknowledges after the
  target tier and lets a background flusher thread move the bytes down;
  ``lazy`` keeps data in its placed tier until eviction or ``drain()``
  pushes it down.  ``flush()``/``drain()`` provide checkpoint
  consistency for the deferred policies.
* **Placement hook** — a :class:`~repro.storage.placement.PlacementPolicy`
  pins namespaces to tiers, applies size/dtype thresholds, and sets the
  spill granularity.
* **Locality** — ``locality(key)`` names the fastest tier holding the
  key; the runtime scheduler uses it to refine DL transfer-cost
  estimates (memory-resident data is cheap, DMS-resident data charges
  the modeled network cost).
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from typing import Iterable, Sequence

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey, StorageBackend
from repro.storage.placement import Placement, PlacementPolicy

# Per-tier staging bandwidth defaults (bytes/s) used by the runtime to
# turn a locality answer into a transfer-cost estimate.  Keys are the
# conventional tier names produced by :meth:`TieredStore.standard`.
TIER_BANDWIDTH: dict[str, float] = {
    "MEM": 2.0e10,  # host memcpy
    "DISK": 1.2e9,  # matches DiskCostModel.disk_bandwidth
    "DMS": 6.0e9,  # matches InProcTransport.link_bandwidth
}


def _assemble(
    pieces: Iterable[tuple[BoundingBox, np.ndarray]],
    roi: BoundingBox,
) -> tuple[np.ndarray | None, "np.ndarray | None"]:
    """Overlay (bb, array) pieces (each array spanning its bb) onto an
    ROI-shaped output.  Later pieces win on overlap — coverage is a
    boolean mask, so overlapping pieces are never double-counted.
    Returns (out, covered); out is None when nothing intersects.
    """
    out = None
    covered = None
    for bb, arr in pieces:
        part = bb.intersect(roi)
        if part.is_empty:
            continue
        if out is None:
            trailing = arr.shape[bb.rank:]
            out = np.zeros(roi.shape + trailing, dtype=arr.dtype)
            covered = np.zeros(roi.shape, dtype=bool)
        out[part.local_slices(roi)] = arr[part.local_slices(bb)]
        covered[part.local_slices(roi)] = True
    return out, covered


@dataclasses.dataclass
class TierStats:
    """Per-tier accounting (hits, promotions, demotions, bytes moved)."""

    hits: int = 0
    misses: int = 0
    puts: int = 0
    promotions: int = 0
    demotions: int = 0
    flushes: int = 0
    flush_failures: int = 0  # drain() could not materialize the key
    bytes_in: int = 0
    bytes_out: int = 0
    bytes_promoted: int = 0
    bytes_demoted: int = 0
    bytes_flushed: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class MemoryTier:
    """Capacity-friendly in-process tier (StorageBackend protocol).

    Chunks are kept exactly as written; ``get`` assembles the requested
    ROI from every intersecting chunk (same contract as DISK/DMS).  The
    :class:`TieredStore` drives eviction, so this class only tracks
    resident bytes.
    """

    def __init__(self, *, name: str = "MEM") -> None:
        self.name = name
        self._chunks: dict[RegionKey, list[tuple[BoundingBox, np.ndarray]]] = {}
        self._lock = threading.Lock()

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        arr = np.asarray(array)
        if tuple(arr.shape)[: bb.rank] != bb.shape:
            raise ValueError(f"payload shape {arr.shape} != bb shape {bb.shape}")
        with self._lock:
            chunks = self._chunks.setdefault(key, [])
            for i, (obb, _) in enumerate(chunks):
                if obb == bb:  # overwrite in place: no stale duplicates
                    chunks[i] = (bb, arr)
                    return
            chunks.append((bb, arr))

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        with self._lock:
            chunks = list(self._chunks.get(key, []))
        if not chunks:
            raise KeyError(f"{self.name}: no data for {key}")
        out, covered = _assemble(chunks, roi)
        if out is None:
            raise KeyError(f"{self.name}: {key} has no chunks intersecting {roi}")
        if not covered.all():
            raise KeyError(
                f"{self.name}: {key} covers only "
                f"{int(covered.sum())}/{roi.volume} of {roi}"
            )
        return out

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        with self._lock:
            out: dict[RegionKey, BoundingBox] = {}
            for key, chunks in self._chunks.items():
                if key.namespace == namespace and key.name == name:
                    for bb, _ in chunks:
                        out[key] = bb if key not in out else out[key].union(bb)
            return sorted(out.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        with self._lock:
            self._chunks.pop(key, None)

    # -- TieredStore hooks -----------------------------------------------------
    def peek_chunks(self, key: RegionKey) -> list[tuple[BoundingBox, np.ndarray]]:
        """The key's chunks as written (lossless demotion source)."""
        with self._lock:
            return list(self._chunks.get(key, []))

    def key_bytes(self, key: RegionKey) -> int:
        with self._lock:
            return sum(a.nbytes for _, a in self._chunks.get(key, []))

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return sum(a.nbytes for cs in self._chunks.values() for _, a in cs)


@dataclasses.dataclass
class Tier:
    """One level of the hierarchy: a backend + an optional byte budget.

    Capacity accounting is exact for :class:`MemoryTier` backends (they
    report resident bytes per key); for other backends it accumulates
    put sizes, which over-counts same-box overwrites — budget bounded
    tiers should therefore be memory tiers (the usual configuration).
    """

    name: str
    backend: StorageBackend
    capacity_bytes: int | None = None  # None = unbounded
    stats: TierStats = dataclasses.field(default_factory=TierStats)


_FLUSH_STOP = object()


class TieredStore:
    """Ordered tier stack behind the unified ``StorageBackend`` protocol."""

    def __init__(
        self,
        tiers: Sequence[Tier | StorageBackend | tuple],
        *,
        name: str = "TIERED",
        policy: PlacementPolicy | None = None,
        write_policy: str = "write_through",
        promote_after: int = 2,
    ) -> None:
        if write_policy not in ("write_through", "write_back", "lazy"):
            raise ValueError(f"unknown write_policy {write_policy!r}")
        self.name = name
        self.tiers: list[Tier] = []
        for t in tiers:
            if isinstance(t, Tier):
                self.tiers.append(t)
            elif isinstance(t, tuple):
                tname, backend, cap = (t + (None,))[:3] if len(t) == 2 else t
                self.tiers.append(Tier(tname, backend, cap))
            else:
                self.tiers.append(Tier(getattr(t, "name", "tier"), t))
        if not self.tiers:
            raise ValueError("TieredStore needs at least one tier")
        self.policy = policy or PlacementPolicy()
        self.write_policy = write_policy
        self.promote_after = max(1, int(promote_after))
        self._lock = threading.RLock()
        # metadata: which tiers hold each key, union bb, per-tier bytes
        self._resident: dict[RegionKey, set[int]] = {}
        self._bb: dict[RegionKey, BoundingBox] = {}
        self._tier_bytes: list[dict[RegionKey, int]] = [
            collections.defaultdict(int) for _ in self.tiers
        ]
        # per-key write generation, and the generation each tier's copy
        # reflects: a copy is stale iff its generation is behind the
        # key's.  Demotion may only *drop* a copy when a lower tier holds
        # a current-generation one; otherwise it must spill.
        self._gen: collections.Counter = collections.Counter()
        self._tier_gen: list[dict[RegionKey, int]] = [{} for _ in self.tiers]
        self._lru: list["collections.OrderedDict[RegionKey, None]"] = [
            collections.OrderedDict() for _ in self.tiers
        ]
        self._placement: dict[RegionKey, Placement] = {}
        self._hits: collections.Counter = collections.Counter()
        self._moving: set[RegionKey] = set()  # promotion/demotion in flight
        # write-back machinery
        self._pending_flush: collections.Counter = collections.Counter()
        self._tombstones: set[RegionKey] = set()
        self._flushq: "queue.Queue" = queue.Queue()
        self._flusher = threading.Thread(
            target=self._flush_loop, daemon=True, name=f"{name}-flusher"
        )
        self._flusher.start()

    # -- helpers ------------------------------------------------------------------
    def _tier_index(self, tier_name: str | None) -> int:
        if tier_name is None:
            return 0
        for i, t in enumerate(self.tiers):
            if t.name == tier_name:
                return i
        raise KeyError(f"{self.name}: no tier named {tier_name!r}")

    @property
    def _bottom(self) -> int:
        return len(self.tiers) - 1

    def _touch(self, ti: int, key: RegionKey) -> None:
        lru = self._lru[ti]
        if key in lru:
            lru.move_to_end(key)
        else:
            lru[key] = None

    def _admit(self, ti: int, key: RegionKey, bb: BoundingBox, nbytes: int) -> None:
        self._resident.setdefault(key, set()).add(ti)
        self._bb[key] = bb if key not in self._bb else self._bb[key].union(bb)
        backend = self.tiers[ti].backend
        if isinstance(backend, MemoryTier):
            # exact accounting: re-puts overwrite in place, so ask the tier
            self._tier_bytes[ti][key] = backend.key_bytes(key)
        else:
            self._tier_bytes[ti][key] += nbytes
        self._touch(ti, key)

    def _drop_from_tier(self, ti: int, key: RegionKey) -> None:
        self._tier_bytes[ti].pop(key, None)
        self._tier_gen[ti].pop(key, None)
        self._lru[ti].pop(key, None)
        tiers = self._resident.get(key)
        if tiers is not None:
            tiers.discard(ti)
            if not tiers:
                self._resident.pop(key, None)

    # -- StorageBackend protocol ----------------------------------------------------
    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        arr = np.asarray(array)
        placement = self.policy.place(key, bb, arr.nbytes, arr.dtype)
        ti = self._tier_index(placement.tier)
        tier = self.tiers[ti]
        tier.backend.put(key, bb, arr)
        with self._lock:
            self._tombstones.discard(key)
            self._placement[key] = placement
            self._gen[key] += 1
            gen = self._gen[key]
            self._admit(ti, key, bb, arr.nbytes)
            self._tier_gen[ti][key] = gen
            tier.stats.puts += 1
            tier.stats.bytes_in += arr.nbytes
            wp = placement.write_policy or self.write_policy
        if ti != self._bottom:
            if wp == "write_through":
                bottom = self.tiers[self._bottom]
                bottom.backend.put(key, bb, arr)
                with self._lock:
                    self._admit(self._bottom, key, bb, arr.nbytes)
                    self._tier_gen[self._bottom][key] = gen
                    bottom.stats.puts += 1
                    bottom.stats.bytes_in += arr.nbytes
            elif wp == "write_back":
                with self._lock:
                    self._pending_flush[key] += 1
                self._flushq.put((key, bb, arr, gen))
            # "lazy": stays in the placed tier until eviction / drain()
        self._enforce_capacity(ti)

    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        arr = None
        ti = None
        # bounded retry: a concurrent demotion may move the payload down
        # between the metadata read and the backend read; the metadata
        # converges (destination is populated before the source is
        # dropped), so re-reading it resolves the race
        had_resident = False
        for _ in range(8):
            with self._lock:
                # freshest copy first (stale lower copies may linger after
                # a lazy/write-back overwrite), fastest tier as tiebreak
                resident = sorted(
                    self._resident.get(key, ()),
                    key=lambda t: (-self._tier_gen[t].get(key, 0), t),
                )
            if not resident:
                break
            had_resident = True
            ti = resident[0]
            try:
                arr = self.tiers[ti].backend.get(key, roi)
                break
            except KeyError:
                # either a concurrent demotion moved the payload (metadata
                # converges: retry) or the freshest tier lacks full ROI
                # coverage (falls through to cross-tier assembly)
                arr = None
                continue
        if arr is None and not had_resident:
            # data staged directly into a backend (not through this store):
            # probe top-down and adopt the key so future reads are tracked
            for i, tier in enumerate(self.tiers):
                try:
                    arr = tier.backend.get(key, roi)
                except KeyError:
                    continue
                ti = i
                found = tier.backend.query(key.namespace, key.name)
                bb = next((b for k, b in found if k == key), roi)
                with self._lock:
                    self._gen[key] = max(self._gen[key], 1)
                    self._admit(ti, key, bb, 0)
                    self._tier_gen[ti][key] = self._gen[key]
                break
        if arr is None:
            # the key's chunks may be split across tiers (placement
            # thresholds route chunks independently) — no single tier
            # covers the ROI, but the hierarchy jointly can
            arr, ti = self._assemble_across_tiers(key, roi)
            if arr is None:
                raise KeyError(f"{self.name}: no tier holds {key}")
        with self._lock:
            for i in range(ti):
                self.tiers[i].stats.misses += 1
            self.tiers[ti].stats.hits += 1
            self.tiers[ti].stats.bytes_out += arr.nbytes
            self._touch(ti, key)
            self._hits[key] += 1
            promote = (
                ti > 0
                and self._hits[key] >= self.promote_after
                and not self._placement.get(key, Placement()).pinned
            )
        if promote:
            self._promote(key, ti, roi, arr)
        return arr

    def _assemble_across_tiers(
        self, key: RegionKey, roi: BoundingBox
    ) -> tuple[np.ndarray | None, int | None]:
        """Assemble an ROI from chunks spread over several tiers.

        Slowest tier first so faster (and per-policy fresher) tiers
        overwrite on overlap.  Returns (None, None) if the hierarchy does
        not jointly cover the ROI.
        """
        with self._lock:
            # stalest first so fresher generations overwrite on overlap;
            # equal generations resolve to the fastest tier
            order = sorted(
                range(len(self.tiers)),
                key=lambda i: (self._tier_gen[i].get(key, 0), -i),
            )
        pieces: list[tuple[BoundingBox, np.ndarray]] = []
        fastest = None
        for i in order:
            tier = self.tiers[i]
            for k, bb in tier.backend.query(key.namespace, key.name):
                if k != key or not bb.intersects(roi):
                    continue
                part = bb.intersect(roi)
                try:
                    pieces.append((part, tier.backend.get(key, part)))
                except KeyError:
                    continue  # this tier's coverage of part is partial
                fastest = i if fastest is None else min(fastest, i)
        out, covered = _assemble(pieces, roi)
        if out is None or not covered.all():
            return None, None
        return out, fastest

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        out: dict[RegionKey, BoundingBox] = {}
        for tier in self.tiers:
            for key, bb in tier.backend.query(namespace, name):
                out[key] = bb if key not in out else out[key].union(bb)
        return sorted(out.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        with self._lock:
            if self._pending_flush.get(key):
                self._tombstones.add(key)
            for ti in range(len(self.tiers)):
                self._drop_from_tier(ti, key)
            self._hits.pop(key, None)
            self._placement.pop(key, None)
            self._bb.pop(key, None)
            # _gen is intentionally kept (and bumped: a delete is a write
            # for anyone caching derived products of this key): it must
            # stay monotonic across delete/re-put so late flushes of the
            # old incarnation can be recognized as stale
            self._gen[key] += 1
        for tier in self.tiers:
            tier.backend.delete(key)

    # -- promotion / demotion -----------------------------------------------------
    def _promote(
        self, key: RegionKey, src: int, roi: BoundingBox, served: np.ndarray
    ) -> None:
        """Copy a hot key straight to the top tier (read-through
        promotion).  The just-served payload is reused when it covers the
        region's full box, so promotion adds no extra backend read."""
        dst = 0
        with self._lock:
            bb = self._bb.get(key)
            # a stale top-tier leftover must not block re-promotion of a
            # fresher copy: compare generations, not mere residency
            dst_current = dst in self._resident.get(key, set()) and self._tier_gen[
                dst
            ].get(key, 0) >= self._tier_gen[src].get(key, 0)
            if bb is None or dst_current or key in self._moving:
                self._hits[key] = 0
                return
            self._moving.add(key)
        try:
            self._promote_locked(key, src, roi, served, bb, dst)
        finally:
            with self._lock:
                self._moving.discard(key)

    def _promote_locked(
        self,
        key: RegionKey,
        src: int,
        roi: BoundingBox,
        served: np.ndarray,
        bb: BoundingBox,
        dst: int,
    ) -> None:
        if roi.contains(bb) and bb.contains(roi):
            arr = served
        else:
            try:
                arr = self.tiers[src].backend.get(key, bb)
            except KeyError:
                return  # partial coverage: promotion needs the full box
        cap = self.tiers[dst].capacity_bytes
        if cap is not None and arr.nbytes > cap:
            with self._lock:
                self._hits[key] = 0  # would be evicted right back out
            return
        dst_backend = self.tiers[dst].backend
        with self._lock:
            src_gen = self._tier_gen[src].get(key, 0)
            # a newer put may have landed while we held the payload; stale
            # bytes must never clobber it
            stale = self._gen[key] != src_gen or (
                dst in self._resident.get(key, set())
                and self._tier_gen[dst].get(key, 0) >= src_gen
            )
            if stale:
                self._hits[key] = 0
                return
            if isinstance(dst_backend, MemoryTier):
                # cheap in-memory write: do it under the lock so the gen
                # check above cannot be invalidated mid-copy
                dst_backend.put(key, bb, arr)
                copied = True
            else:
                copied = False
        if not copied:
            dst_backend.put(key, bb, arr)
            with self._lock:
                if self._gen[key] != src_gen:
                    return  # raced: metadata never claims the stale copy
        with self._lock:
            self._admit(dst, key, bb, arr.nbytes)
            self._tier_gen[dst][key] = src_gen
            self.tiers[dst].stats.promotions += 1
            self.tiers[dst].stats.bytes_promoted += arr.nbytes
            self._hits[key] = 0
        self._enforce_capacity(dst)

    def _enforce_capacity(self, ti: int) -> None:
        tier = self.tiers[ti]
        if tier.capacity_bytes is None:
            return
        undemotable: set[RegionKey] = set()
        while True:
            with self._lock:
                used = sum(self._tier_bytes[ti].values())
                if used <= tier.capacity_bytes:
                    return
                victim = None
                for key in self._lru[ti]:  # oldest first
                    if key in undemotable:
                        continue
                    p = self._placement.get(key, Placement())
                    if p.pinned:
                        # a pin with tier=None pins to the top tier
                        try:
                            pin_ti = self._tier_index(p.tier)
                        except KeyError:
                            pin_ti = None
                        if pin_ti == ti:
                            continue
                    victim = key
                    break
                if victim is None:
                    # every candidate pinned or busy: over budget for now
                    return
            if not self._demote(victim, ti):
                # mid-relocation or un-materializable: try the next victim
                undemotable.add(victim)

    def _demote(self, key: RegionKey, src: int) -> bool:
        """Demote the key out of ``src``: the region never leaves the
        hierarchy.  If a lower tier already holds it (write-through copy,
        flushed write-back, promotion leftover) dropping the ``src`` copy
        suffices — locality simply moves down.  Otherwise the payload is
        spilled to the next tier (optionally re-blocked at ROI
        granularity)."""
        dst = src + 1
        if dst > self._bottom:
            return False  # bottom tier is never demoted
        with self._lock:
            if key in self._moving:
                return False  # another thread is already relocating it
            self._moving.add(key)
        try:
            return self._demote_locked(key, src, dst)
        finally:
            with self._lock:
                self._moving.discard(key)

    def _demote_locked(self, key: RegionKey, src: int, dst: int) -> bool:
        src_tier, dst_tier = self.tiers[src], self.tiers[dst]
        with self._lock:
            resident = set(self._resident.get(key, set()))
            if src not in resident:
                return False  # relocated meanwhile
            spill_block = self._placement.get(key, Placement()).spill_block
            moved = self._tier_bytes[src].get(key, 0)
            src_gen = self._tier_gen[src].get(key, 0)
            # drop only if a lower tier holds a copy at least as fresh as
            # ours — a stale lower copy (lazy/write-back overwrite) must
            # not shadow the only up-to-date data
            fresh_below = any(
                t > src and self._tier_gen[t].get(key, -1) >= src_gen
                for t in resident
            )
        if not fresh_below:
            # nothing fresh below: copy to the next tier FIRST so a
            # concurrent reader always finds the payload somewhere
            if isinstance(src_tier.backend, MemoryTier):
                chunks = src_tier.backend.peek_chunks(key)
            else:
                bb = self._bb.get(key)
                try:
                    chunks = [(bb, src_tier.backend.get(key, bb))] if bb else []
                except KeyError:
                    chunks = []
            if not chunks:
                # cannot materialize a copy and nothing durable below:
                # keep it where it is rather than losing data
                with self._lock:
                    self._touch(src, key)  # avoid re-picking it immediately
                return False
            for bb, arr in chunks:
                for part, payload in _spill_parts(bb, arr, spill_block):
                    dst_tier.backend.put(key, part, payload)
                    with self._lock:
                        self._admit(dst, key, part, payload.nbytes)
                        self._tier_gen[dst][key] = max(
                            self._tier_gen[dst].get(key, 0), src_gen
                        )
        # metadata drops before the source payload: readers that re-check
        # the metadata are routed below, never at a half-deleted tier
        with self._lock:
            self._drop_from_tier(src, key)
            src_tier.stats.demotions += 1
            src_tier.stats.bytes_demoted += moved
        src_tier.backend.delete(key)
        self._enforce_capacity(dst)
        return True

    # -- write-back flusher -------------------------------------------------------
    def _flush_loop(self) -> None:
        bottom = self._bottom
        while True:
            item = self._flushq.get()
            try:
                if item is _FLUSH_STOP:
                    return
                key, bb, arr, gen = item
                with self._lock:
                    # stale if deleted, or the bottom already holds a copy
                    # at least this fresh via another path (write-through
                    # override, newer flush, push-down)
                    skip = (
                        key in self._tombstones
                        or self._tier_gen[bottom].get(key, 0) >= gen
                    )
                wrote = False
                if not skip:
                    self.tiers[bottom].backend.put(key, bb, arr)
                    wrote = True
                resurrected = False
                with self._lock:
                    self._pending_flush[key] -= 1
                    if self._pending_flush[key] <= 0:
                        self._pending_flush.pop(key, None)
                    if wrote and key in self._tombstones:
                        # deleted while we were writing: undo, don't
                        # resurrect the key in the bottom tier
                        resurrected = True
                    elif wrote:
                        self._admit(bottom, key, bb, arr.nbytes)
                        self._tier_gen[bottom][key] = max(
                            self._tier_gen[bottom].get(key, 0), gen
                        )
                        self.tiers[bottom].stats.flushes += 1
                        self.tiers[bottom].stats.bytes_flushed += arr.nbytes
                    if key not in self._pending_flush:
                        self._tombstones.discard(key)
                if resurrected:
                    self.tiers[bottom].backend.delete(key)
            finally:
                self._flushq.task_done()

    def flush(self) -> None:
        """Block until every queued write-back has reached the bottom tier."""
        self._flushq.join()

    def drain(self) -> None:
        """Checkpoint consistency: flush write-backs, push lazily held
        regions down to the bottom tier, then sync the bottom backend's
        own buffers (e.g. DISK I/O groups)."""
        self.flush()
        self._push_down()
        bottom = self.tiers[self._bottom].backend
        if hasattr(bottom, "flush"):
            bottom.flush()

    def _push_down(self) -> None:
        """Copy every region not yet bottom-resident to the bottom tier."""
        bi = self._bottom
        bottom = self.tiers[bi]
        with self._lock:
            pending = []
            for key, tiers in self._resident.items():
                if not tiers:
                    continue
                # source = the freshest copy (fastest tier on ties)
                src = max(
                    tiers, key=lambda t, key=key: (self._tier_gen[t].get(key, 0), -t)
                )
                if src == bi:
                    continue
                if bi in tiers and self._tier_gen[bi].get(
                    key, 0
                ) >= self._tier_gen[src].get(key, 0):
                    continue  # bottom already current
                pending.append((key, src, self._bb.get(key)))
        for key, ti, bb in pending:
            if bb is None:
                continue
            try:
                arr = self.tiers[ti].backend.get(key, bb)
            except KeyError:
                # chunks split across tiers: assemble the full box
                arr, _ = self._assemble_across_tiers(key, bb)
                if arr is None:
                    with self._lock:
                        bottom.stats.flush_failures += 1
                    continue  # genuinely uncoverable; surfaced in stats
            bottom.backend.put(key, bb, arr)
            with self._lock:
                src_gen = self._tier_gen[ti].get(key, 0)
                self._admit(bi, key, bb, arr.nbytes)
                self._tier_gen[bi][key] = max(
                    self._tier_gen[bi].get(key, 0), src_gen
                )
                bottom.stats.flushes += 1
                bottom.stats.bytes_flushed += arr.nbytes

    def close(self) -> None:
        self.flush()
        self._flushq.put(_FLUSH_STOP)
        self._flusher.join(timeout=2.0)
        for tier in self.tiers:
            backend_close = getattr(tier.backend, "close", None)
            if callable(backend_close):
                backend_close()  # e.g. DMS socket transports

    # -- introspection -------------------------------------------------------------
    def locality(self, key: RegionKey, *, probe: bool = False) -> str | None:
        """Name of the fastest tier holding the key (None = not resident).

        The default answers from in-memory metadata only — O(1), safe on
        the scheduler hot path.  ``probe=True`` additionally scans the
        backends for data staged into them directly (linear in resident
        keys; such data is also adopted lazily on first ``get``).
        """
        with self._lock:
            resident = self._resident.get(key)
            if resident:
                # the tier that actually serves reads: freshest, then
                # fastest — a stale faster copy must not be reported
                best = min(
                    resident,
                    key=lambda t: (-self._tier_gen[t].get(key, 0), t),
                )
                return self.tiers[best].name
        if probe:
            for tier in self.tiers:
                if any(
                    k == key for k, _ in tier.backend.query(key.namespace, key.name)
                ):
                    return tier.name
        return None

    def dirty(self, key: RegionKey) -> bool:
        """True while the key has not yet reached the bottom tier."""
        with self._lock:
            if self._pending_flush.get(key, 0) > 0:
                return True
            tiers = self._resident.get(key)
            return bool(tiers) and self._bottom not in tiers

    def generation(self, key: RegionKey) -> int:
        """Monotonic per-key write generation (puts AND deletes bump it).

        Consumed by derived-product caches (the gateway's near-data
        compute tier): a cached result is valid iff the generation it was
        computed under still matches, so writes that bypass the cache
        owner — direct ``store.put`` while a gateway fronts the store —
        still invalidate.
        """
        with self._lock:
            return self._gen[key]

    def bump_generation(self, key: RegionKey, floor: int | None = None) -> int:
        """Raise ``key``'s write generation: by one (``floor=None``, an
        out-of-band mutation observed outside the put path — forces
        every generation-validated cache above this store to drop the
        key), or to at least ``floor`` (restoring a persisted generation
        watermark).  Never moves backwards; returns the current
        generation."""
        with self._lock:
            if floor is None:
                self._gen[key] += 1
            elif self._gen[key] < int(floor):
                self._gen[key] = int(floor)
            return self._gen[key]

    def tier_stats(self) -> dict[str, TierStats]:
        return {t.name: t.stats for t in self.tiers}

    def used_bytes(self, tier_name: str) -> int:
        ti = self._tier_index(tier_name)
        with self._lock:
            return sum(self._tier_bytes[ti].values())

    def __repr__(self) -> str:
        stack = " -> ".join(
            f"{t.name}"
            + (f"[{t.capacity_bytes >> 20}MiB]" if t.capacity_bytes else "")
            for t in self.tiers
        )
        return f"TieredStore({self.name}: {stack}, {self.write_policy})"

    # -- canonical stack ------------------------------------------------------------
    @staticmethod
    def standard(
        domain: BoundingBox,
        block_shape: Iterable[int],
        *,
        root: str,
        name: str = "TIERED",
        mem_capacity_bytes: int = 256 << 20,
        num_servers: int = 4,
        policy: PlacementPolicy | None = None,
        write_policy: str = "write_through",
        promote_after: int = 2,
        disk_kwargs: dict | None = None,
        dms_transport=None,
        replication: int = 1,
        repair_interval: float | None = None,
        wire_codec=None,
        membership=None,
    ) -> "TieredStore":
        """The paper-shaped stack: bounded RAM -> DISK (ADIOS-style) -> DMS.

        ``dms_transport`` swaps the DMS tier's server link: ``None`` keeps
        the in-process shards, a :class:`~repro.storage.net.
        SocketTransport` (or a pre-spawned ``ServerGroup().transport()``)
        makes the bottom tier span hosts — demotion, write-back flush and
        ``locality()`` are unchanged, only the bytes ride TCP.  The store
        owns the transport: ``close()`` closes it.

        ``replication`` is the DMS tier's R-way block replication: each
        demoted/flushed block lands on R servers along the SFC ring, so
        the bottom tier survives R-1 server deaths with zero failed
        reads — and zero failed writes (puts re-home blocks past dead
        replicas).  ``repair_interval`` (seconds) opts into the DMS
        tier's background anti-entropy sweep: a crashed server that
        rejoins empty is re-filled until every block has R live copies
        again; ``close()`` stops the sweep.

        ``wire_codec`` compresses the DMS tier's payloads on the wire:
        either one codec name (``repro.storage.codec.WIRE_CODECS``) for
        every block, or a per-key glob mapping such as ``{"labels/*":
        "zlib", "feat/*": "bf16"}`` routing each region key to its own
        codec (unmatched keys ride raw).  Negotiated per connection, old
        servers degrade the link to raw.  It requires a socket
        ``dms_transport`` — in-process shards move no wire bytes, so a
        codec there would only burn CPU — and must be set before the
        transport's first use (negotiation happens at dial time).

        ``membership`` seeds the DMS tier's elastic fleet view (a
        :class:`~repro.storage.membership.RingView`); leave ``None`` for
        the genesis ring over the transport's servers.  The DMS tier's
        ``add_server``/``remove_server``/``rebalance`` then grow and
        shrink the bottom tier live — reach it via
        ``store.tiers[-1].backend``.
        """
        from repro.storage.codec import check_codec
        from repro.storage.disk import DiskStorage
        from repro.storage.dms import DistributedMemoryStorage

        if wire_codec is not None:
            if dms_transport is None:
                raise ValueError(
                    "wire_codec= needs a socket dms_transport (in-process "
                    "shards move no wire bytes); pass a SocketTransport or "
                    "ServerGroup().transport()"
                )
            dms_transport.wire_codec = check_codec(wire_codec)
        mem = MemoryTier(name="MEM")
        disk = DiskStorage(root, name=f"{name}-DISK", **(disk_kwargs or {}))
        dms = DistributedMemoryStorage(
            domain, block_shape,
            num_servers if dms_transport is None else None,
            name=f"{name}-DMS", transport=dms_transport,
            replication=replication, membership=membership,
        )
        if repair_interval is not None:
            dms.start_auto_repair(repair_interval)
        return TieredStore(
            [
                Tier("MEM", mem, mem_capacity_bytes),
                Tier("DISK", disk),
                Tier("DMS", dms),
            ],
            name=name,
            policy=policy,
            write_policy=write_policy,
            promote_after=promote_after,
        )


def _spill_parts(
    bb: BoundingBox, arr: np.ndarray, spill_block: tuple[int, ...] | None
):
    """Yield (bb, payload) demotion units, re-blocked at ROI granularity."""
    if spill_block is None or len(spill_block) != bb.rank:
        yield bb, arr
        return
    for tile in bb.tiles(spill_block):
        yield tile, np.ascontiguousarray(arr[tile.local_slices(bb)])
