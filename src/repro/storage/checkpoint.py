"""Fault-tolerant checkpointing built on region templates + the DISK store.

A checkpoint is a *versioned set of data regions*: each pytree leaf becomes
a data region named by its tree path, with ``timestamp = step``; sharded
``jax.Array`` leaves are written one region-chunk per addressable shard,
whose bounding box is the shard's global index box.  That makes restore
*elastic for free*: a job restarted on a different mesh simply reads the
ROIs its new sharding needs (the DISK store assembles across chunk
boundaries), via ``jax.make_array_from_callback``.

Protocol (crash tolerant):
  1. write all leaf chunks for ``step``;
  2. write a tiny COMMIT region for ``step`` — only committed steps are
     visible to ``steps()``/``latest_step()``/``restore``.

Saves can run asynchronously on a writer thread (the paper's separated-I/O
configuration maps onto this: training is the compute core, the writer is
the I/O core).
"""
from __future__ import annotations

import threading
from typing import Any

import jax
import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import ElementType, RegionKey
from repro.storage.disk import DiskStorage

_COMMIT = "__ckpt_commit__"


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_path_elem_str(p) for p in path)
        out.append((name or "leaf", leaf))
    return out


def _path_elem_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _index_box(shape: tuple[int, ...], index: tuple[slice, ...]) -> BoundingBox:
    lo, hi = [], []
    for dim, sl in zip(shape, index):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        lo.append(start)
        hi.append(stop)
    return BoundingBox(tuple(lo), tuple(hi))


class CheckpointManager:
    """Async, sharded, versioned checkpoints with elastic restore."""

    def __init__(
        self,
        store: DiskStorage,
        *,
        namespace: str = "ckpt",
        keep: int = 3,
    ) -> None:
        self.store = store
        self.namespace = namespace
        self.keep = keep
        self._inflight: threading.Thread | None = None
        self._error: BaseException | None = None
        self._lock = threading.Lock()

    # -- save ----------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        """Snapshot ``tree`` at ``step``; async if ``blocking=False``."""
        self.wait()  # one in-flight save at a time
        # Snapshot to host *now* so training may mutate/donate buffers after.
        host_leaves: list[tuple[str, list[tuple[BoundingBox, np.ndarray]]]] = []
        for name, leaf in _leaf_paths(tree):
            chunks: list[tuple[BoundingBox, np.ndarray]] = []
            if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
                shape = tuple(leaf.shape)
                if not shape:  # scalars: single chunk
                    chunks.append((BoundingBox((0,), (1,)), np.asarray(leaf).reshape(1)))
                else:
                    seen: set[tuple] = set()
                    for shard in leaf.addressable_shards:
                        box = _index_box(shape, shard.index)
                        tkey = (box.lo, box.hi)
                        if tkey in seen:  # replicated shards: write once
                            continue
                        seen.add(tkey)
                        chunks.append((box, np.asarray(shard.data)))
            else:
                arr = np.asarray(leaf)
                if not arr.shape:
                    arr = arr.reshape(1)
                chunks.append((BoundingBox.from_shape(arr.shape), arr))
            host_leaves.append((name, chunks))

        def _write() -> None:
            try:
                for name, chunks in host_leaves:
                    for box, arr in chunks:
                        key = RegionKey(
                            self.namespace,
                            name,
                            ElementType.from_dtype(arr.dtype),
                            timestamp=step,
                        )
                        self.store.put(key, box, arr)
                self.store.flush()
                commit_key = RegionKey(
                    self.namespace, _COMMIT, ElementType.INT64, timestamp=step
                )
                self.store.put(commit_key, BoundingBox((0,), (1,)), np.asarray([step]))
                self.store.flush()
                self._gc()
            except BaseException as e:  # surfaced on next wait()/save()
                with self._lock:
                    self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            t = threading.Thread(target=_write, daemon=True, name=f"ckpt-save-{step}")
            self._inflight = t
            t.start()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
        self._raise_if_failed()

    def close(self) -> None:
        """Join any in-flight async save (surfacing its error, if any)."""
        self.wait()

    def _raise_if_failed(self) -> None:
        with self._lock:
            if self._error is not None:
                err, self._error = self._error, None
                raise RuntimeError("async checkpoint save failed") from err

    def _gc(self) -> None:
        steps = self.steps()
        for old in steps[: -self.keep] if self.keep > 0 else []:
            for key in self.store.keys():
                if key.namespace == self.namespace and key.timestamp == old:
                    self.store.delete(key)

    # -- inspect -----------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for key, _ in self.store.query(self.namespace, _COMMIT):
            out.append(key.timestamp)
        return sorted(set(out))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- restore --------------------------------------------------------------------
    def restore(self, target: Any, step: int | None = None) -> Any:
        """Rebuild a pytree like ``target`` from the checkpoint at ``step``.

        ``target`` leaves may be jax.Arrays, ShapeDtypeStructs (optionally
        carrying ``.sharding``) or numpy arrays; each leaf is materialized
        with its target sharding via ``make_array_from_callback`` so the
        restore mesh may differ from the save mesh (elastic scaling).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no committed checkpoint found")
        if step not in self.steps():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")

        leaves = _leaf_paths(target)
        rebuilt: list[Any] = []
        for name, leaf in leaves:
            shape = tuple(getattr(leaf, "shape", ()))
            dtype = getattr(leaf, "dtype", None)
            key = RegionKey(
                self.namespace,
                name,
                ElementType.from_dtype(np.dtype(dtype) if dtype is not None else np.float32),
                timestamp=step,
            )
            if not shape:
                arr = self.store.get(key, BoundingBox((0,), (1,)))
                rebuilt.append(arr.reshape(())[()])
                continue
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and isinstance(sharding, jax.sharding.Sharding):
                def cb(index: tuple[slice, ...], *, _key=key, _shape=shape):
                    box = _index_box(_shape, index)
                    return self.store.get(_key, box)

                rebuilt.append(jax.make_array_from_callback(shape, sharding, cb))
            else:
                rebuilt.append(self.store.get(key, BoundingBox.from_shape(shape)))
        treedef = jax.tree_util.tree_structure(target)
        return jax.tree_util.tree_unflatten(treedef, rebuilt)
