"""Multi-host network layer for the DMS (paper S4.1's RDMA transport).

The paper's DataSpaces deployment keeps payload blocks on their home
servers and moves bytes between hosts over an RDMA transport; this module
is the TCP equivalent, implementing the same :class:`~repro.storage.dms.
Transport` message API as :class:`~repro.storage.dms.InProcTransport` so
the two are drop-in swaps under :class:`~repro.storage.dms.
DistributedMemoryStorage` (and therefore under the DMS tier of a
:class:`~repro.storage.tiers.TieredStore`).

Wire protocol (one request/response round-trip per message)::

    frame    := u32 header_len | u64 payload_len | header | payload
    header   := JSON (op, sid, key/coord/bb/home..., array meta)
    payload  := block bytes (C order, little-endian), only for store
                requests and fetch / fetch_many responses.
                fetch_many: per-block buffers back to back, each block's
                byte offset in its header entry ("off"/"len"; legacy
                servers omit them and the client falls back to
                cumulative raw sizes).  The server sends the buffers
                with one scatter-IO ``sendmsg`` — they are never
                concatenated in memory.

Array payloads travel as ``header {shape, dtype} + raw buffer`` — no
pickling, dtype and shape preserved bit-exact (including float16 /
bfloat16 / empty arrays; non-contiguous inputs are compacted once on the
sending side).  Optionally the buffer is compressed by one of the
``storage/codec.py`` codecs (a ``codec`` tag in the array header makes
every block self-describing) and/or replaced entirely by a
shared-memory reference (``"shm": [offset, nbytes]``) when client and
server negotiated a same-host arena — see ``storage/shm.py``.

Negotiation: a client constructed with ``wire_codec=`` or ``shm=`` sends
one ``hello`` frame per connection before its first message.  The reply
carries the server's supported codecs and (when requested and available)
its arena descriptor ``{name, size, token}``.  An old server rejects
``hello`` as an unknown op and the client silently falls back to the
plain wire format, so mixed-version fleets interoperate; a client
without those options never sends ``hello`` and is byte-identical to the
legacy protocol.

Pieces:
  * :class:`SocketTransport` — the client: one pipelined TCP connection
    per server endpoint, thread-safe, every wire byte accounted in
    ``TransportStats`` (raw vs wire bytes split).
  * :class:`ShmTransport` — a :class:`SocketTransport` that requires the
    shared-memory data plane (co-located fleets; control frames on the
    socket, payloads through the arena).
  * :class:`ServerProcess` — a subprocess handle that runs ``python -m
    repro.storage.net`` hosting one or more ``_Server`` shards behind a
    threaded socket loop (the standalone entry point documented in the
    README).
  * :func:`spawn_servers` — convenience: start N shards across M
    processes and hand back a :class:`ServerGroup` with the endpoint
    list, ready for ``SocketTransport``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import socket
import socketserver
import struct
import subprocess
import sys
import threading
import time
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey
from repro.storage.codec import (  # noqa: F401 — array codec re-exported
    WIRE_CODECS,
    Encoded,
    check_codec,
    codec_names,
    decode_array,  # noqa: F401
    decode_block,
    encode_array,  # noqa: F401
    encode_block,
    is_lossless,
    raw_nbytes,
    resolve_codec,
)
from repro.storage.disk import _bb_from_json, _bb_to_json, _key_from_json, _key_to_json
from repro.storage.dms import (  # noqa: F401 — TransportError re-exported
    META_MSG_BYTES,
    TransportError,
    TransportStats,
    _Server,
    decode_homes,
    encode_homes,
)
from repro.storage.shm import ShmArena, ShmWindow

_PREFIX = struct.Struct("!IQ")  # header_len, payload_len

# default arena capacity for shard hosts (created lazily on the first
# shm-negotiating hello, so plain fleets never touch /dev/shm)
DEFAULT_ARENA_BYTES = 256 << 20


def _homes_json(home):
    """``home`` directory field for the wire: a bare int stays a bare int
    (the legacy single-home format, byte-for-byte), a replica sequence
    becomes a JSON list.  The server stores it as sent; lookup returns it
    as stored.  One source of truth: the dms codec pair."""
    return encode_homes(decode_homes(home))


# ---------------------------------------------------------------------------
# framing + array codec
# ---------------------------------------------------------------------------
def _recv_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            raise TransportError("connection closed mid-frame")
        got += r
    return buf


def _nbytes(buf) -> int:
    return buf.nbytes if isinstance(buf, memoryview) else len(buf)


_IOV_CHUNK = 64  # comfortably under IOV_MAX (1024 on linux)


def _sendmsg_all(sock: socket.socket, parts: list) -> None:
    """Scatter-IO sendall: put every buffer on the wire without ever
    concatenating them (``sendmsg`` io-vectors + partial-send loop)."""
    bufs = [memoryview(p).cast("B") for p in parts]
    bufs = [b for b in bufs if b.nbytes]
    while bufs:
        sent = sock.sendmsg(bufs[:_IOV_CHUNK])
        if sent <= 0:
            raise OSError("sendmsg returned no progress")
        while bufs and sent:
            if sent >= bufs[0].nbytes:
                sent -= bufs[0].nbytes
                bufs.pop(0)
            else:
                bufs[0] = bufs[0][sent:]
                sent = 0


def send_frame_parts(sock: socket.socket, header: dict, parts: Sequence) -> int:
    """Send one frame whose payload is ``parts`` back to back; returns
    the number of bytes put on the wire."""
    hbytes = json.dumps(header, separators=(",", ":")).encode()
    plen = sum(_nbytes(p) for p in parts)
    _sendmsg_all(sock, [_PREFIX.pack(len(hbytes), plen), hbytes, *parts])
    return _PREFIX.size + len(hbytes) + plen


def send_frame(sock: socket.socket, header: dict, payload=b"") -> int:
    """Send one frame; returns the number of bytes put on the wire."""
    return send_frame_parts(sock, header, (payload,))


def recv_frame(sock: socket.socket) -> tuple[dict, bytearray, int]:
    """Receive one frame; returns (header, payload, wire_bytes)."""
    hlen, plen = _PREFIX.unpack(bytes(_recv_exact(sock, _PREFIX.size)))
    header = json.loads(bytes(_recv_exact(sock, hlen)))
    payload = _recv_exact(sock, plen) if plen else bytearray()
    return header, payload, _PREFIX.size + hlen + plen


# The array codec itself (encode_array/decode_array + the compressing
# encode_block/decode_block) lives in ``storage/codec.py`` and is
# re-exported above: net.py owns framing, codec.py owns payload bytes.


# ---------------------------------------------------------------------------
# client: SocketTransport
# ---------------------------------------------------------------------------
def _parse_endpoint(ep) -> tuple[str, int]:
    if isinstance(ep, str):
        host, _, port = ep.rpartition(":")
        return host or "127.0.0.1", int(port)
    host, port = ep
    return str(host), int(port)


_SCOPE_SEP = "\x1f"  # unit separator: cannot appear in a sane namespace


class SocketTransport:
    """Transport over framed TCP to one or more :class:`ServerProcess`es.

    ``endpoints[i]`` is the address serving global server id ``i``; the
    same address may appear for several ids when one process hosts
    multiple shards.  One connection per distinct address, guarded by a
    lock (requests to the same host serialize; different hosts proceed
    concurrently).  A failed connection is dropped and re-dialed on the
    next message, so a restarted server becomes reachable again — but the
    failing message itself surfaces as :class:`TransportError`.

    ``scope`` isolates keyspaces on a *shared* server fleet: every key is
    namespace-prefixed on the wire and filtered/stripped on the way back,
    so several stores (e.g. the WSI pipeline's DMS3 + DMS2) can share one
    fleet without ``query``/``delete`` cross-contamination — matching the
    isolation that separate ``InProcTransport`` instances give for free.
    (``payload_bytes`` stays physical: it reports the server's total
    resident bytes across scopes.)

    Liveness: a request failure marks the endpoint dead for
    ``dead_backoff`` seconds.  The first request after a failure (and
    after each backoff expiry) sends one short ``ping`` probe
    (``probe_timeout``) — so a transient blip recovers on the very next
    request — while requests between a FAILED probe and its backoff
    expiry fail fast with :class:`TransportError` instead of re-paying a
    connect/op timeout, which is what keeps the DMS's replica failover
    cheap.  ``alive()`` exposes the cache so routing can prefer live
    replicas up front.

    Data-plane options (all default OFF — the plain transport is
    byte-identical to the legacy wire format and never sends ``hello``):

      * ``wire_codec`` — compress payload blocks on the wire with one of
        ``codec.WIRE_CODECS`` ("zlib" lossless; "bf16"/"int8" lossy for
        float blocks, lossless-zlib fallback otherwise).  Negotiated per
        connection; an old server degrades the link to raw.  A *mapping*
        is a per-key override table — glob patterns over region keys
        (``{"labels/*": "zlib", "feat/*": "bf16"}``, first hit wins, no
        hit means raw) — so label tiles and float features each get
        their best codec on ONE connection.  Per-key tagging inside a
        single ``fetch_many`` needs a server that advertises the ``pkc``
        capability; older servers serve the map per request (store/
        fetch) and raw gathers.
      * ``shm`` — ``"off"`` | ``"auto"`` | ``"require"``: map the
        server's shared-memory arena when co-located so fetch payloads
        arrive by ``(offset, nbytes)`` reference instead of a TCP
        stream.  ``auto`` silently falls back to socket payloads (remote
        host, old server, no arena); ``require`` raises
        :class:`TransportError` when any endpoint cannot negotiate it.
      * ``zero_copy`` — shm fetches return read-only views directly into
        the mapped arena (RDMA-window semantics: valid until the block
        is dropped or overwritten server-side) instead of copying out.
    """

    def __init__(
        self,
        endpoints: Sequence,
        *,
        connect_timeout: float = 10.0,
        op_timeout: float = 120.0,
        scope: str | None = None,
        dead_backoff: float = 2.0,
        probe_timeout: float = 1.0,
        wire_codec: str | None = None,
        shm: str = "off",
        zero_copy: bool = False,
    ) -> None:
        self.endpoints = [_parse_endpoint(e) for e in endpoints]
        if not self.endpoints:
            raise ValueError("SocketTransport needs at least one endpoint")
        if shm not in ("off", "auto", "require"):
            raise ValueError(f"shm must be 'off', 'auto' or 'require', got {shm!r}")
        self.scope = scope
        self.num_servers = len(self.endpoints)
        self.stats = TransportStats()
        self.connect_timeout = connect_timeout
        self.op_timeout = op_timeout
        self.dead_backoff = dead_backoff
        self.probe_timeout = probe_timeout
        self.wire_codec = check_codec(wire_codec)
        self.shm = shm
        self.zero_copy = zero_copy
        self._conns: dict[tuple[str, int], socket.socket] = {}
        self._conn_locks: dict[tuple[str, int], threading.Lock] = {
            addr: threading.Lock() for addr in set(self.endpoints)
        }
        # per-connection negotiation outcome: {"codec": str|None,
        # "codecs": set, "pkc": bool, "window": ShmWindow|None};
        # absent until the first dial
        self._neg: dict[tuple[str, int], dict] = {}
        self._dead: dict[tuple[str, int], float] = {}  # addr -> retry-at (monotonic)
        self._probe_failed: set[tuple[str, int]] = set()  # probed dead this window
        self._removed: set[int] = set()  # sids torn down by remove_endpoint
        self._ep_lock = threading.Lock()  # guards endpoint-table mutation
        self._closed = False
        self._stats_lock = threading.Lock()
        self._elapsed = 0.0
        self._busy_until = 0.0  # interval-union bookkeeping for virtual_time

    # -- elastic membership ---------------------------------------------------------
    def add_endpoint(self, endpoint, *, sid: "int | None" = None) -> int:
        """Register one more server address live and return its sid.
        Re-adding a removed sid (same or new address) revives it; the
        liveness cache for the address is cleared so the newcomer is
        probed, not served a stale-dead answer."""
        addr = _parse_endpoint(endpoint)
        with self._ep_lock:
            if sid is None:
                sid = len(self.endpoints)
            for gap in range(len(self.endpoints), sid):
                # a skipped-ahead sid leaves placeholder slots behind it;
                # sids are table indices, so mark the gap absent — ops on
                # it fail fast instead of dialing the newcomer's address
                self._removed.add(gap)
            while len(self.endpoints) <= sid:
                self.endpoints.append(addr)
            self.endpoints[sid] = addr
            self._conn_locks.setdefault(addr, threading.Lock())
            self._removed.discard(sid)
            self.num_servers = len(self.endpoints)
        self.reset_liveness(sid)
        return sid

    def remove_endpoint(self, sid: int) -> None:
        """Tear down a departed server's path: its sid keeps its slot in
        the endpoint table (sids are indices — survivors must not shift)
        but every subsequent op fails fast with TransportError."""
        with self._ep_lock:
            self._removed.add(sid)
            addr = self.endpoints[sid]
            last = not any(
                self.endpoints[i] == addr
                for i in range(len(self.endpoints))
                if i not in self._removed
            )
        if last:
            # last sid on that address: drop the connection too (outside
            # _ep_lock — the connection lock must never nest under it)
            lock = self._conn_locks.get(addr)
            if lock is not None and lock.acquire(timeout=1.0):
                try:
                    self._drop_connection(addr)
                finally:
                    lock.release()

    def reset_liveness(self, server: int) -> None:
        """Forget cached deadness for the server's address and force a
        re-dial (+ re-negotiation) on the next request — the epoch-bump
        probe that keeps a leave/rejoin on the same port within the
        backoff window from being served stale-dead answers."""
        addr = self._addr_of(server)
        self._dead.pop(addr, None)
        self._probe_failed.discard(addr)
        lock = self._conn_locks.get(addr)
        if lock is not None and lock.acquire(timeout=1.0):
            try:
                self._drop_connection(addr)
            finally:
                lock.release()

    def known_servers(self) -> list[int]:
        """Every sid a frame could still reach (removed ones excluded)."""
        with self._ep_lock:
            return [i for i in range(len(self.endpoints)) if i not in self._removed]

    def _addr_of(self, server: int) -> tuple[str, int]:
        """Endpoint snapshot under the membership lock — the table can
        be grown (add_endpoint) or retired (remove_endpoint) from other
        threads mid-read."""
        with self._ep_lock:
            return self.endpoints[server]

    # -- connection management ----------------------------------------------------
    def _connection(self, addr: tuple[str, int]) -> socket.socket:
        sock = self._conns.get(addr)
        if sock is not None:
            return sock
        try:
            sock = socket.create_connection(addr, timeout=self.connect_timeout)
        except OSError as e:
            self._dead[addr] = time.monotonic() + self.dead_backoff
            self._probe_failed.discard(addr)
            raise TransportError(f"cannot reach DMS server at {addr[0]}:{addr[1]}: {e}") from e
        sock.settimeout(self.op_timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        if self.wire_codec or self.shm != "off":
            try:
                self._negotiate(addr, sock)
            except (OSError, TransportError):
                sock.close()
                raise
        self._conns[addr] = sock
        return sock

    def _negotiate(self, addr: tuple[str, int], sock: socket.socket) -> None:
        """One ``hello`` round-trip on a fresh connection.

        Establishes the wire codec and (when requested) maps the
        server's shm arena.  An old server rejects the unknown op —
        that degrades the link to the plain wire format rather than
        failing it, so new clients keep working against old fleets.
        """
        self._close_window(addr)
        hello = {"op": "hello", "shm": self.shm != "off"}
        needed = codec_names(self.wire_codec)
        if needed:
            hello["codecs"] = needed
        wire = send_frame(sock, hello)
        rheader, _, rwire = recv_frame(sock)
        self._account("meta", wire + rwire)
        neg = {"codec": None, "codecs": set(), "pkc": False, "window": None}
        if rheader.get("ok"):
            supported = set(rheader.get("codecs", ()))
            neg["codecs"] = {c for c in needed if c in supported}
            neg["pkc"] = bool(rheader.get("pkc"))
            if (
                isinstance(self.wire_codec, str)
                and self.wire_codec in neg["codecs"]
            ):
                neg["codec"] = self.wire_codec
            desc = rheader.get("shm")
            if desc:
                neg["window"] = ShmWindow.attach(desc)
        if self.shm == "require" and neg["window"] is None:
            raise TransportError(
                f"shm='require' but server at {addr[0]}:{addr[1]} could not "
                "negotiate a same-host arena (old server, remote host, or no "
                "arena configured)"
            )
        self._neg[addr] = neg

    def _close_window(self, addr: tuple[str, int]) -> None:
        neg = self._neg.pop(addr, None)
        if neg and neg.get("window") is not None:
            neg["window"].close()

    def _drop_connection(self, addr: tuple[str, int]) -> None:
        sock = self._conns.pop(addr, None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
        # negotiation state is per-connection: a re-dial re-negotiates
        # (the server may have restarted with a brand-new arena)
        self._close_window(addr)

    # -- liveness cache -------------------------------------------------------------
    def alive(self, server: int) -> bool:
        """Cheap cache read (no network): False while the endpoint's last
        failure is inside its ``dead_backoff`` window (or the sid was
        removed from the fleet)."""
        with self._ep_lock:
            if server in self._removed:
                return False
            addr = self.endpoints[server]
        until = self._dead.get(addr)
        return until is None or time.monotonic() >= until

    def _probe(self, addr: tuple[str, int]) -> bool:
        """Short-timeout ping on a throwaway connection: cheaper than
        paying a full op timeout to rediscover a still-dead host."""
        try:
            with socket.create_connection(addr, timeout=self.probe_timeout) as s:
                s.settimeout(self.probe_timeout)
                send_frame(s, {"op": "ping", "sid": -1})
                recv_frame(s)
            return True
        except (OSError, TransportError):
            return False

    def _check_liveness(self, server: int, addr: tuple[str, int], op) -> None:
        """One cheap ping probe per failure / backoff window, fail fast
        in between.  Probing on the FIRST retry after a failure (not
        only once the backoff expires) means a transient blip on a
        block's last live replica costs one probe, not ``dead_backoff``
        seconds of failed reads; a genuinely dead host still costs at
        most one probe per window."""
        until = self._dead.get(addr)
        if until is None:
            return
        now = time.monotonic()
        if now < until and addr in self._probe_failed:
            raise TransportError(
                f"DMS server {server} at {addr[0]}:{addr[1]} marked dead for "
                f"another {until - now:.1f}s (liveness backoff); {op!r} not sent"
            )
        if not self._probe(addr):
            self._probe_failed.add(addr)
            self._dead[addr] = time.monotonic() + self.dead_backoff
            raise TransportError(
                f"DMS server {server} at {addr[0]}:{addr[1]} still unreachable "
                f"(ping probe failed); backing off {self.dead_backoff:.1f}s"
            )
        self._dead.pop(addr, None)
        self._probe_failed.discard(addr)

    def _codec_for(self, neg: "dict | None", key) -> "str | None":
        """The negotiated codec this request should use: per-key
        resolution for mapping specs (only codecs the server supports),
        the single negotiated codec otherwise."""
        if neg is None:
            return None
        if isinstance(self.wire_codec, Mapping):
            if key is None:
                return None
            c = resolve_codec(self.wire_codec, key)
            return c if c in neg["codecs"] else None
        return neg["codec"]

    def _request(
        self,
        server: int,
        header: dict,
        payload=b"",
        *,
        encode_arr=None,
        data_plane=False,
        codec_key=None,
    ) -> tuple[dict, bytearray, int]:
        with self._ep_lock:
            if server in self._removed:
                raise TransportError(
                    f"server {server} has left the fleet; {header.get('op')!r} refused"
                )
            addr = self.endpoints[server]
        t0 = time.perf_counter()
        with self._conn_locks[addr]:
            if self._closed:
                raise TransportError(
                    f"transport is closed; {header.get('op')!r} to server "
                    f"{server} refused"
                )
            self._check_liveness(server, addr, header.get("op"))
            sock = self._connection(addr)
            # negotiation outcome is per-connection, so the request's
            # data-plane fields can only be filled in once the dial (and
            # hello) above has happened
            neg = self._neg.get(addr)
            if data_plane and neg is not None:
                codec = self._codec_for(neg, codec_key)
                if codec:
                    header["codec"] = codec
                if neg["window"] is not None:
                    header["shm"] = True
            if encode_arr is not None:
                meta, payload = encode_block(encode_arr, self._codec_for(neg, codec_key))
                header["array"] = meta
            try:
                wire = send_frame(sock, header, payload)  # relint: allow(blocking-under-lock) — the per-connection lock IS the wire serialization: one request owns the socket for its full round-trip
                rheader, rpayload, rwire = recv_frame(sock)  # relint: allow(blocking-under-lock) — paired with the send above; interleaved frames would corrupt the stream
            except (OSError, TransportError) as e:
                self._drop_connection(addr)
                # fresh failure: dead-marked, but the next request earns
                # one probe (see _check_liveness) — a blip must not cost
                # the whole backoff window
                self._dead[addr] = time.monotonic() + self.dead_backoff
                self._probe_failed.discard(addr)
                raise TransportError(
                    f"DMS server {server} at {addr[0]}:{addr[1]} failed during "
                    f"{header.get('op')!r}: {e}"
                ) from e
        t1 = time.perf_counter()
        with self._stats_lock:
            # union of in-flight intervals: concurrent requests to
            # different hosts must not double-count wall time
            start = max(t0, self._busy_until)
            if t1 > start:
                self._elapsed += t1 - start
                self._busy_until = t1
        if not rheader.get("ok"):
            if rheader.get("etype") == "KeyError":
                raise KeyError(rheader.get("msg", "remote KeyError"))
            raise TransportError(
                f"server {server} rejected {header.get('op')!r}: "
                f"{rheader.get('etype')}: {rheader.get('msg')}"
            )
        return rheader, rpayload, wire + rwire

    def _scoped(self, key: RegionKey) -> RegionKey:
        if not self.scope:
            return key
        return dataclasses.replace(
            key, namespace=self.scope + _SCOPE_SEP + key.namespace
        )

    def _unscoped(self, key: RegionKey) -> RegionKey | None:
        """Strip the scope prefix; None for keys outside this scope."""
        if not self.scope:
            return key
        prefix = self.scope + _SCOPE_SEP
        if not key.namespace.startswith(prefix):
            return None
        return dataclasses.replace(key, namespace=key.namespace[len(prefix):])

    def _account(self, op: str, nbytes: int, raw: int | None = None, shm_blocks: int = 0) -> None:
        if op == "put":
            self.stats.add(
                puts=1, bytes_put=nbytes, bytes_put_raw=nbytes if raw is None else raw
            )
        elif op == "get":
            self.stats.add(
                gets=1,
                bytes_get=nbytes,
                bytes_get_raw=nbytes if raw is None else raw,
                shm_gets=shm_blocks,
            )
        else:
            self.stats.add(meta_msgs=1, bytes_meta=nbytes)

    def _window(self, server: int) -> ShmWindow | None:
        neg = self._neg.get(self._addr_of(server))
        return neg["window"] if neg else None

    def _read_shm(self, server: int, meta: dict) -> np.ndarray:
        window = self._window(server)
        if window is None:
            # a reply can only carry an shm ref when this client asked
            # for one on this connection — a missing window is a bug or
            # a torn re-dial, not a protocol state
            raise TransportError(
                f"server {server} replied with an shm reference but no "
                "arena window is mapped on this connection"
            )
        return window.read(int(meta["shm"][0]), meta, zero_copy=self.zero_copy)

    # -- Transport message API -----------------------------------------------------
    def store(self, server, key, block_coord, box, payload) -> None:
        arr = np.asarray(payload)
        header = {
            "op": "store",
            "sid": server,
            "key": _key_to_json(self._scoped(key)),
            "coord": list(block_coord),
            "bb": _bb_to_json(box),
        }
        # the payload is encoded inside _request once the connection's
        # negotiated codec is known (stores always ride the socket; the
        # server places them into its arena for later shm fetches)
        _, _, wire = self._request(server, header, encode_arr=arr, codec_key=key)
        self._account("put", wire, raw=arr.nbytes)

    def fetch(self, server, key, block_coord) -> np.ndarray:
        header = {
            "op": "fetch",
            "sid": server,
            "key": _key_to_json(self._scoped(key)),
            "coord": list(block_coord),
        }
        rheader, rpayload, wire = self._request(
            server, header, data_plane=True, codec_key=key
        )
        meta = rheader["array"]
        if "shm" in meta:
            arr = self._read_shm(server, meta)
            self._account("get", wire, raw=arr.nbytes, shm_blocks=1)
            return arr
        arr = decode_block(meta, rpayload)
        self._account("get", wire, raw=arr.nbytes)
        return arr

    def fetch_many(self, server, requests) -> list[np.ndarray]:
        """Scatter-gather fetch: N blocks in ONE round-trip.

        The response header carries per-block {shape, dtype, off, len}
        metadata; each block decodes straight out of the single receive
        buffer at its stated offset (shm-resident blocks carry an
        ``shm`` arena reference instead and skip the socket payload
        entirely).  Legacy servers omit the offsets — the client falls
        back to cumulative raw sizes in request order.
        """
        if not requests:
            return []
        per_key = isinstance(self.wire_codec, Mapping)
        if per_key:
            # per-request codec tags ride in the reqs themselves when the
            # server negotiated the pkc capability; _request leaves the
            # top-level codec unset for mapping specs, and against an old
            # server the tags below are filtered out (raw gather)
            neg = self._neg.get(self._addr_of(server))
            reqs = [
                [
                    _key_to_json(self._scoped(key)),
                    list(coord),
                    self._codec_for(neg, key) if neg and neg["pkc"] else None,
                ]
                for key, coord in requests
            ]
            if not (neg and neg["pkc"]):
                reqs = [r[:2] for r in reqs]
        else:
            reqs = [
                [_key_to_json(self._scoped(key)), list(coord)]
                for key, coord in requests
            ]
        header = {"op": "fetch_many", "sid": server, "reqs": reqs}
        rheader, rpayload, wire = self._request(server, header, data_plane=True)
        out: list[np.ndarray] = []
        view = memoryview(rpayload)
        cursor = 0
        shm_blocks = 0
        for meta in rheader["arrays"]:
            if "shm" in meta:
                out.append(self._read_shm(server, meta))
                shm_blocks += 1
                continue
            if "off" in meta:
                off, n = int(meta["off"]), int(meta["len"])
            else:  # legacy server: raw buffers back to back, no offsets
                off, n = cursor, raw_nbytes(meta)
                cursor = off + n
            out.append(decode_block(meta, view[off : off + n]))
        self._account(
            "get", wire, raw=sum(a.nbytes for a in out), shm_blocks=shm_blocks
        )
        return out

    def put_meta(self, server, key, block_coord, box, home) -> None:
        header = {
            "op": "put_meta",
            "sid": server,
            "key": _key_to_json(self._scoped(key)),
            "coord": list(block_coord),
            "bb": _bb_to_json(box),
            "home": _homes_json(home),
        }
        self._request(server, header)
        self._account("meta", META_MSG_BYTES)

    def put_meta_batch(self, server, entries) -> "list[tuple] | None":
        """One frame carrying every directory record of a put — N
        round-trips per put instead of blocks x N.  The response's
        ``had`` field lists the coords that already had an entry (the
        rollback pre-image); None when the server predates it."""
        header = {
            "op": "put_meta_batch",
            "sid": server,
            "entries": [
                [
                    _key_to_json(self._scoped(key)),
                    list(coord),
                    _bb_to_json(box),
                    _homes_json(home),
                ]
                for key, coord, box, home in entries
            ],
        }
        rheader, _, wire = self._request(server, header)
        # one wire frame, len(entries) logical directory records
        self.stats.add(meta_msgs=len(entries), bytes_meta=wire)
        had = rheader.get("had")
        return None if had is None else [tuple(c) for c in had]

    def lookup(self, server, key) -> dict[tuple, tuple[BoundingBox, int]]:
        header = {"op": "lookup", "sid": server, "key": _key_to_json(self._scoped(key))}
        rheader, _, wire = self._request(server, header)
        self._account("meta", wire)
        return {
            tuple(coord): (_bb_from_json(bb), home)
            for coord, bb, home in rheader["blocks"]
        }

    def keys(self, server) -> list[RegionKey]:
        rheader, _, wire = self._request(server, {"op": "keys", "sid": server})
        self._account("meta", wire)
        decoded = (self._unscoped(_key_from_json(k)) for k in rheader["keys"])
        return [k for k in decoded if k is not None]

    def drop(self, server, key) -> None:
        self._request(
            server, {"op": "drop", "sid": server, "key": _key_to_json(self._scoped(key))}
        )
        self._account("meta", META_MSG_BYTES)

    def drop_block(self, server, key, block_coord) -> None:
        """Per-block drop (payload + directory entry): the put-rollback
        primitive — a whole-key ``drop`` would destroy sibling blocks."""
        self._request(
            server,
            {
                "op": "drop_block",
                "sid": server,
                "key": _key_to_json(self._scoped(key)),
                "coord": list(block_coord),
            },
        )
        self._account("meta", META_MSG_BYTES)

    def payload_bytes(self, server) -> int:
        rheader, _, _ = self._request(server, {"op": "payload_bytes", "sid": server})
        return int(rheader["nbytes"])

    def join(self, server: int, sid: int, view: dict) -> "dict | None":
        """Announce ``sid``'s join under the given RingView JSON; the
        host adopts it if newer and returns the view it now holds."""
        rheader, _, wire = self._request(
            server, {"op": "join", "sid": server, "member": sid, "view": view}
        )
        self._account("meta", wire)
        return rheader.get("view")

    def leave(self, server: int, sid: int, view: dict, purge: bool = False) -> "dict | None":
        """Announce ``sid``'s leave; ``purge=True`` (sent to the host of
        the departed shard once the drain finished) also clears that
        shard's payload, directory, and arena slots."""
        header = {
            "op": "leave",
            "sid": server,
            "member": sid,
            "view": view,
            "purge": bool(purge),
        }
        rheader, _, wire = self._request(server, header)
        self._account("meta", wire)
        return rheader.get("view")

    def epoch(self, server: int) -> "dict | None":
        """The fleet view this host currently holds (RingView JSON), or
        None when it was never told one."""
        rheader, _, wire = self._request(server, {"op": "epoch", "sid": server})
        self._account("meta", wire)
        return rheader.get("view")

    def gen(self, server: int, bump=None, want=None) -> dict:
        """Write-generation gossip: bump/read per-key fleet counters on
        ``server``'s shard (see :meth:`Transport.gen`)."""
        header = {
            "op": "gen",
            "sid": server,
            "bump": list(bump or ()),
            "want": list(want or ()),
        }
        rheader, _, wire = self._request(server, header)
        self._account("meta", wire)
        return dict(rheader.get("gens") or {})

    def ping(self, server: int) -> list[int]:
        """Liveness probe; returns the shard ids the endpoint hosts."""
        rheader, _, _ = self._request(server, {"op": "ping", "sid": server})
        return list(rheader.get("sids", []))

    # -- lifecycle / accounting ------------------------------------------------------
    def virtual_time(self) -> float:
        """Measured wall seconds during which at least one request was on
        the wire (keeps ``aggregate_throughput`` meaningful over real
        sockets, including multi-threaded clients)."""
        with self._stats_lock:
            return self._elapsed

    def reset(self) -> None:
        with self._stats_lock:
            self.stats.reset()
            self._elapsed = 0.0
            self._busy_until = 0.0

    def close(self) -> None:
        # refuse new requests, then close each connection under its lock
        # so an in-flight _request finishes its frame first.  The wait is
        # bounded: a request stuck in recv on a hung host must not stall
        # shutdown for its full op_timeout — after the grace period the
        # socket is closed anyway, and the stuck recv's OSError is still
        # wrapped into TransportError by _request (never a raw mid-frame
        # error reaching the caller)
        self._closed = True
        for addr, lock in list(self._conn_locks.items()):
            acquired = lock.acquire(timeout=1.0)
            try:
                self._drop_connection(addr)
            finally:
                if acquired:
                    lock.release()


class ShmTransport(SocketTransport):
    """A :class:`SocketTransport` that requires the shared-memory data
    plane: control frames on the socket, fetch payloads through the
    server's mapped arena.  Construction fails fast (on first use of an
    endpoint) with :class:`TransportError` when the fleet is not
    co-located or predates arenas — use ``SocketTransport(shm="auto")``
    for opportunistic zero-copy that degrades to the stream."""

    def __init__(self, endpoints: Sequence, **kw) -> None:
        kw.setdefault("shm", "require")
        super().__init__(endpoints, **kw)


# ---------------------------------------------------------------------------
# server: _Server shards behind a threaded socket loop
# ---------------------------------------------------------------------------
class _NetServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(
        self,
        address: tuple[str, int],
        sids: Iterable[int],
        *,
        arena_bytes: int = DEFAULT_ARENA_BYTES,
        at_rest: bool = False,
    ) -> None:
        self.shards: dict[int, _Server] = {int(s): _Server(int(s)) for s in sids}
        self.arena_bytes = int(arena_bytes)
        self.at_rest = bool(at_rest)
        self.arena: ShmArena | None = None
        self._arena_lock = threading.Lock()
        # fleet membership view (RingView JSON) adopted via join/leave
        # announcements: highest epoch wins, served back on every
        # membership op so any client/server can catch up from any peer
        self.fleet_view: dict | None = None
        self._view_lock = threading.Lock()
        # REPRO_NET_COMPAT=1 makes this process behave like a pre-codec
        # server (hello is an unknown op, every payload raw) — the
        # mixed-fleet compatibility tests run against the real code path
        # new clients hit on old fleets, not a mock
        self.compat = os.environ.get("REPRO_NET_COMPAT", "") not in ("", "0")
        super().__init__(address, _FrameHandler)

    def _ensure_arena(self) -> ShmArena | None:
        """Create the arena on the first shm-negotiating hello — plain
        fleets never allocate /dev/shm capacity."""
        if self.arena_bytes <= 0:
            return None
        with self._arena_lock:
            if self.arena is None:
                self.arena = ShmArena(self.arena_bytes)
                for shard in self.shards.values():
                    shard.arena = self.arena
            return self.arena

    def _adopt_view(self, view: "dict | None") -> "dict | None":
        with self._view_lock:
            if view is not None and (
                self.fleet_view is None
                or int(view["epoch"]) > int(self.fleet_view["epoch"])
            ):
                self.fleet_view = dict(view)
            return None if self.fleet_view is None else dict(self.fleet_view)

    def _encode_for_reply(self, shard: _Server, key, coord, header: dict, codec=None):
        """(meta, buf) for one fetched block, honouring the request's
        negotiated data plane: shm reference > at-rest passthrough >
        wire codec > raw.  ``codec`` overrides the header's connection-
        level codec (per-key tags inside a fetch_many)."""
        if header.get("shm"):
            ref = shard.arena_ref(key, coord)
            if ref is not None:
                meta, off, nbytes = ref
                return dict(meta, shm=[off, nbytes]), b""
        if codec is None:
            codec = header.get("codec")
        block = shard.fetch_resident(key, coord)
        if isinstance(block, Encoded):
            if codec:  # codec-capable client: ship the resident blob as-is
                return dict(block.meta), memoryview(block.data)
            block = block.decode()
        return encode_block(block, codec)

    def dispatch(self, header: dict, payload: bytearray) -> tuple[dict, object]:
        op = header.get("op")
        if op == "ping":
            return {"ok": True, "sids": sorted(self.shards)}, b""
        if op == "hello":
            if self.compat:
                raise ValueError(f"unknown op {op!r}")
            resp: dict = {
                "ok": True,
                "sids": sorted(self.shards),
                "codecs": [c for c in WIRE_CODECS if c != "raw"],
                "pkc": True,  # per-key codec tags accepted in fetch_many reqs
            }
            if header.get("shm"):
                arena = self._ensure_arena()
                if arena is not None:
                    resp["shm"] = arena.describe()
            return resp, b""
        if op == "epoch":
            if self.compat:
                raise ValueError(f"unknown op {op!r}")
            return {"ok": True, "view": self._adopt_view(None)}, b""
        if op == "join":
            if self.compat:
                raise ValueError(f"unknown op {op!r}")
            return {"ok": True, "view": self._adopt_view(header.get("view"))}, b""
        if op == "leave":
            if self.compat:
                raise ValueError(f"unknown op {op!r}")
            view = self._adopt_view(header.get("view"))
            if header.get("purge"):
                departed = self.shards.get(header.get("member"))
                if departed is not None:
                    departed.clear()
            return {"ok": True, "view": view}, b""
        sid = header.get("sid")
        if sid not in self.shards:
            raise ValueError(f"shard {sid} not hosted here (have {sorted(self.shards)})")
        shard = self.shards[sid]
        if op == "gen":
            if self.compat:
                raise ValueError(f"unknown op {op!r}")
            return {
                "ok": True,
                "gens": shard.gen(header.get("bump"), header.get("want")),
            }, b""
        if op == "store":
            meta = header["array"]
            key = _key_from_json(header["key"])
            coord = tuple(header["coord"])
            box = _bb_from_json(header["bb"])
            if self.at_rest and is_lossless(meta) and meta.get("codec"):
                # keep the losslessly-compressed blob resident: decode is
                # deferred to fetch time (plain clients) or skipped
                # entirely (codec clients get the blob passed through)
                shard.store(key, coord, box, Encoded(meta, bytes(payload)))
            else:
                shard.store(
                    key,
                    coord,
                    box,
                    decode_block(meta, payload),
                    owned=True,  # the frame buffer is private: no second copy
                )
            return {"ok": True}, b""
        if op == "fetch":
            meta, buf = self._encode_for_reply(
                shard, _key_from_json(header["key"]), tuple(header["coord"]), header
            )
            return {"ok": True, "array": meta}, buf
        if op == "fetch_many":
            # scatter-IO: per-block buffers with explicit offsets in the
            # header; the send path hands the list straight to sendmsg —
            # payloads are never concatenated server-side
            metas, bufs = [], []
            off = 0
            for req in header["reqs"]:
                kj, coord = req[0], req[1]
                meta, buf = self._encode_for_reply(
                    shard,
                    _key_from_json(kj),
                    tuple(coord),
                    header,
                    codec=req[2] if len(req) > 2 else None,
                )
                n = _nbytes(buf)
                if "shm" not in meta:
                    meta = dict(meta, off=off, len=n)
                    off += n
                    bufs.append(buf)
                metas.append(meta)
            return {"ok": True, "arrays": metas}, bufs
        if op == "put_meta":
            shard.put_meta(
                _key_from_json(header["key"]),
                tuple(header["coord"]),
                _bb_from_json(header["bb"]),
                _homes_json(header["home"]),
            )
            return {"ok": True}, b""
        if op == "put_meta_batch":
            existing: dict = {}
            had = []
            for kj, coord, bbj, home in header["entries"]:
                key = _key_from_json(kj)
                if key not in existing:
                    existing[key] = shard.lookup(key)
                if tuple(coord) in existing[key]:
                    had.append(list(coord))
                shard.put_meta(key, tuple(coord), _bb_from_json(bbj), _homes_json(home))
            return {"ok": True, "had": had}, b""
        if op == "lookup":
            blocks = shard.lookup(_key_from_json(header["key"]))
            return {
                "ok": True,
                "blocks": [
                    [list(coord), _bb_to_json(bb), home]
                    for coord, (bb, home) in blocks.items()
                ],
            }, b""
        if op == "keys":
            return {"ok": True, "keys": [_key_to_json(k) for k in shard.keys()]}, b""
        if op == "drop":
            shard.drop(_key_from_json(header["key"]))
            return {"ok": True}, b""
        if op == "drop_block":
            shard.drop_block(_key_from_json(header["key"]), tuple(header["coord"]))
            return {"ok": True}, b""
        if op == "payload_bytes":
            return {"ok": True, "nbytes": shard.payload_bytes}, b""
        raise ValueError(f"unknown op {op!r}")


class _FrameHandler(socketserver.BaseRequestHandler):
    def handle(self) -> None:
        sock: socket.socket = self.request
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while True:
            try:
                header, payload, _ = recv_frame(sock)
            except (TransportError, ConnectionError, OSError):
                return  # client went away
            try:
                rheader, rpayload = self.server.dispatch(header, payload)
            except Exception as e:  # noqa: BLE001 — every error crosses the wire
                rheader, rpayload = (
                    {"ok": False, "etype": type(e).__name__, "msg": str(e)},
                    b"",
                )
            try:
                if isinstance(rpayload, list):
                    send_frame_parts(sock, rheader, rpayload)
                else:
                    send_frame(sock, rheader, rpayload)
            except OSError:
                return


def serve(
    host: str = "127.0.0.1",
    port: int = 0,
    sids: Iterable[int] = (0,),
    *,
    arena_bytes: int = DEFAULT_ARENA_BYTES,
    at_rest: bool = False,
) -> None:
    """Run a shard host in the foreground (the ``python -m`` entry).

    Prints ``REPRO_NET LISTENING <port>`` once bound so a parent process
    (or an operator's script) can discover the ephemeral port.
    """
    import signal

    server = _NetServer((host, port), sids, arena_bytes=arena_bytes, at_rest=at_rest)

    def _sigterm(_sig, _frm):
        # ServerProcess.stop() sends SIGTERM; without a handler the
        # finally below never runs and the shm arena is left for the
        # parent's resource tracker to reclaim (noisily)
        raise SystemExit(0)

    try:
        signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass  # not the main thread (embedded use)
    print(f"REPRO_NET LISTENING {server.server_address[1]}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        if server.arena is not None:
            server.arena.close(unlink=True)


# ---------------------------------------------------------------------------
# process management
# ---------------------------------------------------------------------------
def _src_root() -> str:
    # net.py lives at <src>/repro/storage/net.py
    return os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


class ServerProcess:
    """Handle on one shard-host subprocess (``python -m repro.storage.net``)."""

    def __init__(
        self,
        sids: Iterable[int] = (0,),
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        startup_timeout: float = 60.0,
        arena_bytes: int = DEFAULT_ARENA_BYTES,
        at_rest: bool = False,
        extra_env: dict[str, str] | None = None,
    ) -> None:
        self.sids = [int(s) for s in sids]
        self.host = host
        self.port = int(port)
        self.startup_timeout = startup_timeout
        self.arena_bytes = int(arena_bytes)
        self.at_rest = bool(at_rest)
        self.extra_env = dict(extra_env) if extra_env else {}
        self.proc: subprocess.Popen | None = None

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    def start(self) -> "ServerProcess":
        if self.proc is not None:
            raise RuntimeError("ServerProcess already started")
        env = os.environ.copy()
        env.update(self.extra_env)
        env["PYTHONPATH"] = _src_root() + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        cmd = [
            sys.executable,
            "-u",
            "-m",
            "repro.storage.net",
            "--host",
            self.host,
            "--port",
            str(self.port),
            "--sids",
            ",".join(map(str, self.sids)),
            "--arena-bytes",
            str(self.arena_bytes),
        ]
        if self.at_rest:
            cmd.append("--at-rest")
        self.proc = subprocess.Popen(
            cmd,
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            env=env,
            text=True,
        )
        # a reader thread feeds a queue so the deadline holds even when
        # the child stays alive but silent (readline would block forever);
        # after startup the same thread keeps the pipe drained
        lines: "queue.Queue[str | None]" = queue.Queue()
        threading.Thread(
            target=self._drain, args=(self.proc.stdout, lines), daemon=True
        ).start()
        deadline = time.monotonic() + self.startup_timeout
        banner: list[str] = []
        while True:
            try:
                line = lines.get(timeout=max(deadline - time.monotonic(), 0.01))
            except queue.Empty:
                self.stop()
                raise TransportError(
                    f"DMS server startup timed out after {self.startup_timeout}s: "
                    + "".join(banner[-20:])
                ) from None
            if line is None:
                code = self.proc.poll()
                self.proc = None  # failed boot: the handle must stay retryable
                raise TransportError(
                    f"DMS server failed to start (exit={code}): "
                    + "".join(banner[-20:])
                )
            if line.startswith("REPRO_NET LISTENING"):
                self.port = int(line.split()[2])
                break
            banner.append(line)
        return self

    @staticmethod
    def _drain(stream, lines: "queue.Queue") -> None:
        try:
            for line in stream:
                lines.put(line)
        except (ValueError, OSError):
            pass
        finally:
            lines.put(None)

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def stop(self, timeout: float = 5.0) -> None:
        if self.proc is None:
            return
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        # reset the handle so start() works again — a stopped/crashed
        # server must be restartable on its (now known) port, which is
        # the crash-simulation primitive the failover tests build on
        self.proc = None

    def kill(self) -> None:
        """Hard-kill (crash simulation for failover/restart tests)."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()
        self.proc = None

    def __enter__(self) -> "ServerProcess":
        return self.start() if self.proc is None else self

    def __exit__(self, *exc) -> None:
        self.stop()


class ServerGroup:
    """A started fleet of shard hosts + the endpoint table for clients."""

    def __init__(self, procs: list[ServerProcess], endpoints: list[tuple[str, int]]):
        self.procs = procs
        self.endpoints = endpoints

    @property
    def num_servers(self) -> int:
        return len(self.endpoints)

    def transport(self, **kw) -> SocketTransport:
        return SocketTransport(self.endpoints, **kw)

    def add_server(self, *, sid: int | None = None, **kw) -> tuple[int, tuple[str, int]]:
        """Start one more shard host (for elastic-join tests/deploys):
        boots a fresh :class:`ServerProcess` for ``sid`` (default: next
        free id), appends it to the group, and returns ``(sid,
        address)`` — feed both to ``DistributedMemoryStorage.
        add_server`` to bring it into the ring."""
        sid = (max((s for p in self.procs for s in p.sids), default=-1) + 1
               if sid is None else int(sid))
        if sid > len(self.endpoints):
            # sids are endpoint-table indices: a skipped-ahead id would
            # leave placeholder rows that crash transport construction
            raise ValueError(
                f"sid {sid} skips ahead of the endpoint table "
                f"(next free id is {len(self.endpoints)})"
            )
        sp = ServerProcess([sid], **kw).start()
        self.procs.append(sp)
        if sid < len(self.endpoints):
            self.endpoints[sid] = sp.address
        else:
            self.endpoints.append(sp.address)
        return sid, sp.address

    def proc_for(self, sid: int) -> ServerProcess | None:
        for p in self.procs:
            if int(sid) in p.sids:
                return p
        return None

    def close(self) -> None:
        for p in self.procs:
            p.stop()

    def __enter__(self) -> "ServerGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def spawn_servers(
    num_servers: int,
    *,
    processes: int | None = None,
    host: str = "127.0.0.1",
    startup_timeout: float = 60.0,
    arena_bytes: int = DEFAULT_ARENA_BYTES,
    at_rest: bool = False,
    extra_env: dict[str, str] | None = None,
) -> ServerGroup:
    """Start ``num_servers`` shards spread over ``processes`` hosts.

    Defaults to one process per shard (the fully distributed shape);
    ``processes=M`` packs shards contiguously onto M processes, matching
    a deployment where each node runs one server daemon with several
    shards.  Each process gets an ``arena_bytes`` shared-memory budget
    (allocated lazily on the first shm-negotiating client; 0 disables);
    ``at_rest=True`` keeps losslessly-compressed puts resident in
    compressed form.
    """
    num_servers = int(num_servers)
    if num_servers < 1:
        raise ValueError("need at least one server")
    processes = num_servers if processes is None else max(1, min(processes, num_servers))
    per = -(-num_servers // processes)  # ceil
    procs: list[ServerProcess] = []
    endpoints: list[tuple[str, int] | None] = [None] * num_servers
    try:
        for p in range(processes):
            sids = list(range(p * per, min((p + 1) * per, num_servers)))
            if not sids:
                break
            sp = ServerProcess(
                sids,
                host=host,
                startup_timeout=startup_timeout,
                arena_bytes=arena_bytes,
                at_rest=at_rest,
                extra_env=extra_env,
            ).start()
            procs.append(sp)
            for sid in sids:
                endpoints[sid] = sp.address
    except Exception:
        for sp in procs:
            sp.stop()
        raise
    return ServerGroup(procs, endpoints)


def main(argv: Sequence[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.storage.net",
        description="Host DMS storage shards behind a TCP socket loop.",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0, help="0 = pick an ephemeral port")
    ap.add_argument(
        "--sids", default="0", help="comma-separated global shard ids hosted here"
    )
    ap.add_argument(
        "--arena-bytes",
        type=int,
        default=DEFAULT_ARENA_BYTES,
        help="shared-memory arena budget for same-host zero-copy fetches "
        "(allocated lazily on first use; 0 disables)",
    )
    ap.add_argument(
        "--at-rest",
        action="store_true",
        help="keep losslessly-compressed puts resident in compressed form",
    )
    args = ap.parse_args(argv)
    sids = [int(s) for s in args.sids.split(",") if s.strip() != ""]
    serve(args.host, args.port, sids, arena_bytes=args.arena_bytes, at_rest=args.at_rest)


if __name__ == "__main__":
    main()
