"""Block codecs for the storage data plane (wire + at-rest compression).

The socket transport moves every payload block as ``header {shape,
dtype} + raw little-endian buffer``.  This module adds the optional
compression layer on top of that frame format: a block is encoded into
``(meta, buf)`` where ``meta`` extends the raw array header with a
``codec`` tag (absent for raw — the legacy wire format, byte-for-byte),
and decoded back by dispatching on that tag.  Because every encoded
block is self-describing, mixed fleets interoperate: an old client never
sends a ``codec`` tag and an old server never emits one, and both sides
fall back to raw.

Codecs (``WIRE_CODECS``):

  * ``raw``  — identity; the legacy format.
  * ``zlib`` — lossless DEFLATE over the raw buffer.  The right choice
    for uint8/int label tiles and masks (mostly-constant runs compress
    10x+); bit-exact for every dtype.
  * ``bf16`` — lossy: float32/float64 cast to bfloat16 on the wire and
    cast back on decode (2x/4x fewer bytes).  Non-float payloads (label
    maps, masks, bools) fall back to ``zlib`` — lossy modes must never
    corrupt discrete data.
  * ``int8`` — lossy: float32/float64 quantized to int8 with a
    per-block max-abs scale (the ``train/compression.py`` idiom), 4x/8x
    fewer bytes.  Non-float payloads fall back to ``zlib``.

``Encoded`` is the at-rest form: a server started with at-rest
compression keeps the losslessly-compressed blob resident instead of the
decoded array (capacity saving), decodes lazily for plain clients, and
passes the blob straight through to codec-negotiated clients.
"""
from __future__ import annotations

import dataclasses
import zlib
from fnmatch import fnmatchcase
from typing import Mapping

import numpy as np

WIRE_CODECS = ("raw", "zlib", "bf16", "int8")

# lossy modes only ever touch these dtypes; everything else (labels,
# masks, counts) silently degrades to lossless zlib
_LOSSY_DTYPES = (np.float32, np.float64)

_ZLIB_LEVEL = 1  # speed over ratio: label tiles still compress 10x+


def check_codec(name):
    """Normalize a codec spec.

    A plain name: ``None``/``"raw"`` -> ``None`` (plain wire), anything
    else must be a member of :data:`WIRE_CODECS`.

    A mapping is a PER-KEY override table — glob patterns over region
    keys (``{"labels/*": "zlib", "feat/*": "bf16"}``) mapped to codec
    names, matched first-hit-wins in insertion order by
    :func:`resolve_codec`; an explicit ``None``/``"raw"`` value forces
    plain wire for its pattern.  The normalized mapping is returned with
    every codec name validated.
    """
    if isinstance(name, Mapping):
        out = {}
        for pattern, codec in name.items():
            if not isinstance(pattern, str) or not pattern:
                raise ValueError(f"wire_codec pattern must be a non-empty str, got {pattern!r}")
            out[pattern] = check_codec(codec) if not isinstance(codec, Mapping) else _reject(codec)
        return out
    if name is None or name == "raw":
        return None
    if name not in WIRE_CODECS:
        raise ValueError(f"unknown wire codec {name!r} (want one of {WIRE_CODECS})")
    return name


def _reject(codec):
    raise ValueError(f"nested wire_codec mapping {codec!r} is not allowed")


def codec_names(spec) -> list[str]:
    """The distinct non-raw codec names a spec can emit — what the
    connection negotiation must ask the server to support."""
    if isinstance(spec, Mapping):
        return sorted({c for c in spec.values() if c is not None})
    return [] if spec in (None, "raw") else [spec]


def resolve_codec(spec, key) -> str | None:
    """The codec a (possibly per-key) spec picks for ``key``.

    ``key`` is anything with ``namespace``/``name`` attributes (a
    ``RegionKey``) or a plain string.  Mapping specs match each glob
    pattern — in insertion order, first hit wins — against
    ``"namespace/name"``, then the bare ``name``, then the bare
    ``namespace``; no hit means plain wire (raw is the safe default for
    keys the override table never anticipated).
    """
    if not isinstance(spec, Mapping):
        return check_codec(spec)
    ns = getattr(key, "namespace", None)
    name = getattr(key, "name", None)
    if ns is None and name is None:
        candidates = [str(key)]
    else:
        candidates = [f"{ns}/{name}", str(name), str(ns)]
    for pattern, codec in spec.items():
        if any(fnmatchcase(c, pattern) for c in candidates):
            return check_codec(codec)
    return None


def _dtype_from_str(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        # jax extended dtypes (bfloat16, float8_*) register with ml_dtypes
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def raw_nbytes(meta: dict) -> int:
    """Decoded payload size implied by an array header."""
    n = 1
    for s in meta["shape"]:
        n *= int(s)
    return n * _dtype_from_str(meta["dtype"]).itemsize


def encode_array(arr: np.ndarray) -> tuple[dict, memoryview]:
    """(meta, buffer): raw C-order bytes + {shape, dtype} — no pickling."""
    arr = np.ascontiguousarray(arr)
    meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if not arr.nbytes:
        return meta, memoryview(b"")
    try:
        return meta, arr.data.cast("B")  # zero-copy
    except ValueError:
        # extended dtypes (bfloat16, float8_*) refuse the buffer protocol
        return meta, memoryview(arr.tobytes())


def decode_array(meta: dict, payload) -> np.ndarray:
    dt = _dtype_from_str(meta["dtype"])
    return np.frombuffer(payload, dtype=dt).reshape(tuple(meta["shape"]))


def encode_block(arr: np.ndarray, codec: str | None) -> tuple[dict, memoryview]:
    """Encode one block for the wire.

    Returns ``(meta, buf)``; ``meta`` is the raw array header plus a
    ``codec`` tag when the payload is actually transformed (raw output
    carries no tag, so it is byte-identical to the legacy format and old
    decoders keep working).  Empty blocks always go raw: there is
    nothing to save and zlib headers would *add* bytes.
    """
    codec = check_codec(codec)
    meta, buf = encode_array(arr)
    if codec is None or not buf.nbytes:
        return meta, buf
    if codec in ("bf16", "int8") and arr.dtype.type in _LOSSY_DTYPES:
        if codec == "bf16":
            import ml_dtypes

            small = np.ascontiguousarray(arr).astype(ml_dtypes.bfloat16)
            meta = dict(meta, codec="bf16")
            return meta, memoryview(small.tobytes())
        absmax = float(np.max(np.abs(arr))) if arr.size else 0.0
        scale = max(absmax, 1e-12) / 127.0
        q = np.clip(np.round(np.asarray(arr, np.float64) / scale), -127, 127)
        meta = dict(meta, codec="int8", scale=scale)
        return meta, memoryview(np.ascontiguousarray(q.astype(np.int8)).data.cast("B"))
    # zlib for explicit "zlib" and as the lossless fallback of lossy modes
    blob = zlib.compress(bytes(buf), _ZLIB_LEVEL)
    if len(blob) >= buf.nbytes:
        return meta, buf  # incompressible: raw is strictly better
    meta = dict(meta, codec="zlib")
    return meta, memoryview(blob)


def decode_block(meta: dict, payload) -> np.ndarray:
    """Decode one self-describing block (raw when no ``codec`` tag)."""
    codec = meta.get("codec")
    if codec is None:
        return decode_array(meta, payload)
    if codec == "zlib":
        return decode_array(meta, zlib.decompress(bytes(payload)))
    shape = tuple(meta["shape"])
    dt = _dtype_from_str(meta["dtype"])
    if codec == "bf16":
        import ml_dtypes

        return np.frombuffer(payload, dtype=ml_dtypes.bfloat16).reshape(shape).astype(dt)
    if codec == "int8":
        q = np.frombuffer(payload, dtype=np.int8).reshape(shape)
        return (q.astype(np.float64) * float(meta["scale"])).astype(dt)
    raise ValueError(f"unknown codec tag {codec!r} in block header")


def is_lossless(meta: dict) -> bool:
    """True when the encoded payload reproduces the block bit-exact —
    the precondition for keeping it as the at-rest resident form."""
    return meta.get("codec") in (None, "zlib")


@dataclasses.dataclass
class Encoded:
    """An at-rest compressed block: the wire blob + its array header.

    Storage servers keep these resident instead of decoded arrays when
    at-rest compression is on (``meta`` must be lossless — enforce with
    :func:`is_lossless` before storing).  ``nbytes`` is the RESIDENT
    size, which is what ``payload_bytes`` capacity accounting should
    see; ``raw_nbytes`` is the decoded size.
    """

    meta: dict
    data: bytes

    @property
    def nbytes(self) -> int:
        return len(self.data)

    @property
    def raw_nbytes(self) -> int:
        return raw_nbytes(self.meta)

    def decode(self) -> np.ndarray:
        return decode_block(self.meta, self.data)
