"""Elastic fleet membership: the epoch'd SFC ring (single placement truth).

The paper's deployment assumes a fixed server set for the whole run; the
DMS inherited that as a frozen ``num_servers`` captured at construction.
This module removes the assumption.  A :class:`RingView` is a versioned,
immutable snapshot of the fleet:

  * a **monotone epoch number** — every membership change (join/leave)
    produces a new view with ``epoch + 1``; servers and clients adopt
    whichever view carries the highest epoch, so propagation order never
    matters;
  * the **ordered live-server set** — join order is preserved, and the
    replica walk (home, then successors) follows this order, so the
    genesis view reproduces the legacy ``(home + i) % N`` ring exactly;
  * the **arc table** — who owns which span of the SFC virtual domain.

Arcs are *exact rationals* over the unit interval, independent of any
particular store's virtual-domain size: a block with compacted SFC rank
``r`` out of ``V`` lives at point ``r/V`` and is owned by the arc that
contains it.  The genesis arcs put server ``i``'s boundary at ``i/n``,
which makes ``owner(r, V) == (r * n) // V`` — bit-identical to the
legacy range partition, so a never-resized fleet sees zero placement
change from this refactor.

Minimal remap (the property the rebalancer and its tests rely on):

  * ``join(sid)`` — every existing server donates exactly ``1/(m+1)`` of
    its share (peeled from the tail of its arc list) to the newcomer.  A
    block moves **iff** the newcomer now owns its point; nothing shuffles
    between the incumbents.  With the equal shares the scheme maintains,
    that is ``K/(m+1)`` blocks for K blocks on m servers.
  * ``leave(sid)`` — only the departed server's arcs change hands,
    redistributed proportionally over the survivors (in ring order).  A
    block moves **iff** the departed server owned its point: ``K/m``
    blocks.

Because donations are exact fractions, shares stay *exactly* equal
(``1/m`` each) through any join/leave sequence — the property test
asserts equality, not a tolerance.

:class:`~repro.core.pacing.TokenBucket` (re-exported here for backward
compatibility) is the rebalance pacer: the sweep pays one token per
migrated block, so background migration yields to foreground traffic at
a configurable blocks/second rate instead of saturating the fleet.
"""
from __future__ import annotations

import bisect
import hashlib
import json
from fractions import Fraction
from typing import Iterable, Sequence

from repro.core.pacing import TokenBucket

__all__ = ["RingView", "TokenBucket", "adopt_newer"]


class RingView:
    """Immutable fleet snapshot: ``(epoch, ordered servers, arc table)``.

    Construct with :meth:`genesis`, evolve with :meth:`join` /
    :meth:`leave` (each returns a NEW view with ``epoch + 1``), compare
    with ``epoch`` (monotone) or :meth:`checksum` (content digest).
    Serializes to plain JSON for the ``join``/``leave``/``epoch``
    transport ops, with arcs as exact ``[numerator, denominator, owner]``
    triples.
    """

    __slots__ = ("epoch", "servers", "_starts", "_owners")

    def __init__(
        self,
        epoch: int,
        servers: Sequence[int],
        arcs: Iterable[tuple[Fraction, int]],
    ) -> None:
        self.epoch = int(epoch)
        self.servers = tuple(int(s) for s in servers)
        pairs = sorted((Fraction(a), int(s)) for a, s in arcs)
        if not pairs or pairs[0][0] != 0:
            raise ValueError("arc table must start at 0")
        # merge adjacent same-owner arcs so the table stays compact
        starts: list[Fraction] = []
        owners: list[int] = []
        for start, owner in pairs:
            if owners and owners[-1] == owner:
                continue
            starts.append(start)
            owners.append(owner)
        self._starts = tuple(starts)
        self._owners = tuple(owners)
        live = set(self.servers)
        if not live.issuperset(owners):
            raise ValueError(f"arc owners {sorted(set(owners) - live)} not in live set")

    # -- construction / evolution --------------------------------------

    @classmethod
    def genesis(cls, num_servers: int) -> "RingView":
        """Epoch 0 over servers ``0..n-1`` with the legacy range
        partition: server ``i`` owns ``[i/n, (i+1)/n)``."""
        n = int(num_servers)
        if n < 1:
            raise ValueError("need at least one server")
        return cls(0, range(n), [(Fraction(i, n), i) for i in range(n)])

    def _arc_list(self) -> list[list]:
        """Mutable ``[start, end, owner]`` rows (end exclusive)."""
        rows = []
        for i, (start, owner) in enumerate(zip(self._starts, self._owners)):
            end = self._starts[i + 1] if i + 1 < len(self._starts) else Fraction(1)
            rows.append([start, end, owner])
        return rows

    def join(self, sid: int) -> "RingView":
        """New view with ``sid`` appended: every incumbent donates
        exactly ``share/(m+1)`` from the tail of its arc list, so only
        the newcomer's arcs change owner (minimal remap)."""
        sid = int(sid)
        if sid in self.servers:
            raise ValueError(f"server {sid} is already a ring member")
        m = len(self.servers)
        rows = self._arc_list()
        out: list[tuple[Fraction, int]] = []
        for owner in self.servers:
            mine = [r for r in rows if r[2] == owner]
            donate = sum((r[1] - r[0] for r in mine), Fraction(0)) / (m + 1)
            # peel the donation off the tail (highest-start arcs first)
            for r in reversed(mine):
                if donate <= 0:
                    break
                width = r[1] - r[0]
                give = min(width, donate)
                out.append((r[1] - give, sid))  # donated span -> newcomer
                r[1] -= give
                donate -= give
        out.extend((r[0], r[2]) for r in rows if r[1] > r[0])
        return RingView(self.epoch + 1, self.servers + (sid,), out)

    def leave(self, sid: int) -> "RingView":
        """New view without ``sid``: its arcs are handed to the
        survivors proportionally to their shares (walked in ring
        order), so only the departed server's arcs change owner."""
        sid = int(sid)
        if sid not in self.servers:
            raise ValueError(f"server {sid} is not a ring member")
        survivors = tuple(s for s in self.servers if s != sid)
        if not survivors:
            raise ValueError("cannot remove the last ring member")
        rows = self._arc_list()
        freed = [r for r in rows if r[2] == sid]
        kept = [r for r in rows if r[2] != sid]
        total = sum((r[1] - r[0] for r in freed), Fraction(0))
        shares = {
            s: sum((r[1] - r[0] for r in kept if r[2] == s), Fraction(0))
            for s in survivors
        }
        remaining = 1 - total
        out = [(r[0], r[2]) for r in kept]
        cursor = 0  # index into freed
        offset = Fraction(0)  # consumed prefix of freed[cursor]
        granted = Fraction(0)
        for i, s in enumerate(survivors):
            if i + 1 == len(survivors):
                gain = total - granted  # exact remainder to the last survivor
            else:
                gain = shares[s] * total / remaining if remaining else Fraction(0)
            granted += gain
            while gain > 0 and cursor < len(freed):
                lo, hi, _ = freed[cursor]
                lo = lo + offset
                width = hi - lo
                take = min(width, gain)
                out.append((lo, s))
                gain -= take
                if take == width:
                    cursor += 1
                    offset = Fraction(0)
                else:
                    offset += take
        return RingView(self.epoch + 1, survivors, out)

    # -- placement ------------------------------------------------------

    def owner(self, rank: int, virtual_size: int) -> int:
        """Home server of the block at compacted SFC ``rank`` (of
        ``virtual_size``): the owner of the arc containing ``rank/V``."""
        point = Fraction(int(rank), int(virtual_size))
        i = bisect.bisect_right(self._starts, point) - 1
        return self._owners[i]

    def walk(self, rank: int, virtual_size: int) -> list[int]:
        """Replica ring order for a block: its home first, then the
        remaining live servers in ring (join) order — the elastic
        generalization of the legacy ``(home + i) % N`` walk."""
        home = self.owner(rank, virtual_size)
        i = self.servers.index(home)
        n = len(self.servers)
        return [self.servers[(i + j) % n] for j in range(n)]

    def share(self, sid: int) -> Fraction:
        """Exact fraction of the SFC domain ``sid`` owns."""
        total = Fraction(0)
        for i, owner in enumerate(self._owners):
            if owner != int(sid):
                continue
            end = self._starts[i + 1] if i + 1 < len(self._starts) else Fraction(1)
            total += end - self._starts[i]
        return total

    @property
    def arcs(self) -> tuple[tuple[Fraction, int], ...]:
        return tuple(zip(self._starts, self._owners))

    # -- wire form ------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "epoch": self.epoch,
            "servers": list(self.servers),
            "arcs": [
                [s.numerator, s.denominator, o]
                for s, o in zip(self._starts, self._owners)
            ],
        }

    @classmethod
    def from_json(cls, data: dict) -> "RingView":
        return cls(
            data["epoch"],
            data["servers"],
            [(Fraction(int(n), int(d)), int(o)) for n, d, o in data["arcs"]],
        )

    def checksum(self) -> str:
        """Short content digest of the view — epoch'd placement truth in
        one comparable token (operator dashboards, rebalance reports)."""
        blob = json.dumps(self.to_json(), separators=(",", ":"), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, RingView)
            and self.epoch == other.epoch
            and self.servers == other.servers
            and self._starts == other._starts
            and self._owners == other._owners
        )

    def __hash__(self) -> int:
        return hash((self.epoch, self.servers, self._starts, self._owners))

    def __repr__(self) -> str:
        return (
            f"RingView(epoch={self.epoch}, servers={list(self.servers)}, "
            f"arcs={len(self._starts)})"
        )


def adopt_newer(current: "RingView | None", candidate: "RingView | None"):
    """The propagation rule, shared by servers and clients: keep
    whichever view has the higher epoch (ties keep the incumbent —
    epochs are produced by a single coordinator per change, so a tie IS
    the same view)."""
    if candidate is None:
        return current
    if current is None or candidate.epoch > current.epoch:
        return candidate
    return current
