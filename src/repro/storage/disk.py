"""High-performance disk storage for data regions (paper S4.2).

An ADIOS-style chunked staging engine extended exactly the way the paper
extends ADIOS:

  (i)  *separated I/O cores*: writers can be dedicated I/O workers coupled
       to compute through queues, instead of every compute core writing
       (co-located);
  (ii) *configurable I/O group sizes*: the cores participating in I/O are
       partitioned into groups of size ``k``; a group enters a write
       session together (synchronizing only within the group) once its
       buffered chunk count reaches ``queue_threshold`` — no cross-group
       synchronization (the paper's 1.13x win over stock single-group
       ADIOS).

Transports:
  * ``posix``      — every chunk becomes its own file, written immediately,
                     no group synchronization (group size effectively 1);
  * ``aggregated`` — chunks buffer per group and flush as one combined file
                     per write session (models MPI_LUSTRE / MPI_AMR
                     staging: fewer, larger I/O requests).

Chunks are raw little-endian payloads with all metadata in a
``manifest.jsonl`` (append-only, crash-tolerant) so a fresh process can
reopen the store — this is what checkpoint restart builds on.

Every operation is accounted in both wall time and a *virtual-time* cost
model (disk bandwidth, per-file open cost, per-member sync cost) so the
benchmark suite can reproduce the paper's Titan experiment shapes on one
box.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import random
import threading
import time
import uuid

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import ElementType, RegionKey


@dataclasses.dataclass
class DiskCostModel:
    """Virtual-time constants (defaults roughly Lustre-on-Titan flavored)."""

    disk_bandwidth: float = 1.2e9  # bytes/s per I/O stream
    file_open_cost: float = 4e-3  # s per file creation
    sync_cost: float = 5e-4  # s per member per group write session
    comm_bandwidth: float = 5.0e9  # bytes/s compute->I/O worker link
    comm_latency: float = 5e-6


@dataclasses.dataclass
class DiskStats:
    chunks_written: int = 0
    files_written: int = 0
    sessions: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    wall_write_s: float = 0.0
    virtual_io_s: float = 0.0
    virtual_sync_s: float = 0.0
    virtual_comm_s: float = 0.0

    @property
    def virtual_total_s(self) -> float:
        return self.virtual_io_s + self.virtual_sync_s + self.virtual_comm_s


def _key_to_json(key: RegionKey) -> dict:
    return {
        "ns": key.namespace,
        "name": key.name,
        "et": int(key.elem_type),
        "ts": key.timestamp,
        "v": key.version,
    }


def _key_from_json(d: dict) -> RegionKey:
    return RegionKey(d["ns"], d["name"], ElementType(d["et"]), d["ts"], d["v"])


def _bb_to_json(bb: BoundingBox) -> dict:
    return {"lo": list(bb.lo), "hi": list(bb.hi), "tlo": bb.t_lo, "thi": bb.t_hi}


def _bb_from_json(d: dict) -> BoundingBox:
    return BoundingBox(tuple(d["lo"]), tuple(d["hi"]), d["tlo"], d["thi"])


@dataclasses.dataclass
class _Chunk:
    key: RegionKey
    bb: BoundingBox
    payload: np.ndarray


@dataclasses.dataclass
class _ManifestEntry:
    key: RegionKey
    bb: BoundingBox
    file: str
    offset: int
    nbytes: int
    shape: tuple[int, ...]
    dtype: str


class _IOGroup:
    """Writers sharing one write session (paper: ADIOS group)."""

    def __init__(self, gid: int, store: "DiskStorage") -> None:
        self.gid = gid
        self.store = store
        self.buffer: list[_Chunk] = []
        self.members = 0
        self.lock = threading.Lock()

    def submit(self, chunk: _Chunk) -> None:
        flush_now: list[_Chunk] | None = None
        with self.lock:
            self.buffer.append(chunk)
            if len(self.buffer) >= self.store.queue_threshold:
                flush_now, self.buffer = self.buffer, []
        if flush_now:
            self.store._write_session(self, flush_now)

    def drain(self) -> None:
        with self.lock:
            chunks, self.buffer = self.buffer, []
        if chunks:
            self.store._write_session(self, chunks)


class _IOWorker(threading.Thread):
    """Dedicated I/O core for the *separated* configuration."""

    def __init__(self, wid: int, group: _IOGroup) -> None:
        super().__init__(daemon=True, name=f"io-worker-{wid}")
        self.wid = wid
        self.group = group
        self.q: "queue.Queue[_Chunk | None]" = queue.Queue()

    def run(self) -> None:
        while True:
            item = self.q.get()
            if item is None:
                self.group.drain()
                return
            self.group.submit(item)


class DiskStorage:
    """The ``DISK`` global storage backend (StorageBackend protocol)."""

    def __init__(
        self,
        root: str,
        *,
        name: str = "DISK",
        transport: str = "posix",  # posix | aggregated
        io_mode: str = "colocated",  # colocated | separated
        io_group_size: int = 1,
        num_io_workers: int = 0,
        queue_threshold: int = 4,
        distribution: str = "round_robin",  # round_robin | random
        cost_model: DiskCostModel | None = None,
        seed: int = 0,
    ) -> None:
        if transport not in ("posix", "aggregated"):
            raise ValueError(f"unknown transport {transport!r}")
        if io_mode not in ("colocated", "separated"):
            raise ValueError(f"unknown io_mode {io_mode!r}")
        self.name = name
        self.root = root
        self.transport = transport
        self.io_mode = io_mode
        self.io_group_size = max(1, int(io_group_size))
        self.queue_threshold = max(1, int(queue_threshold)) if transport == "aggregated" else 1
        self.distribution = distribution
        self.cost = cost_model or DiskCostModel()
        self.stats = DiskStats()
        self._rng = random.Random(seed)
        self._rr = 0
        self._lock = threading.Lock()
        self._index: dict[RegionKey, list[_ManifestEntry]] = {}
        os.makedirs(root, exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.jsonl")
        self._manifest_lock = threading.Lock()
        self._load_manifest()

        self._workers: list[_IOWorker] = []
        self._groups: list[_IOGroup] = []
        if io_mode == "separated":
            n = max(1, int(num_io_workers))
            n_groups = max(1, n // self.io_group_size)
            self._groups = [_IOGroup(g, self) for g in range(n_groups)]
            for g in self._groups:
                g.members = 0
            for w in range(n):
                grp = self._groups[w % n_groups]
                grp.members += 1
                self._workers.append(_IOWorker(w, grp))
            for w in self._workers:
                w.start()
        else:
            # co-located: every caller is a writer; group per io_group_size slots
            self._colocated_groups: dict[int, _IOGroup] = {}

    # -- manifest ------------------------------------------------------------------
    def _load_manifest(self) -> None:
        if not os.path.exists(self._manifest_path):
            return
        with open(self._manifest_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                entry = _ManifestEntry(
                    key=_key_from_json(d["key"]),
                    bb=_bb_from_json(d["bb"]),
                    file=d["file"],
                    offset=d["offset"],
                    nbytes=d["nbytes"],
                    shape=tuple(d["shape"]),
                    dtype=d["dtype"],
                )
                self._index.setdefault(entry.key, []).append(entry)

    def _append_manifest(self, entries: list[_ManifestEntry]) -> None:
        with self._manifest_lock:
            with open(self._manifest_path, "a") as f:
                for e in entries:
                    f.write(
                        json.dumps(
                            {
                                "key": _key_to_json(e.key),
                                "bb": _bb_to_json(e.bb),
                                "file": e.file,
                                "offset": e.offset,
                                "nbytes": e.nbytes,
                                "shape": list(e.shape),
                                "dtype": e.dtype,
                            }
                        )
                        + "\n"
                    )
                f.flush()
                os.fsync(f.fileno())

    # -- write path -------------------------------------------------------------------
    def _group_for_caller(self) -> _IOGroup:
        """Co-located: map the calling thread onto an I/O group slot."""
        slot = threading.get_ident() % max(1, self.io_group_size)
        with self._lock:
            if slot not in self._colocated_groups:
                g = _IOGroup(slot, self)
                g.members = self.io_group_size
                self._colocated_groups[slot] = g
            return self._colocated_groups[slot]

    def _pick_worker(self) -> _IOWorker:
        if self.distribution == "random":
            return self._rng.choice(self._workers)
        with self._lock:
            w = self._workers[self._rr % len(self._workers)]
            self._rr += 1
            return w

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        array = np.ascontiguousarray(array)
        chunk = _Chunk(key, bb, array)
        if self.io_mode == "separated":
            with self._lock:
                self.stats.virtual_comm_s += (
                    self.cost.comm_latency + array.nbytes / self.cost.comm_bandwidth
                )
            self._pick_worker().q.put(chunk)
        elif self.transport == "posix":
            self._write_session(None, [chunk])
        else:
            self._group_for_caller().submit(chunk)

    def _write_session(self, group: _IOGroup | None, chunks: list[_Chunk]) -> None:
        """One (possibly grouped) write session producing a single file."""
        t0 = time.perf_counter()
        fname = f"chunk-{uuid.uuid4().hex}.bin"
        path = os.path.join(self.root, fname)
        entries: list[_ManifestEntry] = []
        offset = 0
        with open(path, "wb") as f:
            for c in chunks:
                raw = c.payload.tobytes()
                f.write(raw)
                entries.append(
                    _ManifestEntry(
                        key=c.key,
                        bb=c.bb,
                        file=fname,
                        offset=offset,
                        nbytes=len(raw),
                        shape=tuple(c.payload.shape),
                        dtype=str(c.payload.dtype),
                    )
                )
                offset += len(raw)
            f.flush()
            os.fsync(f.fileno())
        self._append_manifest(entries)
        with self._lock:
            for e in entries:
                self._index.setdefault(e.key, []).append(e)
            members = group.members if group is not None else 1
            self.stats.chunks_written += len(chunks)
            self.stats.files_written += 1
            self.stats.sessions += 1
            self.stats.bytes_written += offset
            self.stats.wall_write_s += time.perf_counter() - t0
            self.stats.virtual_io_s += (
                self.cost.file_open_cost + offset / self.cost.disk_bandwidth
            )
            # group members synchronize to enter the session together
            self.stats.virtual_sync_s += self.cost.sync_cost * max(0, members - 1)

    def flush(self) -> None:
        """Drain all buffers (and, in separated mode, quiesce the workers)."""
        if self.io_mode == "separated":
            for w in self._workers:
                w.q.join_thread = None  # no-op, keep interface simple
            for w in self._workers:
                w.q.put(None)
            for w in self._workers:
                w.join()
            # restart workers so the store remains usable
            old = self._workers
            self._workers = []
            for i, w in enumerate(old):
                nw = _IOWorker(i, w.group)
                self._workers.append(nw)
                nw.start()
        else:
            with self._lock:
                groups = list(getattr(self, "_colocated_groups", {}).values())
            for g in groups:
                g.drain()

    def close(self) -> None:
        """Drain buffers and retire the I/O workers for good (flush()
        restarts them so the store stays usable; close() does not)."""
        if self.io_mode == "separated":
            workers, self._workers = self._workers, []
            for w in workers:
                w.q.put(None)
            for w in workers:
                w.join()
        else:
            self.flush()

    # -- read path ---------------------------------------------------------------------
    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        from repro.storage.tiers import _assemble

        with self._lock:
            entries = list(self._index.get(key, []))
        if not entries:
            raise KeyError(f"DISK: no data for {key}")

        def _read(e: _ManifestEntry) -> np.ndarray:
            path = os.path.join(self.root, e.file)
            with open(path, "rb") as f:
                f.seek(e.offset)
                raw = f.read(e.nbytes)
            with self._lock:
                self.stats.bytes_read += e.nbytes
            return np.frombuffer(raw, dtype=np.dtype(e.dtype)).reshape(e.shape)

        pieces = ((e.bb, _read(e)) for e in entries if e.bb.intersects(roi))
        out, covered = _assemble(pieces, roi)
        if out is None:
            raise KeyError(f"DISK: {key} has no chunks intersecting {roi}")
        if not covered.all():
            raise KeyError(
                f"DISK: {key} covers only {int(covered.sum())}/{roi.volume} of {roi}"
            )
        return out

    def query(self, namespace: str, name: str) -> list[tuple[RegionKey, BoundingBox]]:
        with self._lock:
            out: dict[RegionKey, BoundingBox] = {}
            for key, entries in self._index.items():
                if key.namespace == namespace and key.name == name:
                    for e in entries:
                        out[key] = e.bb if key not in out else out[key].union(e.bb)
            return sorted(out.items(), key=lambda kv: kv[0])

    def delete(self, key: RegionKey) -> None:
        with self._lock:
            self._index.pop(key, None)
        # files are shared between chunks; physical GC is a separate sweep

    def keys(self) -> list[RegionKey]:
        with self._lock:
            return sorted(self._index)
