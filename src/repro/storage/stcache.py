"""Spatio-temporal region cache with predictive prefetch.

The paper's §7 names this as the motivating extension for the cell-
tracking application: "smart spatial-temporal caching and data
prefetching strategies, which could anticipate the data reading process".

This module implements it:

  * an LRU cache over (key, ROI) reads fronting any StorageBackend;
  * overlap-aware hits: a request is served from cache when a cached
    entry's bounding box *contains* the requested ROI (cheap slicing);
  * a motion-model prefetcher: per (namespace, name) stream, the
    displacement between consecutive requested ROIs is tracked (EWMA),
    the next ROI is extrapolated (spatially, and temporally via the key
    timestamp), and fetched on a background thread before it is asked
    for — the paper's object-tracking access pattern.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey, StorageBackend


@dataclasses.dataclass
class STCacheStats:
    hits: int = 0
    misses: int = 0
    prefetch_issued: int = 0
    prefetch_hits: int = 0
    evictions: int = 0
    bytes_cached: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class SpatioTemporalCache:
    """LRU + motion-predictive prefetch front for a StorageBackend.

    Implements the StorageBackend protocol itself, so it can be
    registered under the same name and dropped in front of DMS or DISK
    transparently (puts write through and update/invalidate the cache).
    """

    def __init__(
        self,
        backend: StorageBackend,
        *,
        name: str | None = None,
        capacity_bytes: int = 256 << 20,
        prefetch: bool = True,
        history: int = 4,
    ) -> None:
        self.backend = backend
        self.name = name or f"{backend.name}+STC"
        self.capacity_bytes = capacity_bytes
        self.prefetch_enabled = prefetch
        self.stats = STCacheStats()
        self._lock = threading.RLock()
        # ordered dict as LRU: (key, bb) -> ndarray
        self._cache: "collections.OrderedDict[tuple, np.ndarray]" = collections.OrderedDict()
        self._inflight: dict[tuple, threading.Event] = {}
        # per-stream request history for the motion model
        self._history: dict[tuple, collections.deque] = {}
        self._hist_len = history

    # -- cache mechanics ---------------------------------------------------------
    def _entry_for(self, key: RegionKey, roi: BoundingBox):
        """Find a cached entry whose box contains roi (containment hit)."""
        for (ck, cbb), arr in reversed(self._cache.items()):
            if ck == key and cbb.contains(roi):
                return (ck, cbb), arr
        return None, None

    def _insert(self, key: RegionKey, bb: BoundingBox, arr: np.ndarray) -> None:
        with self._lock:
            ck = (key, bb)
            if ck in self._cache:
                self._cache.move_to_end(ck)
                return
            self._cache[ck] = arr
            self.stats.bytes_cached += arr.nbytes
            while self.stats.bytes_cached > self.capacity_bytes and len(self._cache) > 1:
                _, old = self._cache.popitem(last=False)
                self.stats.bytes_cached -= old.nbytes
                self.stats.evictions += 1

    def invalidate(self, key: RegionKey) -> None:
        with self._lock:
            for ck in [ck for ck in self._cache if ck[0] == key]:
                self.stats.bytes_cached -= self._cache[ck].nbytes
                del self._cache[ck]

    # -- motion model ----------------------------------------------------------------
    def _stream_id(self, key: RegionKey) -> tuple:
        return (key.namespace, key.name)

    def _record_and_predict(
        self, key: RegionKey, roi: BoundingBox
    ) -> tuple[RegionKey, BoundingBox] | None:
        sid = self._stream_id(key)
        hist = self._history.setdefault(sid, collections.deque(maxlen=self._hist_len))
        hist.append((key, roi))
        if len(hist) < 2:
            return None
        (k0, r0), (k1, r1) = hist[-2], hist[-1]
        if r0.rank != r1.rank:
            return None
        # EWMA displacement over the full history
        deltas = []
        items = list(hist)
        for (ka, ra), (kb, rb) in zip(items[:-1], items[1:]):
            if ra.rank == rb.rank:
                deltas.append(tuple(lb - la for la, lb in zip(ra.lo, rb.lo)))
        if not deltas:
            return None
        w = 0.0
        acc = [0.0] * len(deltas[0])
        weight = 1.0
        for d in reversed(deltas):
            for i, v in enumerate(d):
                acc[i] += weight * v
            w += weight
            weight *= 0.5
        disp = tuple(int(round(a / w)) for a in acc)
        dt = k1.timestamp - k0.timestamp
        next_key = k1.at(k1.timestamp + dt) if dt else k1
        next_roi = r1.translate(disp)
        if next_roi == r1 and next_key == k1:
            return None
        return next_key, next_roi

    def _prefetch(self, key: RegionKey, roi: BoundingBox) -> None:
        ck = (key, roi)
        with self._lock:
            hit, _ = self._entry_for(key, roi)
            if hit is not None or ck in self._inflight:
                return
            evt = threading.Event()
            self._inflight[ck] = evt
            self.stats.prefetch_issued += 1

        def work():
            try:
                arr = self.backend.get(key, roi)
                self._insert(key, roi, np.asarray(arr))
            except KeyError:
                pass  # predicted region does not exist (yet) — harmless
            finally:
                with self._lock:
                    self._inflight.pop(ck, None)
                evt.set()

        threading.Thread(target=work, daemon=True, name="st-prefetch").start()

    # -- StorageBackend protocol ----------------------------------------------------
    def get(self, key: RegionKey, roi: BoundingBox) -> np.ndarray:
        with self._lock:
            ck, arr = self._entry_for(key, roi)
            inflight = self._inflight.get((key, roi))
        if inflight is not None:
            inflight.wait()
            with self._lock:
                ck, arr = self._entry_for(key, roi)
            if arr is not None:
                self.stats.prefetch_hits += 1
        if arr is not None:
            with self._lock:
                self.stats.hits += 1
                self._cache.move_to_end(ck)
            out = arr[roi.local_slices(ck[1])] if ck[1] != roi else arr
        else:
            with self._lock:
                self.stats.misses += 1
            out = np.asarray(self.backend.get(key, roi))
            self._insert(key, roi, out)
        if self.prefetch_enabled:
            pred = self._record_and_predict(key, roi)
            if pred is not None:
                self._prefetch(*pred)
        return out

    def put(self, key: RegionKey, bb: BoundingBox, array: np.ndarray) -> None:
        self.backend.put(key, bb, array)
        self.invalidate(key)  # write-through + invalidate overlaps
        self._insert(key, bb, np.asarray(array))

    def query(self, namespace: str, name: str):
        return self.backend.query(namespace, name)

    def delete(self, key: RegionKey) -> None:
        self.backend.delete(key)
        self.invalidate(key)

    def close(self) -> None:
        """Stop issuing prefetches and wait out in-flight prefetch
        threads (each signals its event when done, hit or miss)."""
        self.prefetch_enabled = False
        with self._lock:
            pending = list(self._inflight.values())
        for evt in pending:
            evt.wait(timeout=5.0)
