"""I/O configuration auto-tuning (paper §5.3 future work).

"We intend to examine ... methods for automating the choice of the I/O
configuration through the integration with parameter auto-tuning
systems" — this module does exactly that over the DISK engine's
configuration space (transport x placement x group size x queue depth)
using the virtual-time cost model as the objective, with a simple
successive-halving search (cheap configs are measured on small workload
slices first; survivors graduate to the full workload).
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
from typing import Iterable

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import ElementType, RegionKey
from repro.storage.disk import DiskCostModel, DiskStorage


@dataclasses.dataclass(frozen=True)
class IOConfig:
    transport: str
    io_mode: str
    io_group_size: int
    queue_threshold: int
    num_io_workers: int = 0

    def build(self, root: str, cost_model: DiskCostModel | None = None) -> DiskStorage:
        return DiskStorage(
            root,
            transport=self.transport,
            io_mode=self.io_mode,
            io_group_size=self.io_group_size,
            num_io_workers=self.num_io_workers,
            queue_threshold=self.queue_threshold,
        )


def default_space(num_writers: int) -> list[IOConfig]:
    out = []
    for transport in ("posix", "aggregated"):
        groups = [1] if transport == "posix" else sorted({1, 4, num_writers})
        for g in groups:
            for q in ([1] if transport == "posix" else [2, 8]):
                out.append(IOConfig(transport, "colocated", g, q))
                out.append(IOConfig(transport, "separated", g, q,
                                    num_io_workers=max(2, num_writers // 2)))
    return out


@dataclasses.dataclass
class TuneResult:
    best: IOConfig
    virtual_s: float
    trials: list[tuple[IOConfig, float]]


def _drive(store: DiskStorage, n_chunks: int, chunk: int = 32) -> float:
    arr = np.ones((chunk, chunk), np.float32)
    for i in range(n_chunks):
        key = RegionKey("tune", f"c{i % 8}", ElementType.FLOAT32, timestamp=i)
        store.put(key, BoundingBox((0, 0), (chunk, chunk)), arr)
    store.flush()
    return store.stats.virtual_total_s


def autotune_io(
    *,
    num_writers: int = 16,
    workload_chunks: int = 64,
    space: Iterable[IOConfig] | None = None,
    survivors: int = 4,
) -> TuneResult:
    """Successive halving over the I/O config space (virtual time)."""
    space = list(space or default_space(num_writers))
    # round 1: 1/4 workload
    trials = []
    for cfg in space:
        tmp = tempfile.mkdtemp(prefix="iotune_")
        try:
            t = _drive(cfg.build(tmp), max(4, workload_chunks // 4))
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        trials.append((cfg, t))
    trials.sort(key=lambda ct: ct[1])
    finalists = [c for c, _ in trials[: max(survivors, 1)]]
    # round 2: full workload
    final = []
    for cfg in finalists:
        tmp = tempfile.mkdtemp(prefix="iotune_")
        try:
            t = _drive(cfg.build(tmp), workload_chunks)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        final.append((cfg, t))
    final.sort(key=lambda ct: ct[1])
    best, best_t = final[0]
    return TuneResult(best=best, virtual_s=best_t, trials=trials + final)
