"""Shared-memory block arena for same-host zero-copy fetches.

The paper's DataSpaces deployment keeps region payloads in RDMA-
registered server memory and ships *descriptors*, not bytes.  This is
the commodity-hardware equivalent: each storage server owns one
``multiprocessing.shared_memory`` segment (the **arena**) and keeps its
resident blocks inside it.  A co-located client maps the same segment
once (the **window**); a fetch reply then carries only ``(offset,
length)`` over the control socket (~50us round-trip) and the client
reads the payload straight out of the mapping — the block bytes never
cross the TCP stream, never get concatenated, and are copied at most
once (zero times with ``zero_copy=True``).

Same-host proof: the segment name alone is not evidence of co-location
(names are not globally unique across hosts).  The server writes a
random 16-byte token at arena offset 0 and sends it in the negotiation
reply; the client attaches, compares, and silently falls back to socket
payloads on any mismatch or attach failure — remote clients keep
working, they just pay the stream copy.

Lifetime rules (RDMA-window semantics):

  * a block's arena slot is valid until that block is dropped or
    overwritten; fetches default to copying out (safe), and
    ``zero_copy=True`` returns a read-only view whose base is the
    mapping — callers own the aliasing hazard;
  * freed slots sit in a short quarantine before reuse so an in-flight
    reader of a just-dropped block sees stale-but-consistent bytes
    rather than a torn rewrite;
  * the server unlinks the segment on clean shutdown; if it is
    SIGKILLed, Python's ``resource_tracker`` in the spawning process
    reclaims the segment (clients therefore *unregister* their attach —
    pre-3.13 ``SharedMemory`` has no ``track=False``).
"""
from __future__ import annotations

import secrets
import threading
import time
from multiprocessing import resource_tracker, shared_memory

import numpy as np

TOKEN_BYTES = 16
_ALIGN = 64  # cache-line align block slots
_QUARANTINE_S = 1.0

# mappings that could not be closed because zero-copy views still alias
# them: keep them referenced so SharedMemory.__del__ never re-raises the
# BufferError as an unraisable warning — the mapping lives until process
# exit, which is exactly what the outstanding views require anyway
_PINNED: list = []


def _close_quiet(shm: shared_memory.SharedMemory) -> None:
    try:
        shm.close()
    except BufferError:
        _PINNED.append(shm)


def _align(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


class ShmArena:
    """Server-side shared-memory segment holding resident block payloads.

    First-fit free-list allocator over one segment; slots are keyed by
    an opaque hashable ``handle`` (the server uses ``(sid, key,
    coord)``).  All methods are thread-safe.  Under pressure ``place``
    evicts least-recently-fetched residents (their bytes move to a heap
    ledger the owning shard reclaims on its next read) and only returns
    ``None`` when the block doesn't fit even then — callers degrade to
    heap residency + socket payloads, never fail the store.
    """

    def __init__(self, capacity: int, name: str | None = None):
        capacity = max(int(capacity), _ALIGN)
        self._shm = shared_memory.SharedMemory(
            create=True, size=_ALIGN + capacity, name=name
        )
        token = secrets.token_bytes(TOKEN_BYTES)
        self._shm.buf[:TOKEN_BYTES] = token
        self.token = token.hex()
        self.name = self._shm.name
        self.capacity = capacity
        self._lock = threading.Lock()
        # free list: sorted [offset, size]; offsets relative to segment
        self._free: list[list[int]] = [[_ALIGN, capacity]]
        self._used: dict[object, tuple[int, int]] = {}  # handle -> (off, size)
        self._quarantine: list[tuple[float, int, int]] = []  # (free_at, off, size)
        self._closed = False
        # LRU eviction state: fetch-recency clock per resident handle,
        # and the heap ledger holding evicted blocks' bytes until their
        # owning shard reclaims them (lazily, on its next read) — an
        # eviction demotes a block to heap residency, never loses it
        self._recency: dict[object, int] = {}
        self._evicted: dict[object, bytes] = {}
        self._seq = 0
        self.evictions = 0

    # -- allocation ----------------------------------------------------

    def _reclaim_locked(self, now: float) -> None:
        keep = []
        for free_at, off, size in self._quarantine:
            if free_at <= now:
                self._insert_free_locked(off, size)
            else:
                keep.append((free_at, off, size))
        self._quarantine = keep

    def _reclaim_some_locked(self, nbytes: int) -> int | None:
        """Pressure fallback: free quarantined slots oldest-deadline
        first, retrying the allocation after each, so a forced early
        reuse recycles as few still-in-grace slots as possible (an
        in-flight shm reader of a just-freed slot gets the full grace
        window unless its bytes are the only way to satisfy the
        allocation)."""
        for entry in sorted(self._quarantine):
            self._quarantine.remove(entry)
            self._insert_free_locked(entry[1], entry[2])
            got = self._alloc_locked(nbytes)
            if got is not None:
                return got
        return None

    def _insert_free_locked(self, off: int, size: int) -> None:
        free = self._free
        lo, hi = 0, len(free)
        while lo < hi:
            mid = (lo + hi) // 2
            if free[mid][0] < off:
                lo = mid + 1
            else:
                hi = mid
        free.insert(lo, [off, size])
        # coalesce with neighbours
        if lo + 1 < len(free) and free[lo][0] + free[lo][1] == free[lo + 1][0]:
            free[lo][1] += free[lo + 1][1]
            del free[lo + 1]
        if lo > 0 and free[lo - 1][0] + free[lo - 1][1] == free[lo][0]:
            free[lo - 1][1] += free[lo][1]
            del free[lo]

    def _alloc_locked(self, nbytes: int) -> int | None:
        want = _align(nbytes)
        for i, (off, size) in enumerate(self._free):
            if size >= want:
                if size == want:
                    del self._free[i]
                else:
                    self._free[i] = [off + want, size - want]
                return off
        return None

    def place(self, handle, payload) -> np.ndarray | None:
        """Copy ``payload`` (any buffer/ndarray) into the arena under
        ``handle`` and return a read-only ndarray view over the slot, or
        ``None`` when it doesn't fit.  Replaces any existing slot for
        the handle (old slot goes to quarantine)."""
        arr = np.ascontiguousarray(payload)
        nbytes = arr.nbytes
        if nbytes == 0 or nbytes > self.capacity:
            return None
        with self._lock:
            if self._closed:  # checked under _lock: close() races with place()
                return None
            self._release_locked(handle)
            now = time.monotonic()
            self._reclaim_locked(now)
            off = self._alloc_locked(nbytes)
            if off is None:
                # pressure: reclaim quarantined slots (oldest first) and retry
                off = self._reclaim_some_locked(nbytes)
            if off is None:
                # still full: evict cold residents (LRU by fetch recency)
                off = self._evict_locked(nbytes, keep=handle, now=now)
            if off is None:
                return None
            self._used[handle] = (off, nbytes)
            self._seq += 1
            self._recency[handle] = self._seq
            self._evicted.pop(handle, None)  # a re-place supersedes any saved copy
        dst = np.frombuffer(self._shm.buf, dtype=np.uint8, count=nbytes, offset=off)
        try:
            dst[:] = arr.view(np.uint8).reshape(-1)
        except (TypeError, ValueError):
            # extended dtypes refuse the zero-copy uint8 view
            dst[:] = np.frombuffer(arr.tobytes(), dtype=np.uint8)
        view = np.frombuffer(self._shm.buf, dtype=arr.dtype.base, count=arr.size, offset=off)
        view = view.reshape(arr.shape)
        view.setflags(write=False)
        return view

    def _evict_locked(self, nbytes: int, keep, now: float) -> int | None:
        """Evict least-recently-fetched residents until ``nbytes`` fits.
        Each victim's bytes are saved to the heap ledger first (its
        owning shard re-homes them via :meth:`claim_or_touch` on the
        next read), then its slot takes the same quarantine grace as a
        released slot — an in-flight shm reader holding the victim's
        ``(off, nbytes)`` ref must not have the bytes recycled under it.
        The quarantine is drained early (oldest slots first) only as far
        as the allocation demands — the pressure fallback — so a victim
        is reused immediately only when its space is the sole way to
        satisfy the store; the block itself is demoted, never dropped."""
        order = sorted(self._used, key=lambda h: self._recency.get(h, 0))
        for victim in order:
            if victim == keep:
                continue
            off, size = self._used.pop(victim)
            self._evicted[victim] = bytes(self._shm.buf[off : off + size])
            self._recency.pop(victim, None)
            self.evictions += 1
            self._quarantine.append((now + _QUARANTINE_S, off, size))
            got = self._reclaim_some_locked(nbytes)
            if got is not None:
                return got
        return None

    def claim_or_touch(self, handle) -> bytes | None:
        """Either hand back an evicted block's saved bytes (consuming
        the ledger entry — the caller re-homes them on its heap) or, for
        a still-resident block, bump its fetch recency and return
        ``None``.  The shard calls this on every read of an
        arena-resident block, which is what makes the eviction order
        *fetch* recency rather than placement order."""
        with self._lock:
            raw = self._evicted.pop(handle, None)
            if raw is not None:
                return raw
            if handle in self._used:
                self._seq += 1
                self._recency[handle] = self._seq
            return None

    def locate(self, handle) -> tuple[int, int] | None:
        """(offset, nbytes) of a resident block, or ``None``."""
        with self._lock:
            return self._used.get(handle)

    def _release_locked(self, handle) -> None:
        slot = self._used.pop(handle, None)
        self._recency.pop(handle, None)
        self._evicted.pop(handle, None)
        if slot is not None:
            self._quarantine.append((time.monotonic() + _QUARANTINE_S, slot[0], slot[1]))

    def release(self, handle) -> None:
        with self._lock:
            self._release_locked(handle)

    # -- observability / lifecycle ------------------------------------

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return sum(size for _, size in self._used.values())

    def describe(self) -> dict:
        """Negotiation payload for the hello reply."""
        return {"name": self.name, "size": self._shm.size, "token": self.token}

    def close(self, *, unlink: bool = True) -> None:
        with self._lock:
            self._closed = True
            self._used.clear()
            self._recency.clear()
            self._evicted.clear()
        if unlink:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass
        # live ndarray views over the mapping (server shutting down with
        # resident blocks) keep it pinned; the unlink above already freed
        # the name — the mapping itself dies with the process
        _close_quiet(self._shm)


class ShmWindow:
    """Client-side read-only mapping of a server's arena."""

    def __init__(self, shm: shared_memory.SharedMemory, token: str):
        self._shm = shm
        self.token = token
        self.name = shm.name

    @classmethod
    def attach(cls, desc: dict) -> "ShmWindow | None":
        """Attach to the arena described by a hello reply; ``None`` when
        the segment is unreachable or the token disproves co-location
        (callers fall back to socket payloads)."""
        try:
            try:
                shm = shared_memory.SharedMemory(name=desc["name"], track=False)
            except TypeError:  # pre-3.13: no track kwarg
                shm = shared_memory.SharedMemory(name=desc["name"])
                try:
                    # the attach registered the segment with OUR
                    # resource tracker, which would unlink the SERVER'S
                    # memory when this process exits — undo that.
                    resource_tracker.unregister(shm._name, "shared_memory")
                except Exception:
                    pass
        except (FileNotFoundError, OSError, ValueError):
            return None
        if bytes(shm.buf[:TOKEN_BYTES]).hex() != desc.get("token"):
            _close_quiet(shm)
            return None
        return cls(shm, desc["token"])

    def read(self, off: int, meta: dict, *, zero_copy: bool = False) -> np.ndarray:
        """Decode the block at ``off`` described by array header
        ``meta``.  Default copies out (safe after the slot is reused);
        ``zero_copy=True`` returns a read-only view into the mapping,
        valid until the block is dropped or overwritten server-side."""
        from repro.storage.codec import _dtype_from_str

        dt = _dtype_from_str(meta["dtype"])
        shape = tuple(meta["shape"])
        n = 1
        for s in shape:
            n *= int(s)
        view = np.frombuffer(self._shm.buf, dtype=dt, count=n, offset=off).reshape(shape)
        if zero_copy:
            view.setflags(write=False)
            return view
        return view.copy()

    def close(self) -> None:
        # if the caller still holds zero-copy views the mapping is
        # pinned instead and persists until process exit
        _close_quiet(self._shm)
