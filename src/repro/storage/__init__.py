"""Global storage implementations for data regions (paper S4 + S7).

Storage hierarchy
-----------------
All backends implement the same ``StorageBackend`` protocol
(``put``/``get``/``query``/``delete``), so stages never care where bytes
live.  Picking one:

* ``DistributedMemoryStorage`` (DMS) — in-memory, SFC-partitioned across
  servers; the fastest *shared* layer.  Use for hot inter-stage exchange
  when everything fits in aggregate RAM.
* ``DiskStorage`` (DISK) — ADIOS-style chunked staging with I/O groups
  and a crash-tolerant manifest.  Use for durable staging, checkpoints,
  and payloads too large for memory.
* ``SpatioTemporalCache`` — an LRU + motion-predictive prefetch *front*
  for any single backend.  Use when one client re-reads a drifting ROI
  stream (tracking workloads).
* ``TieredStore`` — the automatic hierarchy (bounded RAM tier -> DISK ->
  DMS) behind one name: read-through promotion, capacity-triggered
  spill-down, write-through/write-back with ``flush()``/``drain()``, and
  a ``PlacementPolicy`` hook (pin namespaces, size/dtype thresholds, ROI
  spill granularity).  Prefer it whenever the working set is bigger than
  any single layer or the access pattern is not known up front; its
  ``locality(key)`` query also lets the runtime scheduler price
  transfers per tier.
"""
from repro.storage.autotune import IOConfig, TuneResult, autotune_io
from repro.storage.checkpoint import CheckpointManager
from repro.storage.disk import DiskCostModel, DiskStats, DiskStorage
from repro.storage.dms import DistributedMemoryStorage, InProcTransport, TransportStats
from repro.storage.placement import (
    Placement,
    PlacementPolicy,
    PlacementRule,
    dtype_tier,
    pin_namespace,
    size_threshold,
)
from repro.storage.stcache import SpatioTemporalCache, STCacheStats
from repro.storage.tiers import (
    TIER_BANDWIDTH,
    MemoryTier,
    Tier,
    TieredStore,
    TierStats,
)

__all__ = [
    "CheckpointManager",
    "DiskCostModel",
    "DiskStats",
    "DiskStorage",
    "DistributedMemoryStorage",
    "InProcTransport",
    "TransportStats",
    "IOConfig",
    "TuneResult",
    "autotune_io",
    "SpatioTemporalCache",
    "STCacheStats",
    "Placement",
    "PlacementPolicy",
    "PlacementRule",
    "dtype_tier",
    "pin_namespace",
    "size_threshold",
    "TIER_BANDWIDTH",
    "MemoryTier",
    "Tier",
    "TieredStore",
    "TierStats",
]
