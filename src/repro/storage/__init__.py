"""Global storage implementations for data regions (paper S4 + S7)."""
from repro.storage.autotune import IOConfig, TuneResult, autotune_io
from repro.storage.checkpoint import CheckpointManager
from repro.storage.disk import DiskCostModel, DiskStats, DiskStorage
from repro.storage.dms import DistributedMemoryStorage, InProcTransport, TransportStats
from repro.storage.stcache import SpatioTemporalCache, STCacheStats

__all__ = [
    "CheckpointManager",
    "DiskCostModel",
    "DiskStats",
    "DiskStorage",
    "DistributedMemoryStorage",
    "InProcTransport",
    "TransportStats",
]
