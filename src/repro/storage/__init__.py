"""Global storage implementations for data regions (paper S4 + S7).

Storage hierarchy
-----------------
All backends implement the same ``StorageBackend`` protocol
(``put``/``get``/``query``/``delete``), so stages never care where bytes
live.  Picking one:

* ``DistributedMemoryStorage`` (DMS) — in-memory, SFC-partitioned across
  servers; the fastest *shared* layer.  Use for hot inter-stage exchange
  when everything fits in aggregate RAM.  Its servers sit behind the
  ``Transport`` message protocol: ``InProcTransport`` (in-process shards
  + virtual-time link model) or ``SocketTransport`` (framed TCP to
  ``ServerProcess`` hosts — the multi-host deployment).
  ``replication=R`` places every block on R servers along the SFC ring
  and fails reads over between replicas, so R-1 dead servers cause zero
  failed reads (directories are replicated everywhere already).
* ``DiskStorage`` (DISK) — ADIOS-style chunked staging with I/O groups
  and a crash-tolerant manifest.  Use for durable staging, checkpoints,
  and payloads too large for memory.
* ``SpatioTemporalCache`` — an LRU + motion-predictive prefetch *front*
  for any single backend.  Use when one client re-reads a drifting ROI
  stream (tracking workloads).
* ``TieredStore`` — the automatic hierarchy (bounded RAM tier -> DISK ->
  DMS) behind one name: read-through promotion, capacity-triggered
  spill-down, write-through/write-back with ``flush()``/``drain()``, and
  a ``PlacementPolicy`` hook (pin namespaces, size/dtype thresholds, ROI
  spill granularity).  Prefer it whenever the working set is bigger than
  any single layer or the access pattern is not known up front; its
  ``locality(key)`` query also lets the runtime scheduler price
  transfers per tier.
"""
from repro.storage.autotune import IOConfig, TuneResult, autotune_io
from repro.storage.codec import (
    WIRE_CODECS,
    Encoded,
    decode_block,
    encode_block,
)
from repro.storage.disk import DiskCostModel, DiskStats, DiskStorage
from repro.storage.dms import (
    DistributedMemoryStorage,
    DMSStats,
    InProcTransport,
    Transport,
    TransportError,
    TransportStats,
    decode_homes,
    encode_homes,
)
from repro.storage.net import (
    ServerGroup,
    ServerProcess,
    ShmTransport,
    SocketTransport,
    spawn_servers,
)
from repro.storage.membership import RingView, TokenBucket, adopt_newer
from repro.storage.shm import ShmArena, ShmWindow
from repro.storage.placement import (
    Placement,
    PlacementPolicy,
    PlacementRule,
    dtype_tier,
    pin_namespace,
    size_threshold,
    when,
)
from repro.storage.stcache import SpatioTemporalCache, STCacheStats
from repro.storage.tiers import (
    TIER_BANDWIDTH,
    MemoryTier,
    Tier,
    TieredStore,
    TierStats,
)

__all__ = [
    "CheckpointManager",
    "DiskCostModel",
    "DiskStats",
    "DiskStorage",
    "DistributedMemoryStorage",
    "DMSStats",
    "InProcTransport",
    "Transport",
    "TransportStats",
    "decode_homes",
    "encode_homes",
    "ServerGroup",
    "ServerProcess",
    "ShmArena",
    "ShmTransport",
    "ShmWindow",
    "SocketTransport",
    "TransportError",
    "spawn_servers",
    "WIRE_CODECS",
    "Encoded",
    "decode_block",
    "encode_block",
    "IOConfig",
    "TuneResult",
    "autotune_io",
    "SpatioTemporalCache",
    "STCacheStats",
    "RingView",
    "TokenBucket",
    "adopt_newer",
    "Placement",
    "PlacementPolicy",
    "PlacementRule",
    "dtype_tier",
    "pin_namespace",
    "size_threshold",
    "when",
    "TIER_BANDWIDTH",
    "MemoryTier",
    "Tier",
    "TieredStore",
    "TierStats",
]


def __getattr__(name: str):
    # CheckpointManager pulls in jax at import time; loading it lazily
    # keeps `python -m repro.storage.net` server processes jax-free (they
    # only move numpy buffers) and fast to spawn.
    if name == "CheckpointManager":
        from repro.storage.checkpoint import CheckpointManager

        return CheckpointManager
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
