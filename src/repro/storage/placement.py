"""Placement policies for the tiered storage hierarchy.

The paper's unified region interface (§4, Fig. 8) hides *where* a data
region's bytes live; a :class:`PlacementPolicy` is the hook that decides
it.  Given the region identifier and payload metadata, the policy answers

  * which tier a fresh ``put`` should land in (pin hot namespaces to the
    memory tier, push cold/bulky regions straight to DISK or DMS);
  * whether the region may be promoted above / demoted below its tier;
  * the write policy for the region (write-through vs. write-back);
  * the spill granularity: demotions may be re-blocked into fixed ROI
    tiles so a later partial read from the lower tier moves only the
    tiles that intersect the request.

Policies are plain data + pure functions of the request, so the
:class:`~repro.storage.tiers.TieredStore` can evaluate them under its
lock without side effects.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.bbox import BoundingBox
from repro.core.regions import RegionKey


@dataclasses.dataclass(frozen=True)
class Placement:
    """One placement decision for a region.

    ``tier``: target tier *name* (None = the store's top tier).
    ``pinned``: region must stay in its tier (never demoted out, never
    promoted above).
    ``write_policy``: per-region override of the store default
    ("write_through" | "write_back" | None = store default).
    ``spill_block``: ROI tile shape used when demoting; None spills the
    region as one chunk.
    """

    tier: str | None = None
    pinned: bool = False
    write_policy: str | None = None
    spill_block: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if self.write_policy not in (None, "write_through", "write_back", "lazy"):
            raise ValueError(
                f"unknown write_policy {self.write_policy!r} "
                "(want 'write_through' | 'write_back' | 'lazy')"
            )


@dataclasses.dataclass(frozen=True)
class PlacementRule:
    """A predicate + the placement it yields; first matching rule wins."""

    match: Callable[[RegionKey, BoundingBox, int, np.dtype], bool]
    placement: Placement
    label: str = "rule"


def pin_namespace(namespace: str, tier: str, **kw) -> PlacementRule:
    """Pin every region of ``namespace`` to ``tier`` (paper: hot stage
    intermediates stay in the memory layer)."""
    return PlacementRule(
        match=lambda key, bb, nbytes, dtype: key.namespace == namespace,
        placement=Placement(tier=tier, pinned=True, **kw),
        label=f"pin:{namespace}->{tier}",
    )


def size_threshold(max_bytes: int, tier: str, **kw) -> PlacementRule:
    """Regions larger than ``max_bytes`` bypass the fast tiers and land
    directly in ``tier`` (bulk payloads would only thrash the cache)."""
    return PlacementRule(
        match=lambda key, bb, nbytes, dtype: nbytes > max_bytes,
        placement=Placement(tier=tier, **kw),
        label=f"size>{max_bytes}->{tier}",
    )


def when(
    cond: Callable[[RegionKey, BoundingBox, int, np.dtype], bool],
    tier: str,
    *,
    label: str | None = None,
    **kw,
) -> PlacementRule:
    """The general rule: route regions matching an arbitrary predicate
    ``cond(key, bb, nbytes, dtype)`` to ``tier``.  The named helpers are
    special cases of this; use it for ad-hoc routing (e.g. steering a
    timestamp range to the DMS tier while an elastic fleet rebalances)."""
    return PlacementRule(
        match=cond,
        placement=Placement(tier=tier, **kw),
        label=label or f"when:{getattr(cond, '__name__', 'cond')}->{tier}",
    )


def dtype_tier(dtypes: Sequence, tier: str, **kw) -> PlacementRule:
    """Route payloads of the given dtypes to ``tier`` (e.g. uint8 masks
    are cheap to recompute — keep them out of the memory tier)."""
    dts = {np.dtype(d) for d in dtypes}
    return PlacementRule(
        match=lambda key, bb, nbytes, dtype: np.dtype(dtype) in dts,
        placement=Placement(tier=tier, **kw),
        label=f"dtype:{sorted(str(d) for d in dts)}->{tier}",
    )


class PlacementPolicy:
    """Ordered rule list with a default placement.

    ``rules`` are evaluated first-match-wins; when none matches the
    default placement (top tier, store-default write policy) applies.
    ``spill_block`` set on the policy applies to every demotion whose
    matched placement did not set its own.
    """

    def __init__(
        self,
        rules: Sequence[PlacementRule] = (),
        *,
        default: Placement | None = None,
        spill_block: tuple[int, ...] | None = None,
    ) -> None:
        self.rules = list(rules)
        self.default = default or Placement()
        self.spill_block = spill_block

    def place(
        self, key: RegionKey, bb: BoundingBox, nbytes: int, dtype
    ) -> Placement:
        for rule in self.rules:
            if rule.match(key, bb, nbytes, dtype):
                return self._with_spill(rule.placement)
        return self._with_spill(self.default)

    def _with_spill(self, p: Placement) -> Placement:
        if p.spill_block is None and self.spill_block is not None:
            return dataclasses.replace(p, spill_block=self.spill_block)
        return p

    def __repr__(self) -> str:
        labels = ", ".join(r.label for r in self.rules) or "default-only"
        return f"PlacementPolicy({labels})"
