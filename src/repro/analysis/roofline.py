"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh) cell, all in seconds/step:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF bf16)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_chip / ICI_bw       (~50 GB/s/link)

HLO quantities come from the multiplicity-aware analyzer (analysis/hlo.py)
over the per-partition SPMD module, so they are already per-chip.
MODEL_FLOPS uses 6*N*D for training (2*N*D inference), N_active for MoE.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link (~50 GB/s)
    hbm_bytes: float = 16e9  # capacity per chip


V5E = HardwareSpec()


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bottleneck: str
    step_s: float  # max of the three (no-overlap bound)
    roofline_fraction: float  # compute_s / step_s: how compute-bound we are

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(
    n_active_params: int, tokens: int, *, training: bool
) -> float:
    return (6.0 if training else 2.0) * n_active_params * tokens


def compute_terms(
    *,
    flops_per_chip: float,
    bytes_per_chip: float,
    collective_bytes_per_chip: float,
    chips: int,
    model_flops_total: float,
    hw: HardwareSpec = V5E,
) -> RooflineTerms:
    compute_s = flops_per_chip / hw.peak_flops
    memory_s = bytes_per_chip / hw.hbm_bw
    collective_s = collective_bytes_per_chip / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    hlo_total = flops_per_chip * chips
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=model_flops_total,
        hlo_flops_total=hlo_total,
        useful_ratio=model_flops_total / hlo_total if hlo_total else 0.0,
        bottleneck=bottleneck,
        step_s=step_s,
        roofline_fraction=compute_s / step_s if step_s else 0.0,
    )
