"""Dry-run analysis: HLO parsing + roofline terms."""
from repro.analysis import hlo, roofline

__all__ = ["hlo", "roofline"]
